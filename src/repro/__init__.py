"""ElasWave-JAX: elastic-native hybrid-parallel training framework.

Public API:
  repro.core       - ElasWave planners / engine / fabric / VirtualCluster
  repro.models     - model zoo + ModelConfig
  repro.configs    - the 10 assigned architectures
  repro.parallel   - production-mesh sharding rules
  repro.optim      - sharded mixed-precision AdamW
  repro.data       - sample-id-addressed data pipeline
  repro.kernels    - Pallas TPU kernels (+ oracles)
  repro.launch     - mesh / dry-run / training launchers
  repro.checkpoint - cold-restart disk checkpointing
"""

__version__ = "1.0.0"
