"""RMSNorm — Pallas TPU kernel.

Row-blocked: grid over row tiles; each program normalizes [block_rows, d] in
VMEM (d is the lane dimension, padded to 128 by the compiler).  fp32 math,
cast back to the input dtype — exactly matching ref.rmsnorm_reference.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) * s_ref[...]).astype(o_ref.dtype)


def rmsnorm_kernel(x, scale, *, eps: float = 1e-5, block_rows: int = 256,
                   interpret: bool = True):
    """x: [..., d]; scale: [d]."""
    orig_shape = x.shape
    d = x.shape[-1]
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    block_rows = min(block_rows, rows)
    # pad rows to a multiple of block_rows
    pad = (-rows) % block_rows
    if pad:
        x2 = jnp.concatenate([x2, jnp.zeros((pad, d), x.dtype)], axis=0)
    grid = (x2.shape[0] // block_rows,)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret,
    )(x2, scale.astype(jnp.float32))
    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)
