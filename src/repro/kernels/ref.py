"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mha_reference(q, k, v, *, causal: bool = True, sm_scale=None):
    """q,k,v: [BH, S, d] -> [BH, S, d]; fp32 softmax like the kernel."""
    BH, S, d = q.shape
    sm_scale = sm_scale if sm_scale is not None else d ** -0.5
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * sm_scale
    if causal:
        mask = jnp.tril(jnp.ones((S, S), dtype=bool))
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)


def rmsnorm_reference(x, scale, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


def ssd_reference(x, dt, A, Bh, Ch, initial_state=None):
    """Sequential (recurrent) SSD oracle — O(S) scan, exact.

    x: [b,s,h,p]; dt: [b,s,h]; A: [h]; Bh, Ch: [b,s,h,n] (groups pre-broadcast).
    Returns y: [b,s,h,p], final_state: [b,h,p,n].
    """
    b, s, h, p = x.shape
    n = Bh.shape[-1]
    state0 = initial_state if initial_state is not None else \
        jnp.zeros((b, h, p, n), jnp.float32)

    def step(state, inp):
        xt, dtt, Bt, Ct = inp          # [b,h,p], [b,h], [b,h,n], [b,h,n]
        dA = jnp.exp(dtt * A[None, :])                       # [b,h]
        upd = jnp.einsum("bh,bhp,bhn->bhpn", dtt, xt.astype(jnp.float32),
                         Bt.astype(jnp.float32))
        state = dA[:, :, None, None] * state + upd
        y = jnp.einsum("bhpn,bhn->bhp", state, Ct.astype(jnp.float32))
        return state, y

    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(Bh, 1, 0), jnp.moveaxis(Ch, 1, 0))
    final, ys = jax.lax.scan(step, state0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), final
