"""Mamba2 SSD chunk scan — Pallas TPU kernel.

The SSD recurrence is computed chunk-by-chunk: within a chunk the quadratic
(matmul-rich, MXU-friendly) form produces the intra-chunk output; the carried
state [p, n] lives in VMEM scratch and is advanced across the sequential
chunk grid dimension.  Tiling:

  grid = (batch, heads, num_chunks)   # chunks sequential (carry in scratch)
  VMEM blocks: x[c, p], dt[c], B[c, n], C[c, n], out y[c, p], state[p, n]

For mamba2-2.7b (p=64, n=128, c=256) the working set is
  256*64 + 2*256*128 + 64*128 floats ≈ 0.4 MiB — VMEM-friendly; matmul dims
(c=256, n=128, p=64) are MXU-aligned on two of three axes.

Groups are pre-broadcast to heads by the ops.py wrapper.  Validated in
interpret mode against ref.ssd_reference (exact sequential recurrence).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, A_ref, B_ref, C_ref, y_ref, state_ref,
                *, chunk: int):
    ci = pl.program_id(2)
    hi = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[...].astype(jnp.float32)        # [c, p]
    dt = dt_ref[...].astype(jnp.float32)      # [c]
    A = A_ref[hi]                             # scalar decay for this head
    B = B_ref[...].astype(jnp.float32)        # [c, n]
    C = C_ref[...].astype(jnp.float32)        # [c, n]

    dA = dt * A                               # [c]  (<= 0)
    cum = jnp.cumsum(dA)                      # within-chunk cumulative decay
    seg_total = cum[-1]

    # ---- intra-chunk quadratic form ----
    # L[i,j] = exp(cum[i] - cum[j]) for i >= j else 0.  Mask before exp:
    # upper-triangle diffs are positive (overflow -> inf -> NaN grads).
    diff = cum[:, None] - cum[None, :]
    li = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    lj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    Lm = jnp.exp(jnp.where(li >= lj, diff, -1e30))        # [c, c]
    CB = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [c, c]
    xdt = x * dt[:, None]                                  # [c, p]
    y_intra = jax.lax.dot_general(CB * Lm, xdt, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    # ---- contribution of the entering state ----
    state = state_ref[...]                                 # [p, n]
    state_decay = jnp.exp(cum)                             # [c]
    y_inter = jax.lax.dot_general(C, state, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32) \
        * state_decay[:, None]                             # [c, p]

    y_ref[...] = (y_intra + y_inter).astype(y_ref.dtype)

    # ---- advance the carried state ----
    decay_to_end = jnp.exp(seg_total - cum)                # [c]
    # state' = exp(seg_total) * state + sum_i B_i dt_i decay_i x_i^T
    upd = jax.lax.dot_general(xdt * decay_to_end[:, None], B,
                              (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # [p, n]
    state_ref[...] = jnp.exp(seg_total) * state + upd


def ssd_scan_kernel(x, dt, A, Bh, Ch, *, chunk: int = 256,
                    interpret: bool = True):
    """x: [b,s,h,p]; dt: [b,s,h]; A: [h]; Bh, Ch: [b,s,h,n] (pre-broadcast).
    Returns y: [b,s,h,p] (final state not returned — training path)."""
    b, s, h, p = x.shape
    n = Bh.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    # layout: [b, h, s, ...] so the chunk axis is blockable per (b, h)
    xt = jnp.moveaxis(x, 1, 2)                 # [b,h,s,p]
    dtt = jnp.moveaxis(dt, 1, 2)               # [b,h,s]
    Bt = jnp.moveaxis(Bh, 1, 2)                # [b,h,s,n]
    Ct = jnp.moveaxis(Ch, 1, 2)
    grid = (b, h, nc)
    y = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None, chunk, p), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((None, None, chunk), lambda bi, hi, ci: (bi, hi, ci)),
            pl.BlockSpec((h,), lambda bi, hi, ci: (0,)),     # full A in VMEM
            pl.BlockSpec((None, None, chunk, n), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((None, None, chunk, n), lambda bi, hi, ci: (bi, hi, ci, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, chunk, p), lambda bi, hi, ci: (bi, hi, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(xt, dtt, A.astype(jnp.float32), Bt, Ct)
    return jnp.moveaxis(y, 2, 1)               # [b,s,h,p]
