"""Pallas TPU kernels for the compute hot-spots (flash attention, Mamba2 SSD
chunk scan, rmsnorm) with jitted wrappers (ops.py) and pure-jnp oracles
(ref.py).  Validated in interpret mode on CPU; lowered natively on TPU."""
from . import ops, ref
