"""Causal flash attention — Pallas TPU kernel.

Tiling: grid = (batch*heads, num_q_blocks, num_kv_blocks); the kv axis is the
innermost **sequential** grid dimension, so the online-softmax running state
(m, l, acc) lives in VMEM scratch and persists across kv steps.  Block shapes
are MXU-aligned (multiples of 128 on the matmul dims whenever the problem
size allows).  VMEM working set per program:
    q[bq, d] + k[bk, d] + v[bk, d] + acc[bq, d] + m/l[bq]  (fp32 acc)
e.g. bq=bk=128, d=128 -> ~4 * 128*128*4B ≈ 256 KiB — comfortably within the
~16 MiB v5e VMEM with double buffering.

GQA is handled by the ops.py wrapper (kv heads broadcast to q heads before
the call; the kernel itself is MHA).  Validated in interpret mode against
ref.mha_reference (CPU backend has no TPU lowering — see DESIGN.md §5).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, causal: bool, sm_scale: float, block_q: int, block_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    def _body():
        q = q_ref[...].astype(jnp.float32)         # [bq, d]
        k = k_ref[...].astype(jnp.float32)         # [bk, d]
        v = v_ref[...].astype(jnp.float32)         # [bk, d]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * sm_scale                            # [bq, bk]
        if causal:
            rows = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            cols = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new
        l_ref[...] = l_new

    if causal:
        # skip blocks strictly above the diagonal
        pl.when(k_start <= q_start + block_q - 1)(_body)
    else:
        _body()

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention_kernel(q, k, v, *, causal: bool = True,
                           sm_scale: float | None = None,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = True):
    """q, k, v: [BH, S, d] (MHA, heads pre-folded into batch).  -> [BH, S, d]."""
    BH, S, d = q.shape
    assert k.shape == (BH, S, d) and v.shape == (BH, S, d)
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    sm_scale = sm_scale if sm_scale is not None else d ** -0.5
    grid = (BH, S // block_q, S // block_k)

    kernel = functools.partial(_flash_kernel, causal=causal, sm_scale=sm_scale,
                               block_q=block_q, block_k=block_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, d), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((None, block_k, d), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((None, block_k, d), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, d), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, d), q.dtype),
        scratch_shapes=[
            # m, l, acc persist across the sequential kv grid dimension
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
