"""Jitted public wrappers for the Pallas kernels.

On this CPU container the kernels always run in interpret mode (Pallas TPU
lowering requires a TPU backend); on a real TPU deployment set
REPRO_PALLAS_INTERPRET=0.  The wrappers adapt model-layer layouts (GQA head
broadcast, group broadcast) to the kernels' MHA/per-head forms, and validate
the layout contracts (head/group divisibility, unsupported initial state)
with crisp ``ValueError``s — shape checks are static, so they fire at trace
time even under ``jax.jit``.

Tolerance tiers
---------------
Pallas blocked softmax/scan is numerically equivalent but not bit-identical
to the plain-jnp references in ``kernels/ref.py`` (different reduction
order, online-softmax rescaling, per-chunk state passing).  Each kernel
declares its rtol/atol tier vs the reference here; ``TOLERANCE_TIERS`` is
the single source of truth consumed by ``tests/test_kernels.py``,
``core.invariants.KernelConsistencyChecker``, and the kernel-vs-ref gate in
``benchmarks/kernel_ref.py`` / CI.  Tiers are f32 bounds validated
empirically with margin over the deterministic test/fuzz corpus.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention_kernel
from .fused_adam import fused_adam_kernel
from .rmsnorm import rmsnorm_kernel
from .ssd_scan import ssd_scan_kernel

_INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"

# ---------------------------------------------------------------------------
# custom VJPs: Pallas forward, jnp-reference backward.
#
# ``pl.pallas_call`` has no autodiff rule, so to live in the jax.grad training
# hot path each kernel is wrapped in a custom_vjp whose backward pass
# differentiates the matching kernels/ref.py oracle, linearized at the saved
# inputs.  The forward activations are the kernel's (within TOLERANCE_TIERS
# of the oracle); the gradients are the oracle's exact jnp gradients.
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _flash_mha(qf, kf, vf, causal, block_q, block_k):
    return flash_attention_kernel(qf, kf, vf, causal=causal, block_q=block_q,
                                  block_k=block_k, interpret=_INTERPRET)


def _flash_mha_fwd(qf, kf, vf, causal, block_q, block_k):
    return _flash_mha(qf, kf, vf, causal, block_q, block_k), (qf, kf, vf)


def _flash_mha_bwd(causal, block_q, block_k, res, g):
    qf, kf, vf = res
    _, vjp = jax.vjp(
        lambda q, k, v: ref.mha_reference(q, k, v, causal=causal), qf, kf, vf)
    return vjp(g)


_flash_mha.defvjp(_flash_mha_fwd, _flash_mha_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _rmsnorm_p(x, scale, eps):
    return rmsnorm_kernel(x, scale, eps=eps, interpret=_INTERPRET)


def _rmsnorm_fwd(x, scale, eps):
    return _rmsnorm_p(x, scale, eps), (x, scale)


def _rmsnorm_bwd(eps, res, g):
    x, scale = res
    _, vjp = jax.vjp(lambda xx, ss: ref.rmsnorm_reference(xx, ss, eps=eps),
                     x, scale)
    return vjp(g)


_rmsnorm_p.defvjp(_rmsnorm_fwd, _rmsnorm_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def _ssd_p(x, dt, A, Bh, Ch, chunk):
    return ssd_scan_kernel(x, dt, A, Bh, Ch, chunk=chunk,
                           interpret=_INTERPRET)


def _ssd_fwd(x, dt, A, Bh, Ch, chunk):
    return _ssd_p(x, dt, A, Bh, Ch, chunk), (x, dt, A, Bh, Ch)


def _ssd_bwd(chunk, res, g):
    _, vjp = jax.vjp(lambda *a: ref.ssd_reference(*a)[0], *res)
    return vjp(g)


_ssd_p.defvjp(_ssd_fwd, _ssd_bwd)

#: Declared per-kernel f32 tolerance vs the ``kernels/ref.py`` oracle.
TOLERANCE_TIERS = {
    "flash_attention": {"rtol": 1e-4, "atol": 1e-5},
    "rmsnorm": {"rtol": 1e-5, "atol": 1e-6},
    "ssd_scan": {"rtol": 1e-4, "atol": 1e-5},
    "fused_adam": {"rtol": 1e-6, "atol": 1e-7},
}


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True,
                    block_q: int = 128, block_k: int = 128):
    """q: [B,S,H,hd]; k,v: [B,S,Hkv,hd] (GQA broadcast inside). -> [B,S,H,hd]."""
    B, S, H, hd = q.shape
    Hkv = k.shape[2]
    if Hkv <= 0 or H % Hkv != 0:
        raise ValueError(
            f"flash_attention: num_heads H={H} is not a multiple of "
            f"num_kv_heads Hkv={Hkv} — the GQA broadcast repeats each kv "
            f"head H//Hkv times and requires H % Hkv == 0")
    rep = H // Hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    o = _flash_mha(qf, kf, vf, causal, block_q, block_k)
    return o.reshape(B, H, S, hd).transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("eps",))
def rmsnorm(x, scale, *, eps: float = 1e-5):
    return _rmsnorm_p(x, scale, eps)


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(x, dt, A, B, C, *, chunk: int = 256, initial_state=None):
    """Mamba2 SSD, model-layer layout: B, C: [b,s,g,n] (groups).
    Returns (y, final_state=None) matching mamba.ssd_chunked's signature.

    The kernel always scans from a zero state (the training path); a caller
    resuming a chunked scan must use the jnp path — silently ignoring the
    state would return wrong results, so a non-``None`` state raises."""
    if initial_state is not None:
        raise ValueError(
            "ssd_scan: initial_state is not supported by the Pallas kernel "
            "(it always scans from a zero state); pass initial_state=None "
            "or use mamba.ssd_chunked with use_pallas=False for the "
            "resume-from-state (prefill/decode) path")
    b, s, h, p = x.shape
    g = B.shape[2]
    if g <= 0 or h % g != 0:
        raise ValueError(
            f"ssd_scan: num_heads h={h} is not a multiple of ngroups g={g} "
            f"— the group broadcast repeats each B/C group h//g times and "
            f"requires h % g == 0")
    rep = h // g
    Bh = jnp.repeat(B, rep, axis=2)
    Ch = jnp.repeat(C, rep, axis=2)
    y = _ssd_p(x, dt, A, Bh, Ch, chunk)
    return y, None


def fused_adam(grad, master, mu, nu, *, step: int, b1: float = 0.9,
               b2: float = 0.95, eps: float = 1e-8, lr: float = 3e-4,
               weight_decay: float = 0.1):
    """Fused AdamW over flat f32 vectors -> (master, mu, nu).

    Same op sequence as ``optim.adam.adam_update_flat_np`` (the VirtualCluster
    hot-path oracle).  Deliberately NOT jitted: under an enclosing jit XLA may
    contract the mul+add chains into FMAs (the PR 2 finding that blocked the
    fused jnp version); the Pallas body keeps the written op order on TPU and
    stays within TOLERANCE_TIERS["fused_adam"] of the numpy oracle in
    interpret mode.  See kernels/fused_adam.py.
    """
    shapes = {"grad": grad.shape, "master": master.shape,
              "mu": mu.shape, "nu": nu.shape}
    if len({tuple(s) for s in shapes.values()}) != 1:
        raise ValueError(f"fused_adam: mismatched operand shapes {shapes}")
    b1t = 1.0 - b1 ** step
    b2t = 1.0 - b2 ** step
    return fused_adam_kernel(grad, master, mu, nu, b1=b1, b2=b2, eps=eps,
                             lr=lr, weight_decay=weight_decay, b1t=b1t,
                             b2t=b2t, interpret=_INTERPRET)
