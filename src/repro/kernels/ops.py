"""Jitted public wrappers for the Pallas kernels.

On this CPU container the kernels always run in interpret mode (Pallas TPU
lowering requires a TPU backend); on a real TPU deployment set
REPRO_PALLAS_INTERPRET=0.  The wrappers adapt model-layer layouts (GQA head
broadcast, group broadcast) to the kernels' MHA/per-head forms.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_kernel
from .rmsnorm import rmsnorm_kernel
from .ssd_scan import ssd_scan_kernel

_INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True,
                    block_q: int = 128, block_k: int = 128):
    """q: [B,S,H,hd]; k,v: [B,S,Hkv,hd] (GQA broadcast inside). -> [B,S,H,hd]."""
    B, S, H, hd = q.shape
    Hkv = k.shape[2]
    rep = H // Hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    o = flash_attention_kernel(qf, kf, vf, causal=causal, block_q=block_q,
                               block_k=block_k, interpret=_INTERPRET)
    return o.reshape(B, H, S, hd).transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("eps",))
def rmsnorm(x, scale, *, eps: float = 1e-5):
    return rmsnorm_kernel(x, scale, eps=eps, interpret=_INTERPRET)


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(x, dt, A, B, C, *, chunk: int = 256, initial_state=None):
    """Mamba2 SSD, model-layer layout: B, C: [b,s,g,n] (groups).
    Returns (y, final_state=None) matching mamba.ssd_chunked's signature."""
    del initial_state   # training path starts from zero state
    b, s, h, p = x.shape
    g = B.shape[2]
    rep = h // g
    Bh = jnp.repeat(B, rep, axis=2)
    Ch = jnp.repeat(C, rep, axis=2)
    y = ssd_scan_kernel(x, dt, A, Bh, Ch, chunk=chunk, interpret=_INTERPRET)
    return y, None
