"""Kernel-vs-ref comparison cases — one corpus, three consumers.

Each :class:`KernelCase` pairs a Pallas kernel invocation with its
``kernels/ref.py`` (or numpy Adam) oracle on seeded inputs shaped like the
training hot path (GQA head ratio, SSD group broadcast, non-default eps).
``ops.TOLERANCE_TIERS`` declares the acceptance bound per kernel.

Consumers:
* ``core.invariants.KernelConsistencyChecker`` — spot-checks every kernel at
  cluster start before locksteping the pallas/jnp cluster twins;
* ``tests/test_kernels.py`` — tier conformance as a unit test;
* ``benchmarks/kernel_ref.py`` — times both sides and gates CI on the tiers.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from . import ops, ref


@dataclasses.dataclass(frozen=True)
class KernelCase:
    """One comparison: ``run_kernel()`` / ``run_ref()`` -> list of f32 arrays
    (same order), judged under ``ops.TOLERANCE_TIERS[name]``."""
    name: str               # TOLERANCE_TIERS key
    label: str              # unique case id (a kernel can have many cases)
    run_kernel: Callable[[], List[np.ndarray]]
    run_ref: Callable[[], List[np.ndarray]]

    @property
    def tier(self) -> Dict[str, float]:
        return ops.TOLERANCE_TIERS[self.name]


def _np(outs) -> List[np.ndarray]:
    return [np.asarray(o, dtype=np.float32) for o in outs]


def kernel_cases(seed: int = 0) -> List[KernelCase]:
    k = jax.random.key(seed)
    ks = jax.random.split(k, 12)
    cases: List[KernelCase] = []

    # -- flash attention, GQA head ratio, causal + non-causal ---------------
    B, S, H, Hkv, hd = 2, 64, 8, 2, 32
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    kk = jax.random.normal(ks[1], (B, S, Hkv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, hd), jnp.float32)

    def flash_ref(causal):
        rep = H // Hkv
        kf = jnp.repeat(kk, rep, axis=2).transpose(0, 2, 1, 3).reshape(B * H, S, hd)
        vf = jnp.repeat(v, rep, axis=2).transpose(0, 2, 1, 3).reshape(B * H, S, hd)
        qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
        o = ref.mha_reference(qf, kf, vf, causal=causal)
        return _np([o.reshape(B, H, S, hd).transpose(0, 2, 1, 3)])

    for causal in (True, False):
        cases.append(KernelCase(
            "flash_attention",
            f"flash_attention[gqa,{'causal' if causal else 'bidir'}]",
            run_kernel=(lambda c=causal: _np(
                [ops.flash_attention(q, kk, v, causal=c)])),
            run_ref=(lambda c=causal: flash_ref(c))))

    # -- rmsnorm, non-default eps -------------------------------------------
    x = jax.random.normal(ks[3], (4, 16, 64), jnp.float32)
    scale = 1.0 + 0.1 * jax.random.normal(ks[4], (64,), jnp.float32)
    eps = 1e-3
    cases.append(KernelCase(
        "rmsnorm", "rmsnorm[eps=1e-3]",
        run_kernel=lambda: _np([ops.rmsnorm(x, scale, eps=eps)]),
        run_ref=lambda: _np([ref.rmsnorm_reference(x, scale, eps=eps)])))

    # -- ssd scan, group broadcast ------------------------------------------
    b, s, h, p, g, n = 2, 32, 4, 16, 2, 16
    sx = jax.random.normal(ks[5], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[6], (b, s, h), jnp.float32))
    A = -jnp.exp(jax.random.normal(ks[7], (h,), jnp.float32))
    Bm = jax.random.normal(ks[8], (b, s, g, n), jnp.float32)
    Cm = jax.random.normal(ks[9], (b, s, g, n), jnp.float32)

    def ssd_ref():
        rep = h // g
        Bh = jnp.repeat(Bm, rep, axis=2)
        Ch = jnp.repeat(Cm, rep, axis=2)
        y, _ = ref.ssd_reference(sx, dt, A, Bh, Ch)
        return _np([y])

    cases.append(KernelCase(
        "ssd_scan", "ssd_scan[groups]",
        run_kernel=lambda: _np(
            [ops.ssd_scan(sx, dt, A, Bm, Cm, chunk=8)[0]]),
        run_ref=ssd_ref))

    # -- fused adam vs the host-numpy hot-path oracle -----------------------
    from repro.optim.adam import AdamConfig, adam_update_flat_np
    acfg = AdamConfig()
    nvec = 4097                       # not a lane multiple: exercises padding
    rng = np.random.default_rng(seed)
    gvec = rng.standard_normal(nvec).astype(np.float32)
    st = {"master": rng.standard_normal(nvec).astype(np.float32),
          "mu": (rng.standard_normal(nvec) * 0.01).astype(np.float32),
          "nu": np.abs(rng.standard_normal(nvec) * 0.01).astype(np.float32)}
    step = 7

    def adam_kernel():
        m, mu, nu = ops.fused_adam(
            jnp.asarray(gvec), jnp.asarray(st["master"]),
            jnp.asarray(st["mu"]), jnp.asarray(st["nu"]), step=step,
            b1=acfg.b1, b2=acfg.b2, eps=acfg.eps, lr=acfg.lr,
            weight_decay=acfg.weight_decay)
        return _np([m, mu, nu])

    def adam_ref():
        out = adam_update_flat_np(gvec, st, step, acfg)
        return _np([out["master"], out["mu"], out["nu"]])

    cases.append(KernelCase("fused_adam", "fused_adam[n=4097]",
                            run_kernel=adam_kernel, run_ref=adam_ref))
    return cases


def case_row(case: KernelCase) -> Dict:
    """Run one case; returns the comparison row (no timing)."""
    got, want = case.run_kernel(), case.run_ref()
    tier = case.tier
    max_err = max((float(np.max(np.abs(g - w))) if g.size else 0.0)
                  for g, w in zip(got, want))
    within = all(np.allclose(g, w, rtol=tier["rtol"], atol=tier["atol"])
                 for g, w in zip(got, want))
    return {"kernel": case.name, "case": case.label,
            "max_abs_err": max_err, "rtol": tier["rtol"],
            "atol": tier["atol"], "within_tolerance": bool(within)}


def check_kernels(seed: int = 0) -> List[Dict]:
    """All comparison rows for one seed (raise-free; callers gate)."""
    return [case_row(c) for c in kernel_cases(seed)]
