"""Fused AdamW — Pallas TPU kernel with controlled arithmetic order.

PR 2 rejected a jitted fused Adam: XLA contracts the ``b1*mu + (1-b1)*g``
mul+add chains into FMAs, breaking bit-identity with the host-numpy oracle
(``optim.adam.adam_update_flat_np``).  A Pallas kernel controls the
arithmetic order instead: on TPU each jnp op in the kernel body lowers to a
distinct Mosaic VPU op (no cross-statement FMA contraction).  In interpret
mode (this container) the Pallas interpreter still compiles the body, so
the result is within ~1 ulp per op of the numpy oracle rather than
bit-identical — validated against ``optim.adam.adam_update_flat_np`` under
``ops.TOLERANCE_TIERS["fused_adam"]`` (~10x observed margin) in
tests/test_kernels.py and timed by ``benchmarks/kernel_ref.py``.  The
bit-exactness claim is a TPU/Mosaic property to be verified on hardware.

First cut: a bench/oracle kernel, NOT wired into the VirtualCluster hot
path (the host-numpy fused update stays the production path; its bit
identity with the seed is the stronger contract).  The bias-correction
terms ``b1t``/``b2t`` are baked in as compile-time constants, so each
optimizer step traces a fresh kernel — fine for validation, one more reason
it stays off the hot path for now.

Layout: the flat vector is padded to a multiple of 128 lanes and reshaped
[rows, 128]; the grid tiles rows, mirroring kernels/rmsnorm.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_LANES = 128


def _fused_adam_body(g_ref, m_ref, mu_ref, nu_ref, m_out, mu_out, nu_out, *,
                     b1: float, b2: float, eps: float, lr: float,
                     weight_decay: float, b1t: float, b2t: float):
    g = g_ref[...]
    master = m_ref[...]
    # exact op sequence of adam_update_flat_np — do not reassociate
    mu = jnp.float32(b1) * mu_ref[...] + jnp.float32(1.0 - b1) * g
    nu = jnp.float32(b2) * nu_ref[...] + jnp.float32(1.0 - b2) * g * g
    upd = (mu / jnp.float32(b1t)) / (jnp.sqrt(nu / jnp.float32(b2t))
                                     + jnp.float32(eps)) \
        + jnp.float32(weight_decay) * master
    m_out[...] = master - jnp.float32(lr) * upd
    mu_out[...] = mu
    nu_out[...] = nu


def fused_adam_kernel(grad, master, mu, nu, *, b1: float, b2: float,
                      eps: float, lr: float, weight_decay: float,
                      b1t: float, b2t: float, block_rows: int = 256,
                      interpret: bool = True):
    """grad/master/mu/nu: flat f32 [n]. Returns (master, mu, nu), f32 [n]."""
    n = grad.size
    cols = min(_LANES, max(n, 1))
    pad = (-n) % cols

    def prep(v):
        v = jnp.asarray(v, jnp.float32).reshape(-1)
        if pad:
            v = jnp.concatenate([v, jnp.zeros((pad,), jnp.float32)])
        return v.reshape(-1, cols)

    g2, m2, mu2, nu2 = prep(grad), prep(master), prep(mu), prep(nu)
    rows = g2.shape[0]
    block_rows = min(block_rows, rows)
    rpad = (-rows) % block_rows
    if rpad:
        z = jnp.zeros((rpad, cols), jnp.float32)
        g2, m2, mu2, nu2 = (jnp.concatenate([v, z]) for v in (g2, m2, mu2, nu2))
    grid = (g2.shape[0] // block_rows,)
    spec = pl.BlockSpec((block_rows, cols), lambda i: (i, 0))
    shape = jax.ShapeDtypeStruct(g2.shape, jnp.float32)
    out_m, out_mu, out_nu = pl.pallas_call(
        functools.partial(_fused_adam_body, b1=b1, b2=b2, eps=eps, lr=lr,
                          weight_decay=weight_decay, b1t=b1t, b2t=b2t),
        grid=grid,
        in_specs=[spec, spec, spec, spec],
        out_specs=[spec, spec, spec],
        out_shape=[shape, shape, shape],
        interpret=interpret,
    )(g2, m2, mu2, nu2)

    def unprep(v):
        return v.reshape(-1)[:n]

    return unprep(out_m), unprep(out_mu), unprep(out_nu)
