"""nemotron-4-15b [dense] — GQA + squared-ReLU ungated MLP, 256k vocab
(arXiv:2402.16819).  long_500k skipped: full attention."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-15b", family="dense",
        num_layers=32, d_model=6144, num_heads=48, num_kv_heads=8,
        d_ff=24576, vocab_size=256000,
        activation="relu2", rope_theta=10000.0,
        skip_shapes=(("long_500k", "full attention; see DESIGN.md §4"),),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="nemotron-smoke", family="dense",
        num_layers=4, d_model=128, num_heads=8, num_kv_heads=2,
        d_ff=256, vocab_size=512, activation="relu2",
        rope_theta=10000.0, dtype="float32",
    )
