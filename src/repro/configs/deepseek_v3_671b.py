"""deepseek-v3-671b [moe] — MLA + 1 shared + 256 routed top-8 experts
(arXiv:2412.19437).  First 3 layers dense (d_ff 18432); MoE layers use
2048-wide experts.  MTP head omitted (orthogonal to elasticity; DESIGN.md §6).

long_500k skipped: full attention.
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b", family="moe",
        num_layers=61, d_model=7168, num_heads=128, num_kv_heads=128,
        d_ff=18432, vocab_size=129280,
        num_experts=256, top_k=8, num_shared_experts=1, moe_d_ff=2048,
        moe_layer_period=1, first_k_dense=3,
        use_mla=True, q_lora_rank=1536, kv_lora_rank=512,
        qk_rope_dim=64, qk_nope_dim=128, v_head_dim=128,
        skip_shapes=(("long_500k", "full attention (MLA latent cache is "
                      "linear in memory but score compute stays quadratic)"),),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-smoke", family="moe",
        num_layers=4, d_model=128, num_heads=8, num_kv_heads=8,
        d_ff=256, vocab_size=512,
        num_experts=8, top_k=2, num_shared_experts=1, moe_d_ff=64,
        moe_layer_period=1, first_k_dense=1,
        use_mla=True, q_lora_rank=64, kv_lora_rank=32,
        qk_rope_dim=16, qk_nope_dim=16, v_head_dim=16,
        rope_theta=10000.0, dtype="float32",
    )
