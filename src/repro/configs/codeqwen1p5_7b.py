"""codeqwen1.5-7b [dense] — qwen1.5 architecture, MHA (kv = heads)
(hf:Qwen/CodeQwen1.5-7B).  long_500k skipped: full attention.
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="codeqwen1.5-7b", family="dense",
        num_layers=32, d_model=4096, num_heads=32, num_kv_heads=32,
        d_ff=13440, vocab_size=92416,
        rope_theta=1000000.0,
        skip_shapes=(("long_500k", "full attention; see DESIGN.md §4"),),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="codeqwen-smoke", family="dense",
        num_layers=4, d_model=128, num_heads=8, num_kv_heads=8,
        d_ff=256, vocab_size=512, rope_theta=10000.0, dtype="float32",
    )
