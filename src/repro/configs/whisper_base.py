"""whisper-base [audio] — encoder-decoder, conv frontend STUB
(arXiv:2212.04356).  input_specs provides precomputed frame embeddings
[B, 1500, 512].

Notes: decode shapes exercise the decoder with a 32k-position KV cache as
assigned (beyond the model's trained 448 positions — honored as the assigned
shape, noted in DESIGN.md).  long_500k skipped: full attention.
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-base", family="audio",
        num_layers=6, d_model=512, num_heads=8, num_kv_heads=8,
        d_ff=2048, vocab_size=51865,
        is_encdec=True, encoder_layers=6, decoder_layers=6,
        max_source_positions=1500, activation="gelu",
        skip_shapes=(("long_500k", "full attention enc-dec; see DESIGN.md §4"),),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke", family="audio",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=512,
        is_encdec=True, encoder_layers=2, decoder_layers=2,
        max_source_positions=32, activation="gelu", dtype="float32",
    )
