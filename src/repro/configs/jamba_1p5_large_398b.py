"""jamba-1.5-large-398b [hybrid] — Mamba+attention 1:7 interleave + MoE 16e
top-2 every other layer (arXiv:2403.19887).

Family adaptation noted in DESIGN.md: Jamba uses Mamba-1 SSM blocks; our
hybrid substrate instantiates Mamba-2 SSD blocks (the TPU-native matmul-rich
formulation) with matched d_state/width.  Attention layers are 1 in 8
(offset 4); MoE replaces the MLP on every second layer.

long_500k RUNS: the decode state is dominated by the SSM layers (O(1)); only
9 of 72 layers hold 524k KV.
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b", family="hybrid",
        num_layers=72, d_model=8192, num_heads=64, num_kv_heads=8,
        d_ff=24576, vocab_size=65536,
        num_experts=16, top_k=2, moe_d_ff=24576, moe_layer_period=2,
        attn_period=8, attn_layer_offset=4,
        ssm_state=128, ssm_headdim=64, ssm_expand=2, ssm_chunk=256,
        ssm_ngroups=1, conv_kernel=4,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="jamba-smoke", family="hybrid",
        num_layers=8, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=512,
        num_experts=4, top_k=2, moe_d_ff=128, moe_layer_period=2,
        attn_period=8, attn_layer_offset=4,
        ssm_state=16, ssm_headdim=16, ssm_expand=2, ssm_chunk=8,
        ssm_ngroups=1, conv_kernel=4, rope_theta=10000.0, dtype="float32",
    )
