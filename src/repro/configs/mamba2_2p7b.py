"""mamba2-2.7b [ssm] — SSD state-space duality (arXiv:2405.21060).

Attention-free: decode state is O(1) in sequence length, so ALL four shapes
run, including long_500k (the sub-quadratic cell).
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-2.7b", family="ssm",
        num_layers=64, d_model=2560, num_heads=0, num_kv_heads=0,
        d_ff=0, vocab_size=50280,
        ssm_state=128, ssm_headdim=64, ssm_expand=2, ssm_chunk=256,
        ssm_ngroups=1, conv_kernel=4,
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-2.7b-smoke", family="ssm",
        num_layers=4, d_model=64, num_heads=0, num_kv_heads=0,
        d_ff=0, vocab_size=512,
        ssm_state=16, ssm_headdim=16, ssm_expand=2, ssm_chunk=8,
        ssm_ngroups=1, conv_kernel=4, tie_embeddings=True, dtype="float32",
    )
