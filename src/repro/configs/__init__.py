"""Assigned-architecture configs.  ``get_config(arch_id)`` returns the exact
published config; ``get_smoke_config(arch_id)`` a reduced same-family config
for CPU smoke tests."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

ARCH_IDS: List[str] = [
    "internvl2_76b",
    "mamba2_2p7b",
    "llama4_scout_17b_a16e",
    "deepseek_v3_671b",
    "jamba_1p5_large_398b",
    "codeqwen1p5_7b",
    "llama3_405b",
    "deepseek_67b",
    "nemotron_4_15b",
    "whisper_base",
]

_ALIASES = {
    "internvl2-76b": "internvl2_76b",
    "mamba2-2.7b": "mamba2_2p7b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "jamba-1.5-large-398b": "jamba_1p5_large_398b",
    "codeqwen1.5-7b": "codeqwen1p5_7b",
    "llama3-405b": "llama3_405b",
    "deepseek-67b": "deepseek_67b",
    "nemotron-4-15b": "nemotron_4_15b",
    "whisper-base": "whisper_base",
}


def _module(arch_id: str):
    arch_id = _ALIASES.get(arch_id, arch_id)
    return importlib.import_module(f"repro.configs.{arch_id}")


def get_config(arch_id: str) -> ModelConfig:
    return _module(arch_id).config()


def get_smoke_config(arch_id: str) -> ModelConfig:
    return _module(arch_id).smoke_config()


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
