"""deepseek-67b [dense] — llama-architecture (arXiv:2401.02954).
long_500k skipped: full attention."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-67b", family="dense",
        num_layers=95, d_model=8192, num_heads=64, num_kv_heads=8,
        d_ff=22016, vocab_size=102400,
        rope_theta=10000.0,
        skip_shapes=(("long_500k", "full attention; see DESIGN.md §4"),),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-67b-smoke", family="dense",
        num_layers=4, d_model=128, num_heads=8, num_kv_heads=2,
        d_ff=256, vocab_size=512, rope_theta=10000.0, dtype="float32",
    )
