"""llama4-scout-17b-a16e [moe] — 16 experts, top-1 routing + shared expert,
early fusion (hf:meta-llama/Llama-4-Scout-17B-16E).

long_500k skipped: full attention (iRoPE chunking not part of the assigned
config).  MoE on every layer; EP shards the expert dim over `model`.
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e", family="moe",
        num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
        d_ff=8192, vocab_size=202048,
        num_experts=16, top_k=1, num_shared_experts=1, moe_d_ff=8192,
        moe_layer_period=1,
        skip_shapes=(("long_500k", "full attention; see DESIGN.md §4"),),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-smoke", family="moe",
        num_layers=4, d_model=128, num_heads=8, num_kv_heads=2,
        d_ff=256, vocab_size=512,
        num_experts=4, top_k=1, num_shared_experts=1, moe_d_ff=256,
        moe_layer_period=1, rope_theta=10000.0, dtype="float32",
    )
