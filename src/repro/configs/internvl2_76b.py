"""internvl2-76b [vlm] — InternViT + InternLM2 backbone (arXiv:2404.16821).

Backbone-only per assignment: the vision frontend is a STUB; input_specs
provides precomputed patch embeddings ([B, 256, d]) prepended to tokens.
long_500k skipped: pure full attention (see DESIGN.md §4).
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-76b", family="vlm",
        num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
        d_ff=28672, vocab_size=128256,
        frontend_embeds=256,
        skip_shapes=(("long_500k", "pure full attention; 524k KV quadratic "
                      "cost unsupportable without an approximation the paper "
                      "does not claim"),),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-76b-smoke", family="vlm",
        num_layers=4, d_model=128, num_heads=8, num_kv_heads=2,
        d_ff=256, vocab_size=512, frontend_embeds=8,
        rope_theta=10000.0, dtype="float32",
    )
