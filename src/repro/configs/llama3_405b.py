"""llama3-405b [dense] — GQA, 128k vocab (arXiv:2407.21783).
long_500k skipped: full attention."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama3-405b", family="dense",
        num_layers=126, d_model=16384, num_heads=128, num_kv_heads=8,
        d_ff=53248, vocab_size=128256,
        rope_theta=500000.0,
        skip_shapes=(("long_500k", "full attention; see DESIGN.md §4"),),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama3-405b-smoke", family="dense",
        num_layers=4, d_model=128, num_heads=8, num_kv_heads=2,
        d_ff=256, vocab_size=512, rope_theta=10000.0, dtype="float32",
    )
