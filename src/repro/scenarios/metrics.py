"""Scenario metrics: per-step records, itemized MTTR, JSON artifacts.

Every scenario run — cluster-mode or analytic — funnels through one
:class:`MetricsCollector` so artifacts share a schema:

```
{"scenario": {...}, "mode": "cluster"|"analytic", "workload": {...},
 "steps":      [{"step": 0, ...}, ...],
 "recoveries": [{"step": 3, "kind": "fail_stop", "ranks": [2],
                 "mttr": {"detect": .., "plan": .., "communicator": ..,
                          "remap": .., "migration": .., "total": ..}, ...}],
 "summary": {...}}
```

Cluster-mode step records carry loss / simulated step time / throughput /
surviving DP width (convergence-consistency material); analytic records carry
per-interval relative throughput and decision metadata.  Records are plain
dicts built deterministically from the trace: identical traces produce
identical *step* records (tested in ``tests/test_scenarios.py``).  The only
intentionally non-replayable fields are measured wall clocks — the planner's
``plan`` seconds inside a recovery record's MTTR itemization (folded into
``total``) and the analytic runner's ``decide_wall_seconds``.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Dict, List, Optional

from repro.core.events import ElasticEvent


@dataclasses.dataclass
class ScenarioResult:
    scenario: Dict
    mode: str
    workload: Dict
    steps: List[Dict]
    recoveries: List[Dict]
    summary: Dict

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(dataclasses.asdict(self), indent=indent,
                          sort_keys=True, default=float)

    def write(self, out_dir) -> Path:
        out = Path(out_dir)
        out.mkdir(parents=True, exist_ok=True)
        path = out / f"{self.scenario['name']}.json"
        path.write_text(self.to_json())
        return path

    @property
    def mttr_total(self) -> float:
        return sum(r["mttr"].get("total", 0.0) for r in self.recoveries)


class MetricsCollector:
    def __init__(self):
        self.steps: List[Dict] = []
        self.recoveries: List[Dict] = []

    def record_step(self, step: int, **fields):
        self.steps.append({"step": step, **fields})

    def record_recovery(self, step: int, event: ElasticEvent,
                        mttr: Dict[str, float], **extra):
        self.recoveries.append({
            "step": step, "kind": event.kind.value,
            "ranks": list(event.ranks), "event": event.describe(),
            "mttr": dict(mttr), **extra})

    def result(self, scenario, mode: str, workload: Dict,
               summary: Optional[Dict] = None) -> ScenarioResult:
        return ScenarioResult(scenario=scenario.describe(), mode=mode,
                              workload=workload, steps=list(self.steps),
                              recoveries=list(self.recoveries),
                              summary=dict(summary or {}))
