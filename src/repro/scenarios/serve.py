"""ServeScenarioRunner: capacity traces replayed against the serving engine.

The third execution mode of the scenario engine (after cluster-numeric and
analytic-policy): the SAME declarative :class:`~repro.scenarios.spec.Scenario`
traces — including ``Scenario.from_capacity_trace`` spot replays, whose
"steps" are wall-clock seconds — drive a
:class:`~repro.serving.engine.ServingEngine` under a deterministic request
stream.  Rank-addressed trace events map onto serving replicas via
``ranks_per_replica`` (capacity traces built for a dp×pp training grid treat
one node = ``pp`` ranks = one serving replica), so the exact traces the
training benchmarks replay exercise the inference tier too.

Artifacts share the :class:`~repro.scenarios.metrics.MetricsCollector`
schema: per-boundary step records (queue depth, active slots, alive
replicas), per-event recovery records (migrated / rebuilt / dropped, KV
bytes moved, stall charged as MTTR), and a latency/goodput summary — the
material for ``BENCH_serve.json``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from .metrics import MetricsCollector, ScenarioResult
from .spec import Scenario


@dataclasses.dataclass(frozen=True)
class ServeWorkload:
    """A serving-tier workload: model family (reduced config), replica
    fleet shape, and a deterministic open-loop request stream."""
    family: str = "dense"
    num_layers: int = 2
    n_replicas: int = 4
    slots_per_replica: int = 6
    max_len: int = 48
    prompt_len: int = 16
    max_new_tokens: int = 16
    request_rate: float = 0.5          # requests / simulated second
    seed: int = 0
    mode: str = "synthetic"            # "synthetic" | "numeric"
    ranks_per_replica: int = 2         # capacity-trace node = pp ranks
    sampler_method: str = "greedy"
    sampler_top_k: int = 0
    sampler_temperature: float = 1.0
    slo_ttft: float = 3.0
    slo_per_token: float = 0.25

    def make_engine(self, policy=None):
        from repro.models import registry as R
        from repro.serving import (SLO, SamplerConfig, ServingEngine)
        cfg = R.tiny_config(self.family, num_layers=self.num_layers,
                            dropout_rate=0.0)
        sampler = SamplerConfig(method=self.sampler_method,
                                top_k=self.sampler_top_k,
                                temperature=self.sampler_temperature,
                                seed=self.seed)
        return ServingEngine(
            cfg, n_replicas=self.n_replicas,
            slots_per_replica=self.slots_per_replica, max_len=self.max_len,
            mode=self.mode, seed=self.seed, sampler=sampler,
            slo=SLO(ttft=self.slo_ttft, per_token=self.slo_per_token),
            policy=policy, ranks_per_replica=self.ranks_per_replica)

    def describe(self) -> Dict:
        return dataclasses.asdict(self)


class ServeScenarioRunner:
    """Serving mode: scenario events against a live ServingEngine."""

    def __init__(self, scenario: Scenario, workload: ServeWorkload,
                 policy=None, time_scale: float = 1.0):
        self.scenario = scenario
        self.workload = workload
        self.policy = policy
        self.time_scale = time_scale

    def run(self) -> ScenarioResult:
        from repro.serving import poisson_arrivals
        w = self.workload
        m = MetricsCollector()
        engine = w.make_engine(self.policy)
        horizon = self.scenario.horizon * self.time_scale
        cfg = engine.cfg
        frames_shape = ((16, cfg.d_model) if cfg.is_encdec else None)
        for req in poisson_arrivals(
                w.request_rate / self.time_scale, horizon,
                prompt_len=w.prompt_len, max_new_tokens=w.max_new_tokens,
                vocab_size=cfg.vocab_size, seed=w.seed,
                frames_shape=frames_shape):
            engine.submit(req)

        for t in self.scenario.event_steps:
            engine.run_until(t * self.time_scale)
            for ev in self.scenario.events_at(t):
                stats = engine.apply_event(ev)
                m.record_recovery(
                    t, ev,
                    {"migration": stats["stall_seconds"],
                     "total": stats["stall_seconds"]},
                    serving={k: stats[k] for k in
                             ("replicas", "policy", "migrated", "rebuilt",
                              "dropped", "kv_bytes_moved")})
            m.record_step(t, clock=engine.clock, queued=engine.n_queued,
                          active=engine.n_active,
                          alive_replicas=len(engine.replicas),
                          completed=engine.summary()["completed"])
        engine.run_until(horizon)

        summary = engine.summary()
        summary["horizon_seconds"] = horizon
        summary["drops_total"] = summary["dropped"]
        summary["agent_detected"] = [e.describe() for e in engine.detected]
        res = m.result(self.scenario, "serving", w.describe(), summary)
        return res


def run_serve_scenario(scenario: Scenario, workload: ServeWorkload,
                       policy=None, time_scale: float = 1.0) -> ScenarioResult:
    return ServeScenarioRunner(scenario, workload, policy=policy,
                               time_scale=time_scale).run()
