"""Seeded generator of *legal* adversarial elastic traces (the fuzzer).

The paper's claim is universally quantified — *every* legal elastic event
sequence preserves the four guarantees (§4) — so hand-picked scenario
builders can never close the argument.  This module draws random traces from
composable :class:`EventStrategy` combinators (fail-stop bursts, correlated
domain bursts, rejoins, cascading fail-slow, DVFS setpoints, directed
migrations, shrink-regrow interleavings) over randomized workload shapes
(dp x pp x model family), constrained to stay *legal*:

* never kill a stage's last surviving replica (training would be
  unrecoverable — that is outside the paper's claim);
* rejoin (SCALE_OUT) only currently-dead ranks, shrink only live ranks,
  no duplicate ranks within one burst (``spec.validate_event_legality``);
* bounded concurrent events per step and per trace.

Everything is derived from a single integer seed: ``make_analytic_case(s)``
/ ``make_cluster_case(s)`` rebuild the exact workload + trace, so a CI
failure is reproducible with one command (``FuzzCase.repro()``).
``run_case`` attaches the invariant checkers from ``core.invariants`` and
decorates any violation with that command; ``shrink_case`` greedily deletes
events (re-checking legality) to hand back a minimal failing trace.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.cost_model import HardwareSpec
from repro.core.events import ElasticEvent, EventKind, burst
from repro.core.invariants import (InvariantViolation,
                                   default_analytic_checkers,
                                   default_cluster_checkers)

from .spec import (AnalyticWorkload, ClusterWorkload, Scenario,
                   validate_event_legality)


# ---------------------------------------------------------------------------
# trace state + legality
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class TraceState:
    """Liveness bookkeeping threaded through the strategies while a trace is
    being drawn.  ``reserved`` ranks have a scheduled future rejoin and may
    not be touched by any other strategy; a dead rank stays counted as dead
    here even past its rejoin step (conservative: the generator under-counts
    widths, so the never-kill-the-last-replica rule can only over-hold)."""
    dp: int
    pp: int
    horizon: int
    dead: set = dataclasses.field(default_factory=set)
    reserved: set = dataclasses.field(default_factory=set)

    def stage_of(self, rank: int) -> int:
        return rank % self.pp

    def width(self, p: int) -> int:
        return self.dp - sum(1 for r in self.dead if r % self.pp == p)

    def live_ranks(self) -> List[int]:
        return [r for r in range(self.dp * self.pp)
                if r not in self.dead and r not in self.reserved]

    def killable(self, extra_dead: set = frozenset()) -> List[int]:
        """Live, unreserved ranks whose removal keeps their stage >= 1 wide
        (``extra_dead``: ranks already picked for the same burst)."""
        out = []
        for r in self.live_ranks():
            if r in extra_dead:
                continue
            p = self.stage_of(r)
            w = self.width(p) - sum(1 for x in extra_dead if x % self.pp == p)
            if w >= 2:
                out.append(r)
        return out


def trace_is_legal(events: Sequence[ElasticEvent], dp: int, pp: int) -> bool:
    """Predicate form of trace legality (used by the shrinker, which must not
    raise): event-sequence rules from ``validate_event_legality`` plus the
    grid rules — ranks inside the dp x pp grid and every stage keeps >= 1
    live replica after every liveness event."""
    evs = sorted(events, key=lambda e: e.step)
    try:
        validate_event_legality(evs, "candidate")
    except ValueError:
        return False
    width = [dp] * pp
    for e in evs:
        if any(r >= dp * pp for r in e.ranks):
            return False
        if e.is_shrink:
            for r in e.ranks:
                width[r % pp] -= 1
            if min(width) < 1:
                return False
        elif e.is_grow:
            for r in e.ranks:
                width[r % pp] += 1
    return True


# ---------------------------------------------------------------------------
# strategy combinators
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class EventStrategy:
    """One adversarial move: ``fn(rnd, state, step)`` either emits a list of
    legal events (mutating ``state``'s liveness books) or returns ``None``
    when inapplicable at this point of the trace."""
    name: str
    fn: Callable[[random.Random, TraceState, int],
                 Optional[List[ElasticEvent]]]
    weight: float = 1.0


def failstop_burst(max_ranks: int = 3) -> EventStrategy:
    """Concurrent multi-rank failure; 30% of draws arrive as scheduler
    SCALE_IN preemptions instead of FAIL_STOPs (same liveness effect)."""
    def fn(rnd, st, step):
        picked: set = set()
        for _ in range(rnd.randint(1, max_ranks)):
            pool = st.killable(picked)
            if not pool:
                break
            picked.add(rnd.choice(pool))
        if not picked:
            return None
        st.dead |= picked
        kind = EventKind.SCALE_IN if rnd.random() < 0.3 else EventKind.FAIL_STOP
        return [burst(kind, step, tuple(picked))]
    return EventStrategy("failstop_burst", fn, weight=2.0)


def rejoin(max_ranks: int = 4) -> EventStrategy:
    """SCALE_OUT of a random subset of the currently-dead ranks."""
    def fn(rnd, st, step):
        pool = sorted(st.dead - st.reserved)
        if not pool:
            return None
        k = rnd.randint(1, min(max_ranks, len(pool)))
        picked = rnd.sample(pool, k)
        st.dead -= set(picked)
        return [burst(EventKind.SCALE_OUT, step, tuple(picked))]
    return EventStrategy("rejoin", fn)


def fail_slow(factors: Tuple[float, ...] = (1.5, 2.0, 3.0)) -> EventStrategy:
    """A live rank starts straggling (repeats on the same rank are legal —
    that is the cascading-degradation shape)."""
    def fn(rnd, st, step):
        pool = st.live_ranks()
        if not pool:
            return None
        return [ElasticEvent(EventKind.FAIL_SLOW, step, (rnd.choice(pool),),
                             slow_factor=rnd.choice(factors))]
    return EventStrategy("fail_slow", fn)


def dvfs_set(freqs: Tuple[float, ...] = (1.0, 1.05, 1.1, 1.178)
             ) -> EventStrategy:
    """Frequency setpoint on a random subset of live ranks (straggler
    absorption / power capping)."""
    def fn(rnd, st, step):
        pool = st.live_ranks()
        if not pool:
            return None
        picked = rnd.sample(pool, rnd.randint(1, min(3, len(pool))))
        return [burst(EventKind.DVFS_SET, step, tuple(picked),
                      freq=rnd.choice(freqs))]
    return EventStrategy("dvfs_set", fn)


def shrink_regrow(max_gap: int = 3) -> EventStrategy:
    """Kill one rank now and schedule its rejoin a few steps later; the rank
    is *reserved* so no other strategy touches it in between (the
    interleaving shape that historically broke naive liveness tracking)."""
    def fn(rnd, st, step):
        if step >= st.horizon - 1:
            return None                       # no room for the rejoin
        pool = st.killable()
        if not pool:
            return None
        r = rnd.choice(pool)
        back = min(step + rnd.randint(1, max_gap), st.horizon - 1)
        st.dead.add(r)
        st.reserved.add(r)
        return [ElasticEvent(EventKind.SCALE_IN, step, (r,)),
                ElasticEvent(EventKind.SCALE_OUT, back, (r,))]
    return EventStrategy("shrink_regrow", fn)


def preempt(max_ranks: int = 2,
            deadlines: Tuple[float, ...] = (0.05, 2.0, 120.0)
            ) -> EventStrategy:
    """Preemption *notice*: liveness-wise a shrink, but the executor drains
    the ranks proactively inside the (randomly short or generous) deadline
    window instead of paying the detection + full-stall path."""
    def fn(rnd, st, step):
        picked: set = set()
        for _ in range(rnd.randint(1, max_ranks)):
            pool = st.killable(picked)
            if not pool:
                break
            picked.add(rnd.choice(pool))
        if not picked:
            return None
        st.dead |= picked
        return [burst(EventKind.PREEMPT_NOTICE, step, tuple(picked),
                      deadline=rnd.choice(deadlines))]
    return EventStrategy("preempt", fn, weight=0.8)


def migrate(num_layers: int, pp: int) -> EventStrategy:
    """Directed layer migration between two distinct stages (analytic-only:
    the numeric executor treats MIGRATE as a planner-internal action)."""
    def fn(rnd, st, step):
        if pp < 2:
            return None
        src = rnd.randrange(pp)
        dst = rnd.choice([p for p in range(pp) if p != src])
        per, rem = num_layers // pp, num_layers % pp
        lo = src * per + min(src, rem)
        n = per + (1 if src < rem else 0)
        layers = sorted(rnd.sample(range(lo, lo + n), min(rnd.randint(1, 3), n)))
        return [ElasticEvent(EventKind.MIGRATE, step, (), layers=tuple(layers),
                             src_stage=src, dst_stage=dst)]
    return EventStrategy("migrate", fn, weight=0.5)


def domain_burst(domains) -> EventStrategy:
    """Correlated whole-domain (rack/pod) failure with a later rejoin of the
    same block — the shape i.i.d. rank sampling never produces."""
    def fn(rnd, st, step):
        if domains is None or step >= st.horizon - 1:
            return None
        order = list(range(domains.n_domains))
        rnd.shuffle(order)
        for d in order:
            ranks = {int(r) for r in domains.ranks_of([d])}
            if ranks & (st.dead | st.reserved):
                continue
            if all(st.width(p) - sum(1 for r in ranks if r % st.pp == p) >= 1
                   for p in range(st.pp)):
                back = min(step + rnd.randint(1, 3), st.horizon - 1)
                st.dead |= ranks
                st.reserved |= ranks
                return [burst(EventKind.FAIL_STOP, step, tuple(ranks),
                              detail=f"domain {d} down"),
                        burst(EventKind.SCALE_OUT, back, tuple(ranks),
                              detail=f"domain {d} rejoin")]
        return None
    return EventStrategy("domain_burst", fn, weight=0.7)


def draw_trace(rnd: random.Random, *, dp: int, pp: int, horizon: int,
               strategies: Sequence[EventStrategy],
               max_events: Optional[int] = None,
               p_event: float = 0.6) -> List[ElasticEvent]:
    """Walk the horizon; at each step maybe fire one weighted strategy."""
    st = TraceState(dp=dp, pp=pp, horizon=horizon)
    weights = [s.weight for s in strategies]
    events: List[ElasticEvent] = []
    for step in range(horizon):
        if max_events is not None and len(events) >= max_events:
            break
        if rnd.random() >= p_event:
            continue
        strat = rnd.choices(list(strategies), weights=weights)[0]
        got = strat.fn(rnd, st, step)
        if got:
            events.extend(got)
    return events


# ---------------------------------------------------------------------------
# randomized workloads
# ---------------------------------------------------------------------------
def draw_analytic_workload(rnd: random.Random) -> AnalyticWorkload:
    from repro.models import registry as R
    pp = rnd.choice((1, 2, 2, 3, 4))
    dp = rnd.randint(2, 6)
    family = rnd.choice(("dense", "moe", "ssm"))
    num_layers = pp * rnd.randint(2, 4)
    mbs = rnd.choice((1, 2))
    num_micro = rnd.randint(2, 4)
    return AnalyticWorkload(
        cfg=R.tiny_config(family, num_layers=num_layers),
        dp=dp, pp=pp, mbs=mbs, global_batch=mbs * dp * num_micro,
        seq=rnd.choice((64, 128, 256)), hw=HardwareSpec(),
        domain_size=pp if rnd.random() < 0.5 else None)


def draw_cluster_workload(rnd: random.Random) -> ClusterWorkload:
    """Numeric workloads stay tiny: every VirtualCluster instance jit-compiles
    its own step functions, so the fuzz budget goes to *traces*, not params."""
    pp = rnd.choice((1, 2))
    dp = rnd.randint(2, 3)
    num_micro = rnd.choice((1, 2))
    per_rank = rnd.choice((1, 2))
    return ClusterWorkload(
        family="dense", num_layers=2 * pp,
        dropout_rate=rnd.choice((0.0, 0.1)), dp=dp, pp=pp,
        global_batch=dp * num_micro * per_rank, num_micro=num_micro,
        seq_len=8, seed=rnd.randrange(10 ** 6), rng_mode="reshard")


def default_analytic_strategies(w: AnalyticWorkload) -> List[EventStrategy]:
    return [failstop_burst(), rejoin(), fail_slow(), dvfs_set(),
            shrink_regrow(), migrate(w.cfg.num_layers, w.pp),
            domain_burst(w.domains), preempt()]


def default_cluster_strategies() -> List[EventStrategy]:
    """No MIGRATE (numeric executor rejects direct injection) and no domain
    bursts (cluster grids are too small for whole-domain kills)."""
    return [failstop_burst(max_ranks=2), rejoin(max_ranks=2),
            fail_slow(factors=(1.5, 2.0)), dvfs_set(), shrink_regrow(),
            preempt(max_ranks=1)]


# ---------------------------------------------------------------------------
# cases
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class FuzzCase:
    """A fully-reproducible fuzz input: seed -> (workload, trace)."""
    seed: int
    mode: str                   # "analytic" | "cluster"
    scenario: Scenario
    workload: object            # AnalyticWorkload | ClusterWorkload

    def repro(self, policy: Optional[str] = None) -> str:
        cmd = (f"PYTHONPATH=src python -m benchmarks.fuzz_soak "
               f"--mode {self.mode} --seed {self.seed}")
        if policy:
            cmd += f" --policy {policy}"
        return cmd


def make_analytic_case(seed: int) -> FuzzCase:
    rnd = random.Random(f"analytic-{seed}")
    w = draw_analytic_workload(rnd)
    horizon = rnd.randint(6, 12)
    events = draw_trace(rnd, dp=w.dp, pp=w.pp, horizon=horizon,
                        strategies=default_analytic_strategies(w))
    return FuzzCase(seed, "analytic",
                    Scenario(f"fuzz-analytic-{seed}", tuple(events), horizon),
                    w)


def make_cluster_case(seed: int) -> FuzzCase:
    rnd = random.Random(f"cluster-{seed}")
    w = draw_cluster_workload(rnd)
    horizon = rnd.randint(3, 5)
    events = draw_trace(rnd, dp=w.dp, pp=w.pp, horizon=horizon,
                        strategies=default_cluster_strategies(),
                        max_events=3, p_event=0.7)
    return FuzzCase(seed, "cluster",
                    Scenario(f"fuzz-cluster-{seed}", tuple(events), horizon),
                    w)


def make_pallas_case(seed: int) -> FuzzCase:
    """Pallas-mode cluster fuzzing: same trace grammar with the kernels in
    the training hot path.  ``run_case`` sees ``workload.use_pallas`` and
    swaps in the tolerance-tier :class:`KernelConsistencyChecker` for the
    bit-exact parameter twin.  Interpret-mode kernels are slow, so traces
    are shorter than plain cluster mode."""
    rnd = random.Random(f"pallas-{seed}")
    w = dataclasses.replace(draw_cluster_workload(rnd),
                            family=rnd.choice(("dense", "ssm")),
                            use_pallas=True)
    horizon = rnd.randint(2, 3)
    events = draw_trace(rnd, dp=w.dp, pp=w.pp, horizon=horizon,
                        strategies=default_cluster_strategies(),
                        max_events=2, p_event=0.7)
    return FuzzCase(seed, "pallas",
                    Scenario(f"fuzz-pallas-{seed}", tuple(events), horizon),
                    w)


def make_case(mode: str, seed: int):
    if mode == "analytic":
        return make_analytic_case(seed)
    if mode == "cluster":
        return make_cluster_case(seed)
    if mode == "pallas":
        return make_pallas_case(seed)
    if mode == "chaos":
        return make_chaos_case(seed)
    raise ValueError(f"unknown fuzz mode {mode!r}")


POLICY_NAMES = ("elaswave", "torchft", "oobleck")


def make_policy(name: str, hw: Optional[HardwareSpec] = None):
    """Fresh policy per run — OobleckPolicy caches templates keyed by config
    identity, so instances must not leak across workloads."""
    from repro.core.policies import (ElasWavePolicy, OobleckPolicy,
                                     TorchFTPolicy)
    if name == "elaswave":
        return ElasWavePolicy(hw=hw)
    if name == "torchft":
        return TorchFTPolicy()
    if name == "oobleck":
        return OobleckPolicy(hw=hw)
    raise ValueError(f"unknown policy {name!r}")


def run_case(case: FuzzCase, policy: Optional[str] = None, checkers=None,
             **runner_kw):
    """Run one fuzz case with the default invariant checkers attached.

    An :class:`InvariantViolation` is re-raised with the fuzz seed and the
    one-line repro command appended, so a red CI log is actionable as-is.
    """
    from .runner import AnalyticScenarioRunner, ClusterScenarioRunner
    try:
        if case.mode == "analytic":
            pol = make_policy(policy or "elaswave", hw=case.workload.hw)
            cks = (default_analytic_checkers() if checkers is None
                   else checkers)
            return AnalyticScenarioRunner(case.scenario, case.workload, pol,
                                          checkers=cks, **runner_kw).run()
        cks = (default_cluster_checkers(
                   use_pallas=getattr(case.workload, "use_pallas", False))
               if checkers is None else checkers)
        return ClusterScenarioRunner(case.scenario, case.workload,
                                     checkers=cks, **runner_kw).run()
    except InvariantViolation as e:
        raise InvariantViolation(
            f"{e}\n  fuzz seed {case.seed} ({case.mode}); reproduce with:\n"
            f"  {case.repro(policy)}") from e


def shrink_case(case: FuzzCase,
                fails: Callable[[FuzzCase], bool]) -> FuzzCase:
    """Greedy event-deletion minimization: repeatedly drop any single event
    whose removal keeps the trace legal AND still failing.  Terminates when
    no single deletion reproduces the failure (1-minimal trace)."""
    current = case
    progress = True
    while progress:
        progress = False
        evs = list(current.scenario.events)
        for i in range(len(evs)):
            cand_events = evs[:i] + evs[i + 1:]
            w = current.workload
            if not trace_is_legal(cand_events, w.dp, w.pp):
                continue
            try:
                cand_scn = Scenario(current.scenario.name,
                                    tuple(cand_events),
                                    current.scenario.horizon)
            except ValueError:
                continue
            cand = dataclasses.replace(current, scenario=cand_scn)
            try:
                still_fails = fails(cand)
            except Exception:
                still_fails = True          # any crash counts as failing
            if still_fails:
                current = cand
                progress = True
                break
    return current


# ---------------------------------------------------------------------------
# detection chaos: the four guarantees under IMPERFECT detection
# ---------------------------------------------------------------------------
# The trace fuzzer above injects *perfectly detected* events.  The chaos
# layer instead perturbs the detection plane itself — probes are dropped,
# delayed, duplicated, reordered, and flapped; snapshot shards are silently
# corrupted — and lets the ElasticController decide what happened.  The
# checked property set grows by one: on top of the four paper invariants, a
# false-positive eviction must never be PERMANENT (the falsely-evicted rank
# resurrects through the normal SCALE_OUT path once its heartbeats reappear)
# and every truly-dead rank must still be evicted.
#
# Three chaos classes (drawn from the seed):
#
# * ``flap_only`` — no real failures at all; every eviction the controller
#   commits is by definition a false positive and must be healed by the end
#   of the settle window.  Runs under the FULL four-checker stack (the
#   bit-exact parameter twin receives the identical event sequence, so even
#   a false eviction + rejoin must keep state bit-identical).
# * ``mixed``    — real kills and preemption notices interleaved with probe
#   chaos; the controller must evict the dead, drain the doomed, and heal
#   everything else.
# * ``corrupt``  — snapshot shards are bit-flipped at the recovery read
#   point: drains re-derive bit-for-bit from the departing device;
#   detected failures degrade to the tolerance-tier master rebuild
#   (``degraded`` recorded).  The parameter twin is dropped (a rebuilt
#   shard legitimately differs by its zeroed Adam moments); dataflow / RNG /
#   MTTR invariants still run.

CHAOS_CLASSES = ("flap_only", "mixed", "corrupt")


@dataclasses.dataclass(frozen=True)
class ChaosAction:
    """One ground-truth action of a chaos schedule (what REALLY happened,
    regardless of what the perturbed probes make it look like)."""
    step: int
    kind: str           # kill | notice | mem | corrupt_kill | corrupt_drain
    rank: int
    deadline: float = 120.0
    component: str = "master"
    value: float = 0.0  # mem: reported used fraction


@dataclasses.dataclass
class ChaosCase:
    """A fully-reproducible detection-chaos input: seed -> (workload,
    ground-truth schedule, chaos class).  Probe perturbations are drawn at
    run time from a seed-derived stream, so a seed is a complete repro."""
    seed: int
    chaos_class: str
    workload: ClusterWorkload
    actions: Tuple[ChaosAction, ...]
    horizon: int
    mode: str = "chaos"

    @property
    def scenario(self) -> Scenario:     # for artifact/shrink tooling parity
        return Scenario(f"fuzz-chaos-{self.seed}", (), self.horizon,
                        description=f"chaos class {self.chaos_class}")

    def repro(self, policy=None) -> str:
        return (f"PYTHONPATH=src python -m benchmarks.fuzz_soak "
                f"--mode chaos --seed {self.seed}")


def make_chaos_case(seed: int) -> ChaosCase:
    rnd = random.Random(f"chaos-{seed}")
    chaos_class = rnd.choice(("flap_only", "flap_only", "mixed", "mixed",
                              "corrupt"))
    pp = rnd.choice((1, 2))
    dp = 3                      # real kills leave >= 2, false positives >= 1
    num_micro = rnd.choice((1, 2))
    w = ClusterWorkload(family="dense", num_layers=2 * pp, dropout_rate=0.0,
                        dp=dp, pp=pp, global_batch=dp * num_micro,
                        num_micro=num_micro, seq_len=8,
                        seed=rnd.randrange(10 ** 6))
    horizon = rnd.randint(4, 6)
    actions: List[ChaosAction] = []
    removed = {p: 0 for p in range(pp)}     # truth removals per stage

    def pick_rank():
        pool = [r for r in range(dp * pp)
                if removed[r % pp] < dp - 1
                and all(a.rank != r for a in actions)]
        return rnd.choice(pool) if pool else None

    if chaos_class == "mixed":
        for kind in ("kill", "notice"):
            if kind == "notice" and rnd.random() < 0.4:
                continue
            r = pick_rank()
            if r is None:
                continue
            removed[r % pp] += 1
            actions.append(ChaosAction(step=rnd.randint(1, horizon - 1),
                                       kind=kind, rank=r,
                                       deadline=rnd.choice((0.05, 120.0))))
        if rnd.random() < 0.7:              # an OOM ramp on a live rank
            live = [r for r in range(dp * pp)
                    if all(a.rank != r for a in actions)]
            r = rnd.choice(live)
            for i, frac in enumerate((0.5, 0.7, 0.85, 0.97)):
                if i >= horizon:
                    break
                actions.append(ChaosAction(step=i, kind="mem", rank=r,
                                           value=frac))
    elif chaos_class == "corrupt":
        for _ in range(rnd.randint(1, 2)):
            r = pick_rank()
            if r is None:
                break
            removed[r % pp] += 1
            actions.append(ChaosAction(
                step=rnd.randint(1, horizon - 1),
                kind=rnd.choice(("corrupt_kill", "corrupt_drain")),
                rank=r, component=rnd.choice(("master", "mu", "nu"))))
    return ChaosCase(seed, chaos_class, w, tuple(actions), horizon)


class DetectionChaosRunner:
    """Drive a VirtualCluster through a chaos case: ground-truth actions
    mutate reality, perturbed probes feed the ElasticController, and
    whatever the controller decides is executed — then the settle window
    must heal every false verdict.

    Probe perturbation knobs (drawn per case): drop, duplicate, one-round
    delay, reorder, and flap (a live rank's heartbeat reads false)."""

    def __init__(self, case: ChaosCase, checkers=None):
        self.case = case
        self.workload = case.workload
        if checkers is None:
            checkers = default_cluster_checkers()
            if case.chaos_class == "corrupt":
                checkers = [c for c in checkers
                            if c.name != "parameter-consistency"]
        self.checkers = checkers

    # -- probe synthesis ---------------------------------------------------
    def _probes(self, cl, rnd, truth_dead, delayed, chaotic,
                p_flap, p_drop, p_dup, p_delay):
        """Truthful probes for every grid rank (dead ranks are silent;
        unregistered-but-alive ranks still probe, feeding resurrection),
        perturbed when ``chaotic``."""
        from repro.core.agent import Probe
        base_t = 0.1
        out = list(delayed)
        delayed.clear()
        for rank in range(cl.dp0 * cl.pp):
            if rank in truth_dead:
                continue                      # the dead emit nothing
            hb = True
            if chaotic and rnd.random() < p_flap:
                hb = False                    # transient blip
            p = Probe(cl.step_count, rank, heartbeat=hb,
                      step_seconds=base_t,
                      mem_used=float(cl.mem_used[rank // cl.pp,
                                                 rank % cl.pp]))
            if chaotic and rnd.random() < p_drop:
                continue                      # lost on the wire
            if chaotic and rnd.random() < p_delay:
                delayed.append(p)             # arrives next round, stale
                continue
            out.append(p)
            if chaotic and rnd.random() < p_dup:
                out.append(Probe(p.step, p.rank, p.heartbeat,
                                 p.step_seconds, p.mem_used))
        if chaotic:
            rnd.shuffle(out)                  # reordered delivery
        return out

    # -- main loop ---------------------------------------------------------
    def run(self):
        case = self.case
        cl = self.workload.make_cluster()
        rnd = random.Random(f"chaos-exec-{case.seed}")
        p_flap = rnd.uniform(0.05, 0.3)
        p_drop = rnd.uniform(0.0, 0.2)
        p_dup = rnd.uniform(0.0, 0.3)
        p_delay = rnd.uniform(0.0, 0.15)
        for c in self.checkers:
            c.on_cluster_start(self, cl)
        truth_dead: set = set()
        delayed: List = []
        expected_degraded = 0
        got_degraded = 0
        by_step: Dict[int, List[ChaosAction]] = {}
        for a in case.actions:
            by_step.setdefault(a.step, []).append(a)

        def apply_ev(ev):
            nonlocal got_degraded
            rec = cl.apply_event(ev)
            got_degraded += int(rec.get("degraded", 0))
            for c in self.checkers:
                c.after_cluster_event(cl.step_count, ev, cl, rec)
            return rec

        def cell(rank):
            return rank // cl.pp, rank % cl.pp

        step = 0
        settle_left = None
        while True:
            chaotic = step < case.horizon
            for act in by_step.get(step, ()):   # ground truth mutates reality
                d, p = cell(act.rank)
                if act.kind == "kill":
                    truth_dead.add(act.rank)
                elif act.kind == "mem":
                    cl.inject_mem_pressure(d, p, act.value)
                elif act.kind in ("notice", "corrupt_kill", "corrupt_drain"):
                    if act.kind.startswith("corrupt"):
                        # bit rot at the recovery read point: corrupt the
                        # holder's stored copy of this rank's shard (shard
                        # index = position in the stage's surviving group)
                        j = cl.stages[p].dp_ranks.index(d)
                        cl.snapshots[p].corrupt_shard(j, act.component)
                    if act.kind == "corrupt_kill":
                        truth_dead.add(act.rank)
                        expected_degraded += 1
                        apply_ev(ElasticEvent(EventKind.FAIL_STOP,
                                              cl.step_count, (act.rank,)))
                    else:                       # notice / corrupt_drain
                        truth_dead.add(act.rank)
                        apply_ev(ElasticEvent(EventKind.PREEMPT_NOTICE,
                                              cl.step_count, (act.rank,),
                                              deadline=act.deadline))
            probes = self._probes(cl, rnd, truth_dead, delayed, chaotic,
                                  p_flap, p_drop, p_dup, p_delay)
            events = cl.controller.observe(probes)
            for ev in events:
                apply_ev(ev)
            loss = cl.train_step()
            for c in self.checkers:
                c.after_cluster_step(cl.step_count - 1, cl, loss)
            step += 1
            if step >= case.horizon:
                if settle_left is None:         # size the settle window once
                    settle_left = cl.agent.max_confirm_misses() + 4
                else:
                    settle_left -= 1
                stable = (not events
                          and all(h.state.value == "healthy"
                                  for h in cl.agent.health.values())
                          and self._grid_matches_truth(cl, truth_dead))
                if stable or settle_left <= 0:
                    break
        self._final_asserts(cl, truth_dead, expected_degraded, got_degraded)
        return cl

    @staticmethod
    def _grid_matches_truth(cl, truth_dead) -> bool:
        for rank in range(cl.dp0 * cl.pp):
            d, p = rank // cl.pp, rank % cl.pp
            if bool(cl.alive[d, p]) != (rank not in truth_dead):
                return False
        return True

    def _final_asserts(self, cl, truth_dead, expected_degraded,
                       got_degraded):
        falsely_evicted = []
        missed_evictions = []
        for rank in range(cl.dp0 * cl.pp):
            d, p = rank // cl.pp, rank % cl.pp
            if rank in truth_dead:
                if bool(cl.alive[d, p]) or rank in cl.agent.times:
                    missed_evictions.append(rank)
            else:
                if not bool(cl.alive[d, p]) or rank not in cl.agent.times:
                    falsely_evicted.append(rank)
        if falsely_evicted:
            raise InvariantViolation(
                f"[detection-chaos] class {self.case.chaos_class}: ranks "
                f"{falsely_evicted} are PERMANENTLY evicted although their "
                f"workers are alive (false positive not healed by "
                f"resurrection)")
        if missed_evictions:
            raise InvariantViolation(
                f"[detection-chaos] class {self.case.chaos_class}: dead "
                f"ranks {missed_evictions} were never evicted")
        if got_degraded != expected_degraded:
            raise InvariantViolation(
                f"[detection-chaos] class {self.case.chaos_class}: expected "
                f"{expected_degraded} tolerance-tier (degraded) shard "
                f"rebuilds, recovery records show {got_degraded}")
        import numpy as _np
        if not all(_np.isfinite(l) for l in cl.losses):
            raise InvariantViolation(
                f"[detection-chaos] class {self.case.chaos_class}: "
                f"non-finite loss after chaotic recovery")


def run_chaos_case(case: ChaosCase, checkers=None):
    """Run one detection-chaos case; violations carry the one-line repro."""
    try:
        return DetectionChaosRunner(case, checkers=checkers).run()
    except InvariantViolation as e:
        raise InvariantViolation(
            f"{e}\n  chaos seed {case.seed} ({case.chaos_class}); reproduce "
            f"with:\n  {case.repro()}") from e


# ---------------------------------------------------------------------------
# detector-level chaos sweep (no cluster: pure control-plane, sub-ms/seed)
# ---------------------------------------------------------------------------
def run_detector_chaos(seed: int) -> None:
    """Property check of Agent + ElasticController alone under probe chaos —
    no numerics, so hundreds of seeds cost milliseconds.  A membership shim
    plays the executor: FAIL_STOP unregisters the rank, SCALE_OUT
    re-registers it.  Asserts: no permanent false evictions, every
    truly-dead rank confirmed, stuck grants recovered.  Raises
    ``AssertionError`` (with the seed) on violation."""
    from repro.core.agent import Agent, Probe
    from repro.core.controller import ElasticController
    rnd = random.Random(f"detchaos-{seed}")
    pp = rnd.choice((1, 2, 3))
    dp = rnd.randint(2, 4)
    n = dp * pp
    agent = Agent(n, miss_limit=2, stage_of={r: r % pp for r in range(n)})
    ctl = ElasticController(agent, grant_timeout=4)
    flap_only = rnd.random() < 0.5
    truth_dead: set = set()
    horizon = rnd.randint(8, 16)
    p_flap = rnd.uniform(0.1, 0.4)
    p_drop = rnd.uniform(0.0, 0.25)
    p_dup = rnd.uniform(0.0, 0.3)
    stuck_rank = None
    if rnd.random() < 0.3:                  # a grant that never joins
        stuck_rank = n + 7
        ctl.grant(stuck_rank, "phantom capacity")

    def observe(chaotic: bool):
        probes = []
        for r in range(n):
            if r in truth_dead:
                continue
            hb = not (chaotic and rnd.random() < p_flap)
            if chaotic and rnd.random() < p_drop:
                continue
            probes.append(Probe(0, r, hb, 0.1))
            if chaotic and rnd.random() < p_dup:
                probes.append(Probe(0, r, hb, 0.1))
        if chaotic:
            rnd.shuffle(probes)
        for ev in ctl.observe(probes):
            if ev.kind == EventKind.FAIL_STOP:
                for r in ev.ranks:
                    agent.remove_rank(r)
            elif ev.kind == EventKind.SCALE_OUT:
                for r in ev.ranks:
                    agent.add_rank(r, stage=r % pp)
                    ctl.note_join(r)

    for step in range(horizon):
        if not flap_only and rnd.random() < 0.15:
            # a real kill that keeps the stage non-empty in truth
            pool = [r for r in range(n) if r not in truth_dead
                    and sum(1 for q in range(n)
                            if q % pp == r % pp and q not in truth_dead) >= 2]
            if pool:
                truth_dead.add(rnd.choice(pool))
        observe(chaotic=True)
    for _ in range(agent.max_confirm_misses() + 2):     # settle: clean probes
        observe(chaotic=False)

    alive_regs = set(agent.ranks)
    false_perm = [r for r in range(n)
                  if r not in truth_dead and r not in alive_regs]
    assert not false_perm, \
        (f"detector-chaos seed {seed}: permanent false eviction of {false_perm}"
         f" ({'flap-only' if flap_only else 'mixed'} trace)")
    missed = [r for r in truth_dead if r in alive_regs]
    assert not missed, \
        f"detector-chaos seed {seed}: dead ranks {missed} never evicted"
    if stuck_rank is not None:
        assert any(g.rank == stuck_rank for g in ctl.stuck_grants()), \
            (f"detector-chaos seed {seed}: granted-but-never-joined rank "
             f"{stuck_rank} was not recovered as a stuck grant")
