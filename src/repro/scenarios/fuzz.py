"""Seeded generator of *legal* adversarial elastic traces (the fuzzer).

The paper's claim is universally quantified — *every* legal elastic event
sequence preserves the four guarantees (§4) — so hand-picked scenario
builders can never close the argument.  This module draws random traces from
composable :class:`EventStrategy` combinators (fail-stop bursts, correlated
domain bursts, rejoins, cascading fail-slow, DVFS setpoints, directed
migrations, shrink-regrow interleavings) over randomized workload shapes
(dp x pp x model family), constrained to stay *legal*:

* never kill a stage's last surviving replica (training would be
  unrecoverable — that is outside the paper's claim);
* rejoin (SCALE_OUT) only currently-dead ranks, shrink only live ranks,
  no duplicate ranks within one burst (``spec.validate_event_legality``);
* bounded concurrent events per step and per trace.

Everything is derived from a single integer seed: ``make_analytic_case(s)``
/ ``make_cluster_case(s)`` rebuild the exact workload + trace, so a CI
failure is reproducible with one command (``FuzzCase.repro()``).
``run_case`` attaches the invariant checkers from ``core.invariants`` and
decorates any violation with that command; ``shrink_case`` greedily deletes
events (re-checking legality) to hand back a minimal failing trace.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Callable, List, Optional, Sequence, Tuple

from repro.core.cost_model import HardwareSpec
from repro.core.events import ElasticEvent, EventKind, burst
from repro.core.invariants import (InvariantViolation,
                                   default_analytic_checkers,
                                   default_cluster_checkers)

from .spec import (AnalyticWorkload, ClusterWorkload, Scenario,
                   validate_event_legality)


# ---------------------------------------------------------------------------
# trace state + legality
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class TraceState:
    """Liveness bookkeeping threaded through the strategies while a trace is
    being drawn.  ``reserved`` ranks have a scheduled future rejoin and may
    not be touched by any other strategy; a dead rank stays counted as dead
    here even past its rejoin step (conservative: the generator under-counts
    widths, so the never-kill-the-last-replica rule can only over-hold)."""
    dp: int
    pp: int
    horizon: int
    dead: set = dataclasses.field(default_factory=set)
    reserved: set = dataclasses.field(default_factory=set)

    def stage_of(self, rank: int) -> int:
        return rank % self.pp

    def width(self, p: int) -> int:
        return self.dp - sum(1 for r in self.dead if r % self.pp == p)

    def live_ranks(self) -> List[int]:
        return [r for r in range(self.dp * self.pp)
                if r not in self.dead and r not in self.reserved]

    def killable(self, extra_dead: set = frozenset()) -> List[int]:
        """Live, unreserved ranks whose removal keeps their stage >= 1 wide
        (``extra_dead``: ranks already picked for the same burst)."""
        out = []
        for r in self.live_ranks():
            if r in extra_dead:
                continue
            p = self.stage_of(r)
            w = self.width(p) - sum(1 for x in extra_dead if x % self.pp == p)
            if w >= 2:
                out.append(r)
        return out


def trace_is_legal(events: Sequence[ElasticEvent], dp: int, pp: int) -> bool:
    """Predicate form of trace legality (used by the shrinker, which must not
    raise): event-sequence rules from ``validate_event_legality`` plus the
    grid rules — ranks inside the dp x pp grid and every stage keeps >= 1
    live replica after every liveness event."""
    evs = sorted(events, key=lambda e: e.step)
    try:
        validate_event_legality(evs, "candidate")
    except ValueError:
        return False
    width = [dp] * pp
    for e in evs:
        if any(r >= dp * pp for r in e.ranks):
            return False
        if e.is_shrink:
            for r in e.ranks:
                width[r % pp] -= 1
            if min(width) < 1:
                return False
        elif e.is_grow:
            for r in e.ranks:
                width[r % pp] += 1
    return True


# ---------------------------------------------------------------------------
# strategy combinators
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class EventStrategy:
    """One adversarial move: ``fn(rnd, state, step)`` either emits a list of
    legal events (mutating ``state``'s liveness books) or returns ``None``
    when inapplicable at this point of the trace."""
    name: str
    fn: Callable[[random.Random, TraceState, int],
                 Optional[List[ElasticEvent]]]
    weight: float = 1.0


def failstop_burst(max_ranks: int = 3) -> EventStrategy:
    """Concurrent multi-rank failure; 30% of draws arrive as scheduler
    SCALE_IN preemptions instead of FAIL_STOPs (same liveness effect)."""
    def fn(rnd, st, step):
        picked: set = set()
        for _ in range(rnd.randint(1, max_ranks)):
            pool = st.killable(picked)
            if not pool:
                break
            picked.add(rnd.choice(pool))
        if not picked:
            return None
        st.dead |= picked
        kind = EventKind.SCALE_IN if rnd.random() < 0.3 else EventKind.FAIL_STOP
        return [burst(kind, step, tuple(picked))]
    return EventStrategy("failstop_burst", fn, weight=2.0)


def rejoin(max_ranks: int = 4) -> EventStrategy:
    """SCALE_OUT of a random subset of the currently-dead ranks."""
    def fn(rnd, st, step):
        pool = sorted(st.dead - st.reserved)
        if not pool:
            return None
        k = rnd.randint(1, min(max_ranks, len(pool)))
        picked = rnd.sample(pool, k)
        st.dead -= set(picked)
        return [burst(EventKind.SCALE_OUT, step, tuple(picked))]
    return EventStrategy("rejoin", fn)


def fail_slow(factors: Tuple[float, ...] = (1.5, 2.0, 3.0)) -> EventStrategy:
    """A live rank starts straggling (repeats on the same rank are legal —
    that is the cascading-degradation shape)."""
    def fn(rnd, st, step):
        pool = st.live_ranks()
        if not pool:
            return None
        return [ElasticEvent(EventKind.FAIL_SLOW, step, (rnd.choice(pool),),
                             slow_factor=rnd.choice(factors))]
    return EventStrategy("fail_slow", fn)


def dvfs_set(freqs: Tuple[float, ...] = (1.0, 1.05, 1.1, 1.178)
             ) -> EventStrategy:
    """Frequency setpoint on a random subset of live ranks (straggler
    absorption / power capping)."""
    def fn(rnd, st, step):
        pool = st.live_ranks()
        if not pool:
            return None
        picked = rnd.sample(pool, rnd.randint(1, min(3, len(pool))))
        return [burst(EventKind.DVFS_SET, step, tuple(picked),
                      freq=rnd.choice(freqs))]
    return EventStrategy("dvfs_set", fn)


def shrink_regrow(max_gap: int = 3) -> EventStrategy:
    """Kill one rank now and schedule its rejoin a few steps later; the rank
    is *reserved* so no other strategy touches it in between (the
    interleaving shape that historically broke naive liveness tracking)."""
    def fn(rnd, st, step):
        if step >= st.horizon - 1:
            return None                       # no room for the rejoin
        pool = st.killable()
        if not pool:
            return None
        r = rnd.choice(pool)
        back = min(step + rnd.randint(1, max_gap), st.horizon - 1)
        st.dead.add(r)
        st.reserved.add(r)
        return [ElasticEvent(EventKind.SCALE_IN, step, (r,)),
                ElasticEvent(EventKind.SCALE_OUT, back, (r,))]
    return EventStrategy("shrink_regrow", fn)


def migrate(num_layers: int, pp: int) -> EventStrategy:
    """Directed layer migration between two distinct stages (analytic-only:
    the numeric executor treats MIGRATE as a planner-internal action)."""
    def fn(rnd, st, step):
        if pp < 2:
            return None
        src = rnd.randrange(pp)
        dst = rnd.choice([p for p in range(pp) if p != src])
        per, rem = num_layers // pp, num_layers % pp
        lo = src * per + min(src, rem)
        n = per + (1 if src < rem else 0)
        layers = sorted(rnd.sample(range(lo, lo + n), min(rnd.randint(1, 3), n)))
        return [ElasticEvent(EventKind.MIGRATE, step, (), layers=tuple(layers),
                             src_stage=src, dst_stage=dst)]
    return EventStrategy("migrate", fn, weight=0.5)


def domain_burst(domains) -> EventStrategy:
    """Correlated whole-domain (rack/pod) failure with a later rejoin of the
    same block — the shape i.i.d. rank sampling never produces."""
    def fn(rnd, st, step):
        if domains is None or step >= st.horizon - 1:
            return None
        order = list(range(domains.n_domains))
        rnd.shuffle(order)
        for d in order:
            ranks = {int(r) for r in domains.ranks_of([d])}
            if ranks & (st.dead | st.reserved):
                continue
            if all(st.width(p) - sum(1 for r in ranks if r % st.pp == p) >= 1
                   for p in range(st.pp)):
                back = min(step + rnd.randint(1, 3), st.horizon - 1)
                st.dead |= ranks
                st.reserved |= ranks
                return [burst(EventKind.FAIL_STOP, step, tuple(ranks),
                              detail=f"domain {d} down"),
                        burst(EventKind.SCALE_OUT, back, tuple(ranks),
                              detail=f"domain {d} rejoin")]
        return None
    return EventStrategy("domain_burst", fn, weight=0.7)


def draw_trace(rnd: random.Random, *, dp: int, pp: int, horizon: int,
               strategies: Sequence[EventStrategy],
               max_events: Optional[int] = None,
               p_event: float = 0.6) -> List[ElasticEvent]:
    """Walk the horizon; at each step maybe fire one weighted strategy."""
    st = TraceState(dp=dp, pp=pp, horizon=horizon)
    weights = [s.weight for s in strategies]
    events: List[ElasticEvent] = []
    for step in range(horizon):
        if max_events is not None and len(events) >= max_events:
            break
        if rnd.random() >= p_event:
            continue
        strat = rnd.choices(list(strategies), weights=weights)[0]
        got = strat.fn(rnd, st, step)
        if got:
            events.extend(got)
    return events


# ---------------------------------------------------------------------------
# randomized workloads
# ---------------------------------------------------------------------------
def draw_analytic_workload(rnd: random.Random) -> AnalyticWorkload:
    from repro.models import registry as R
    pp = rnd.choice((1, 2, 2, 3, 4))
    dp = rnd.randint(2, 6)
    family = rnd.choice(("dense", "moe", "ssm"))
    num_layers = pp * rnd.randint(2, 4)
    mbs = rnd.choice((1, 2))
    num_micro = rnd.randint(2, 4)
    return AnalyticWorkload(
        cfg=R.tiny_config(family, num_layers=num_layers),
        dp=dp, pp=pp, mbs=mbs, global_batch=mbs * dp * num_micro,
        seq=rnd.choice((64, 128, 256)), hw=HardwareSpec(),
        domain_size=pp if rnd.random() < 0.5 else None)


def draw_cluster_workload(rnd: random.Random) -> ClusterWorkload:
    """Numeric workloads stay tiny: every VirtualCluster instance jit-compiles
    its own step functions, so the fuzz budget goes to *traces*, not params."""
    pp = rnd.choice((1, 2))
    dp = rnd.randint(2, 3)
    num_micro = rnd.choice((1, 2))
    per_rank = rnd.choice((1, 2))
    return ClusterWorkload(
        family="dense", num_layers=2 * pp,
        dropout_rate=rnd.choice((0.0, 0.1)), dp=dp, pp=pp,
        global_batch=dp * num_micro * per_rank, num_micro=num_micro,
        seq_len=8, seed=rnd.randrange(10 ** 6), rng_mode="reshard")


def default_analytic_strategies(w: AnalyticWorkload) -> List[EventStrategy]:
    return [failstop_burst(), rejoin(), fail_slow(), dvfs_set(),
            shrink_regrow(), migrate(w.cfg.num_layers, w.pp),
            domain_burst(w.domains)]


def default_cluster_strategies() -> List[EventStrategy]:
    """No MIGRATE (numeric executor rejects direct injection) and no domain
    bursts (cluster grids are too small for whole-domain kills)."""
    return [failstop_burst(max_ranks=2), rejoin(max_ranks=2),
            fail_slow(factors=(1.5, 2.0)), dvfs_set(), shrink_regrow()]


# ---------------------------------------------------------------------------
# cases
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class FuzzCase:
    """A fully-reproducible fuzz input: seed -> (workload, trace)."""
    seed: int
    mode: str                   # "analytic" | "cluster"
    scenario: Scenario
    workload: object            # AnalyticWorkload | ClusterWorkload

    def repro(self, policy: Optional[str] = None) -> str:
        cmd = (f"PYTHONPATH=src python -m benchmarks.fuzz_soak "
               f"--mode {self.mode} --seed {self.seed}")
        if policy:
            cmd += f" --policy {policy}"
        return cmd


def make_analytic_case(seed: int) -> FuzzCase:
    rnd = random.Random(f"analytic-{seed}")
    w = draw_analytic_workload(rnd)
    horizon = rnd.randint(6, 12)
    events = draw_trace(rnd, dp=w.dp, pp=w.pp, horizon=horizon,
                        strategies=default_analytic_strategies(w))
    return FuzzCase(seed, "analytic",
                    Scenario(f"fuzz-analytic-{seed}", tuple(events), horizon),
                    w)


def make_cluster_case(seed: int) -> FuzzCase:
    rnd = random.Random(f"cluster-{seed}")
    w = draw_cluster_workload(rnd)
    horizon = rnd.randint(3, 5)
    events = draw_trace(rnd, dp=w.dp, pp=w.pp, horizon=horizon,
                        strategies=default_cluster_strategies(),
                        max_events=3, p_event=0.7)
    return FuzzCase(seed, "cluster",
                    Scenario(f"fuzz-cluster-{seed}", tuple(events), horizon),
                    w)


def make_case(mode: str, seed: int) -> FuzzCase:
    if mode == "analytic":
        return make_analytic_case(seed)
    if mode == "cluster":
        return make_cluster_case(seed)
    raise ValueError(f"unknown fuzz mode {mode!r}")


POLICY_NAMES = ("elaswave", "torchft", "oobleck")


def make_policy(name: str, hw: Optional[HardwareSpec] = None):
    """Fresh policy per run — OobleckPolicy caches templates keyed by config
    identity, so instances must not leak across workloads."""
    from repro.core.policies import (ElasWavePolicy, OobleckPolicy,
                                     TorchFTPolicy)
    if name == "elaswave":
        return ElasWavePolicy(hw=hw)
    if name == "torchft":
        return TorchFTPolicy()
    if name == "oobleck":
        return OobleckPolicy(hw=hw)
    raise ValueError(f"unknown policy {name!r}")


def run_case(case: FuzzCase, policy: Optional[str] = None, checkers=None,
             **runner_kw):
    """Run one fuzz case with the default invariant checkers attached.

    An :class:`InvariantViolation` is re-raised with the fuzz seed and the
    one-line repro command appended, so a red CI log is actionable as-is.
    """
    from .runner import AnalyticScenarioRunner, ClusterScenarioRunner
    try:
        if case.mode == "analytic":
            pol = make_policy(policy or "elaswave", hw=case.workload.hw)
            cks = (default_analytic_checkers() if checkers is None
                   else checkers)
            return AnalyticScenarioRunner(case.scenario, case.workload, pol,
                                          checkers=cks, **runner_kw).run()
        cks = default_cluster_checkers() if checkers is None else checkers
        return ClusterScenarioRunner(case.scenario, case.workload,
                                     checkers=cks, **runner_kw).run()
    except InvariantViolation as e:
        raise InvariantViolation(
            f"{e}\n  fuzz seed {case.seed} ({case.mode}); reproduce with:\n"
            f"  {case.repro(policy)}") from e


def shrink_case(case: FuzzCase,
                fails: Callable[[FuzzCase], bool]) -> FuzzCase:
    """Greedy event-deletion minimization: repeatedly drop any single event
    whose removal keeps the trace legal AND still failing.  Terminates when
    no single deletion reproduces the failure (1-minimal trace)."""
    current = case
    progress = True
    while progress:
        progress = False
        evs = list(current.scenario.events)
        for i in range(len(evs)):
            cand_events = evs[:i] + evs[i + 1:]
            w = current.workload
            if not trace_is_legal(cand_events, w.dp, w.pp):
                continue
            try:
                cand_scn = Scenario(current.scenario.name,
                                    tuple(cand_events),
                                    current.scenario.horizon)
            except ValueError:
                continue
            cand = dataclasses.replace(current, scenario=cand_scn)
            try:
                still_fails = fails(cand)
            except Exception:
                still_fails = True          # any crash counts as failing
            if still_fails:
                current = cand
                progress = True
                break
    return current
