"""Declarative elastic-scenario specs.

A :class:`Scenario` is a named, ordered trace of timed
:class:`~repro.core.events.ElasticEvent` injections over a horizon of steps
(cluster mode) or seconds (analytic trace replay).  Scenarios compose: the
builders below cover single failures, concurrent multi-rank bursts, cascades
of worsening stragglers, DVFS setpoints, directed migrations, and
SpotServe-style capacity-trace replays — the ROADMAP's "as many scenarios as
you can imagine" expressed as data instead of bespoke event loops.

Two workload descriptions exist because the runner has two execution modes
(see :mod:`repro.scenarios.runner`):

* :class:`ClusterWorkload` — a tiny real model driven numerically on the
  :class:`~repro.core.cluster.VirtualCluster` (losses, live remap, bit-exact
  consistency checks);
* :class:`AnalyticWorkload` — a paper-scale workload (e.g. Llama-2 on 96
  NPUs) evaluated through the recovery policies and cost models only.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cost_model import HardwareSpec, SegmentCosts
from repro.core.events import ElasticEvent, EventKind, burst
from repro.models.config import ModelConfig


# ---------------------------------------------------------------------------
# workloads
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ClusterWorkload:
    """A VirtualCluster-sized workload (tiny real model, real numerics)."""
    family: str = "dense"
    num_layers: int = 8
    dropout_rate: float = 0.1
    dp: int = 4
    pp: int = 2
    global_batch: int = 16
    num_micro: int = 2
    seq_len: int = 16
    seed: int = 0
    rng_mode: str = "reshard"
    use_pallas: bool = False

    def make_cluster(self, **overrides):
        """Build the VirtualCluster.  ``overrides`` pass straight through to
        the constructor — e.g. ``fast_path=False`` builds the bit-exact
        ``core/legacy.py`` twin the invariant harness locksteps against, and
        ``use_pallas=False`` builds the plain-jnp twin the tolerance-tier
        kernel checker compares a pallas-mode run against."""
        from repro.core.cluster import VirtualCluster
        from repro.models import registry as R
        cfg = R.tiny_config(self.family, num_layers=self.num_layers,
                            dropout_rate=self.dropout_rate)
        kw = dict(global_batch=self.global_batch, num_micro=self.num_micro,
                  seq_len=self.seq_len, seed=self.seed,
                  rng_mode=self.rng_mode, use_pallas=self.use_pallas)
        kw.update(overrides)
        return VirtualCluster(cfg, dp=self.dp, pp=self.pp, **kw)

    def rank(self, d: int, p: int) -> int:
        return d * self.pp + p

    def describe(self) -> Dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class AnalyticWorkload:
    """A paper-scale workload evaluated through policies + cost models.

    ``domain_size`` (ranks per rack/pod) activates correlated failure
    domains: the built views carry a
    :class:`~repro.core.clusterview.FailureDomainMap` and at-scale scenarios
    can sample whole domains (``Scenario.domain_burst``)."""
    cfg: ModelConfig
    dp: int
    pp: int
    mbs: int
    global_batch: int
    seq: int
    hw: HardwareSpec
    mem_cap: Optional[float] = None
    domain_size: Optional[int] = None

    @property
    def num_micro(self) -> int:
        return self.global_batch // (self.mbs * self.dp)

    @property
    def domains(self):
        if self.domain_size is None:
            return None
        from repro.core.clusterview import FailureDomainMap
        return FailureDomainMap(self.dp * self.pp, self.domain_size)

    def rank(self, d: int, p: int) -> int:
        return d * self.pp + p

    def build_seg(self) -> SegmentCosts:
        return SegmentCosts.build(self.cfg, self.seq, self.hw)

    def build_view(self, seg: SegmentCosts, alive: Optional[np.ndarray] = None,
                   slow: Optional[np.ndarray] = None):
        """A ClusterView over this workload (balanced layer assignment)."""
        from repro.core.policies import ClusterView
        L, pp = self.cfg.num_layers, self.pp
        per, rem = L // pp, L % pp
        ranges, a = [], 0
        for p in range(pp):
            b = a + per + (1 if p < rem else 0) - 1
            ranges.append((a, b))
            a = b + 1
        return ClusterView(
            dp=self.dp, pp=self.pp, global_batch=self.global_batch,
            num_micro=self.num_micro, seq=self.seq, layer_assignment=ranges,
            alive=alive if alive is not None else np.ones((self.dp, self.pp), bool),
            freq=np.ones((self.dp, self.pp)),
            slow=slow if slow is not None else np.ones((self.dp, self.pp)),
            mem_cap=self.mem_cap if self.mem_cap is not None
            else self.hw.hbm_bytes,
            domains=self.domains)

    def describe(self) -> Dict:
        return {"model": self.cfg.name, "dp": self.dp, "pp": self.pp,
                "mbs": self.mbs, "global_batch": self.global_batch,
                "seq": self.seq,
                **({"domain_size": self.domain_size}
                   if self.domain_size is not None else {})}


def node_shrink_cells(n_nodes: int, dp: int, pp: int) -> List[Tuple[int, int]]:
    """The paper's shrink pattern: one node = 2 workers, killed replica-major
    so distinct replicas fail first.  Monotone: ``cells(n)`` is a prefix of
    ``cells(n+1)``, which lets capacity traces move between levels by
    failing/rejoining only the delta."""
    cells: List[Tuple[int, int]] = []
    d = 0
    while len(cells) < 2 * n_nodes and d < dp:
        for p in (0, 1):
            if len(cells) < 2 * n_nodes:
                cells.append((d % dp, (p + d) % pp))
        d += 1
    return cells


# ---------------------------------------------------------------------------
# scenario
# ---------------------------------------------------------------------------
def validate_event_legality(events: Sequence[ElasticEvent],
                            name: str = "trace") -> None:
    """Construction-time trace legality — the fuzzer's definition of "legal".

    Walks the (step-sorted) events with a dead-rank set and raises a crisp
    ``ValueError`` on the shapes that used to fail deep inside the runner:
    duplicate ranks within one burst, negative steps/ranks, rejoin
    (SCALE_OUT) of a rank that is currently alive, and shrink (FAIL_STOP /
    SCALE_IN) of a rank that is already dead.  FAIL_SLOW / DVFS_SET / MIGRATE
    do not alter liveness (repeats are legal).  Grid-shape rules (never kill
    a stage's last replica) need dp x pp and live in
    ``scenarios.fuzz.trace_is_legal``.
    """
    dead: set = set()
    for e in events:
        if e.step < 0:
            raise ValueError(
                f"scenario {name!r}: event at negative step {e.step}")
        if any(r < 0 for r in e.ranks):
            raise ValueError(
                f"scenario {name!r}: negative rank in {e.describe()}")
        if len(set(e.ranks)) != len(e.ranks):
            raise ValueError(
                f"scenario {name!r}: duplicate ranks in burst "
                f"{e.describe()} at step {e.step}")
        if e.is_grow:
            live = sorted(set(e.ranks) - dead)
            if live:
                raise ValueError(
                    f"scenario {name!r}: rejoin of live rank(s) {live} at "
                    f"step {e.step} (SCALE_OUT may only target dead ranks)")
            dead -= set(e.ranks)
        elif e.is_shrink:
            already = sorted(set(e.ranks) & dead)
            if already:
                raise ValueError(
                    f"scenario {name!r}: shrink of already-dead rank(s) "
                    f"{already} at step {e.step}")
            dead |= set(e.ranks)


@dataclasses.dataclass
class Scenario:
    """An ordered trace of timed elastic events over a horizon."""
    name: str
    events: Tuple[ElasticEvent, ...]
    horizon: int
    description: str = ""

    def __post_init__(self):
        # stable sort by step; ties keep insertion order (burst determinism)
        self.events = tuple(sorted(self.events, key=lambda e: e.step))
        if self.events and self.events[-1].step >= self.horizon:
            raise ValueError(
                f"event at step {self.events[-1].step} outside horizon "
                f"{self.horizon} of scenario {self.name!r}")
        validate_event_legality(self.events, self.name)

    def events_at(self, step: int) -> List[ElasticEvent]:
        return [e for e in self.events if e.step == step]

    @property
    def event_steps(self) -> List[int]:
        return sorted({e.step for e in self.events})

    def describe(self) -> Dict:
        return {"name": self.name, "horizon": self.horizon,
                "description": self.description,
                "events": [e.describe() for e in self.events]}

    # -- builders ----------------------------------------------------------
    @staticmethod
    def single(name: str, kind: EventKind, step: int, ranks: Sequence[int],
               horizon: int, **kw) -> "Scenario":
        return Scenario(name, (ElasticEvent(kind, step, tuple(ranks), **kw),),
                        horizon)

    @staticmethod
    def fail_stop_burst(name: str, step: int, ranks: Sequence[int],
                        horizon: int) -> "Scenario":
        """Concurrent multi-rank failure (e.g. a node or switch domain)."""
        return Scenario(name, (burst(EventKind.FAIL_STOP, step, tuple(ranks)),),
                        horizon, description="concurrent multi-rank fail-stop")

    @staticmethod
    def cascade(name: str, cells_factors: Sequence[Tuple[int, float]],
                start: int, spacing: int, horizon: int,
                absorb_freq: Optional[Tuple[Sequence[int], float, int]] = None,
                ) -> "Scenario":
        """Cascading fail-slow: (rank, factor) pairs fire ``spacing`` steps
        apart; optionally followed by a DVFS_SET absorbing the stragglers
        (``absorb_freq=(ranks, freq, step)``)."""
        evs = [ElasticEvent(EventKind.FAIL_SLOW, start + i * spacing, (r,),
                            slow_factor=f)
               for i, (r, f) in enumerate(cells_factors)]
        if absorb_freq is not None:
            ranks, freq, step = absorb_freq
            evs.append(ElasticEvent(EventKind.DVFS_SET, step, tuple(ranks),
                                    freq=freq))
        return Scenario(name, tuple(evs), horizon,
                        description="cascading fail-slow with DVFS absorption")

    @staticmethod
    def domain_burst(name: str, step: int, domain_ids: Sequence[int],
                     domains, horizon: int,
                     kind: EventKind = EventKind.FAIL_STOP,
                     regrow_step: Optional[int] = None) -> "Scenario":
        """Correlated failure-domain burst: every rank of the given rack/pod
        domains (a :class:`~repro.core.clusterview.FailureDomainMap`) fails
        at once — the at-scale shape i.i.d. rank sampling never produces.
        ``regrow_step`` optionally rejoins the whole block later."""
        ranks = tuple(int(r) for r in domains.ranks_of(list(domain_ids)))
        evs: List[ElasticEvent] = [
            burst(kind, step, ranks,
                  detail=f"domains {sorted(set(domain_ids))} down")]
        if regrow_step is not None:
            evs.append(burst(EventKind.SCALE_OUT, regrow_step, ranks,
                             detail="domain rejoin"))
        return Scenario(name, tuple(evs), horizon,
                        description="correlated rack/pod domain burst")

    @staticmethod
    def shrink_regrow(name: str, rank: int, fail_step: int, rejoin_step: int,
                      horizon: int) -> "Scenario":
        """Scale-down then scale-up rejoin of the same worker."""
        return Scenario(name, (
            ElasticEvent(EventKind.SCALE_IN, fail_step, (rank,)),
            ElasticEvent(EventKind.SCALE_OUT, rejoin_step, (rank,))),
            horizon, description="scale-down then scale-up rejoin")

    @staticmethod
    def from_capacity_trace(name: str, trace: Sequence[Tuple[int, int]],
                            dp: int, pp: int) -> "Scenario":
        """Spot-instance replay: ``trace`` is (duration, nodes_down) segments.
        Because the shrink pattern is a monotone prefix, moving between
        capacity levels emits SCALE_IN/SCALE_OUT events for the delta cells
        only; steps are wall-clock seconds."""
        events: List[ElasticEvent] = []
        t, prev = 0, 0
        horizon = sum(d for d, _ in trace)
        max_down = max((down for _, down in trace), default=0)
        seq = node_shrink_cells(max_down, dp, pp)
        for dur, down in trace:
            if down != prev and t > 0:
                lo, hi = 2 * min(prev, down), 2 * max(prev, down)
                ranks = tuple(d * pp + p for d, p in seq[lo:hi])
                kind = EventKind.SCALE_IN if down > prev else EventKind.SCALE_OUT
                events.append(ElasticEvent(kind, t, ranks,
                                           detail=f"capacity->{down} nodes down"))
            elif down != prev:          # trace starts degraded
                ranks = tuple(d * pp + p for d, p in seq[:2 * down])
                events.append(ElasticEvent(EventKind.SCALE_IN, 0, ranks))
            prev = down
            t += dur
        return Scenario(name, tuple(events), horizon,
                        description="capacity-trace replay (seconds horizon)")

    @staticmethod
    def preempt_notice(name: str, step: int, ranks: Sequence[int],
                       horizon: int, deadline: float = 120.0,
                       rejoin_step: Optional[int] = None) -> "Scenario":
        """Spot-style preemption with advance warning: the scheduler notifies
        at ``step`` and the ranks are drained proactively inside the
        ``deadline``-second window.  ``rejoin_step`` optionally brings the
        capacity back (preempted instances often return)."""
        evs: List[ElasticEvent] = [
            burst(EventKind.PREEMPT_NOTICE, step, tuple(ranks),
                  deadline=deadline, detail=f"{deadline:g}s notice")]
        if rejoin_step is not None:
            evs.append(burst(EventKind.SCALE_OUT, rejoin_step, tuple(ranks),
                             detail="preempted capacity returned"))
        return Scenario(name, tuple(evs), horizon,
                        description="preemption notice with proactive drain")

    def reactive_twin(self) -> "Scenario":
        """The reactive baseline of this scenario: every PREEMPT_NOTICE
        becomes a plain FAIL_STOP at the same step — the preemption lands and
        is *detected* instead of drained.  Everything else is unchanged, so
        (proactive MTTR) - (twin MTTR) isolates what the notice window buys."""
        evs = tuple(
            dataclasses.replace(e, kind=EventKind.FAIL_STOP,
                                detail=e.detail + " (reactive baseline)")
            if e.kind == EventKind.PREEMPT_NOTICE else e
            for e in self.events)
        return Scenario(self.name + "-reactive", evs, self.horizon,
                        description=self.description + " [reactive baseline]")

    @staticmethod
    def migration_probe(name: str, probes: Sequence[Tuple[int, ...]],
                        src: int = 0, dst: int = 1) -> "Scenario":
        """One MIGRATE event per probe (a tuple of layer ids), one step
        apart — used to meter migration stall in isolation."""
        evs = tuple(ElasticEvent(EventKind.MIGRATE, i, (), layers=tuple(ls),
                                 src_stage=src, dst_stage=dst)
                    for i, ls in enumerate(probes))
        return Scenario(name, evs, len(probes) + 1,
                        description="directed layer-migration probes")
