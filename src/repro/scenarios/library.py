"""Named scenario library.

Every entry returns ``(Scenario, ClusterWorkload)`` pairs runnable via
:func:`repro.scenarios.runner.run_scenario`.  The first three are shapes the
pre-scenario-engine benchmark scripts could *not* express:

* ``concurrent_burst``       — two ranks in *different* stages fail in the
  same step (switch-domain failure).  Expected shape: one burst recovery
  record whose itemized MTTR accumulates both ranks' control-plane phases
  with detection paid once; the loss trajectory stays consistent with a
  fault-free twin.
* ``shrink_regrow``          — scale-in (preemption) followed by the same
  worker rejoining.  Expected shape: DP width dips then recovers to the
  initial value; rejoin MTTR is communicator-add + reverse remap only (no
  detect / plan / migration).
* ``cascading_failslow``     — a straggler worsens in two waves, then a DVFS
  setpoint up-clocks the slowed workers.  Expected shape: step time rises
  with each wave (minus what migration rebalance claws back) and drops after
  the DVFS absorption event.

Plus single-event baselines (``single_failstop``, ``single_failslow``) used
by tests and as copy-paste templates for new scenarios.
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

from repro.core.events import EventKind

from .spec import ClusterWorkload, Scenario


def concurrent_burst() -> Tuple[Scenario, ClusterWorkload]:
    w = ClusterWorkload(dp=4, pp=2, global_batch=16, num_micro=2)
    # ranks: (d=1, p=0) and (d=2, p=1) fail in the same step
    scn = Scenario.fail_stop_burst(
        "concurrent_burst", step=3,
        ranks=(w.rank(1, 0), w.rank(2, 1)), horizon=7)
    return scn, w


def shrink_regrow() -> Tuple[Scenario, ClusterWorkload]:
    w = ClusterWorkload(dp=4, pp=2, global_batch=16, num_micro=2)
    scn = Scenario.shrink_regrow("shrink_regrow", rank=w.rank(1, 1),
                                 fail_step=2, rejoin_step=5, horizon=8)
    return scn, w


def cascading_failslow() -> Tuple[Scenario, ClusterWorkload]:
    w = ClusterWorkload(dp=4, pp=2, global_batch=32, num_micro=8,
                        dropout_rate=0.0)
    slow_ranks = (w.rank(0, 0), w.rank(1, 0))
    scn = Scenario.cascade(
        "cascading_failslow",
        cells_factors=[(slow_ranks[0], 1.25), (slow_ranks[1], 1.5)],
        start=2, spacing=2, horizon=9,
        absorb_freq=(slow_ranks, 1.4, 6))
    return scn, w


def preempt_drain() -> Tuple[Scenario, ClusterWorkload]:
    """Spot preemption with a two-minute notice: the named rank is drained
    proactively (verified snapshot flush + remap inside the window) and the
    instance rejoins later.  ``Scenario.reactive_twin()`` of this trace is
    the fail-stop baseline ``benchmarks/proactive_mttr.py`` diffs against."""
    w = ClusterWorkload(dp=3, pp=2, global_batch=12, num_micro=2,
                        dropout_rate=0.0)
    scn = Scenario.preempt_notice("preempt_drain", step=2,
                                  ranks=(w.rank(1, 0),), horizon=8,
                                  deadline=120.0, rejoin_step=6)
    return scn, w


def single_failstop() -> Tuple[Scenario, ClusterWorkload]:
    w = ClusterWorkload()
    scn = Scenario.single("single_failstop", EventKind.FAIL_STOP, step=3,
                          ranks=(w.rank(1, 1),), horizon=6)
    return scn, w


def single_failslow() -> Tuple[Scenario, ClusterWorkload]:
    w = ClusterWorkload(global_batch=32, num_micro=8, dropout_rate=0.0)
    scn = Scenario.single("single_failslow", EventKind.FAIL_SLOW, step=2,
                          ranks=(w.rank(0, 0),), horizon=5, slow_factor=1.6)
    return scn, w


SCENARIOS: Dict[str, Callable[[], Tuple[Scenario, ClusterWorkload]]] = {
    "concurrent_burst": concurrent_burst,
    "shrink_regrow": shrink_regrow,
    "cascading_failslow": cascading_failslow,
    "preempt_drain": preempt_drain,
    "single_failstop": single_failstop,
    "single_failslow": single_failslow,
}


def get_scenario(name: str) -> Tuple[Scenario, ClusterWorkload]:
    try:
        return SCENARIOS[name]()
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; "
                       f"available: {sorted(SCENARIOS)}") from None
