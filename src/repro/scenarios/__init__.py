"""Trace-driven elastic-scenario engine (see docs/ARCHITECTURE.md).

Declarative scenario specs (:mod:`.spec`), a two-mode runner (:mod:`.runner`
— numeric VirtualCluster / analytic policy evaluation), a shared JSON metrics
schema (:mod:`.metrics`) and a library of named scenarios (:mod:`.library`).

Quick use::

    from repro.scenarios import get_scenario, run_scenario
    result = run_scenario(*get_scenario("concurrent_burst"))
    print(result.summary)
    result.write("artifacts/")
"""
from repro.core.clusterview import ClusterView, FailureDomainMap, GroupDelta

from .library import SCENARIOS, get_scenario
from .metrics import MetricsCollector, ScenarioResult
from .runner import (AnalyticScenarioRunner, ClusterScenarioRunner,
                     run_scenario)
from .serve import ServeScenarioRunner, ServeWorkload, run_serve_scenario
from .spec import (AnalyticWorkload, ClusterWorkload, Scenario,
                   node_shrink_cells)

__all__ = [
    "AnalyticScenarioRunner", "AnalyticWorkload", "ClusterScenarioRunner",
    "ClusterView", "ClusterWorkload", "FailureDomainMap", "GroupDelta",
    "MetricsCollector", "SCENARIOS", "Scenario", "ScenarioResult",
    "ServeScenarioRunner", "ServeWorkload", "get_scenario",
    "node_shrink_cells", "run_scenario", "run_serve_scenario",
]
