"""Trace-driven elastic-scenario engine (see docs/ARCHITECTURE.md).

Declarative scenario specs (:mod:`.spec`), a two-mode runner (:mod:`.runner`
— numeric VirtualCluster / analytic policy evaluation), a shared JSON metrics
schema (:mod:`.metrics`) and a library of named scenarios (:mod:`.library`).

Quick use::

    from repro.scenarios import get_scenario, run_scenario
    result = run_scenario(*get_scenario("concurrent_burst"))
    print(result.summary)
    result.write("artifacts/")
"""
from repro.core.clusterview import ClusterView, FailureDomainMap, GroupDelta

from .fuzz import (CHAOS_CLASSES, ChaosCase, DetectionChaosRunner, FuzzCase,
                   POLICY_NAMES, make_analytic_case, make_case,
                   make_chaos_case, make_cluster_case, make_pallas_case,
                   make_policy, run_case, run_chaos_case, run_detector_chaos,
                   shrink_case, trace_is_legal)
from .library import SCENARIOS, get_scenario
from .metrics import MetricsCollector, ScenarioResult
from .runner import (AnalyticScenarioRunner, ClusterScenarioRunner,
                     run_scenario)
from .serve import ServeScenarioRunner, ServeWorkload, run_serve_scenario
from .spec import (AnalyticWorkload, ClusterWorkload, Scenario,
                   node_shrink_cells, validate_event_legality)

__all__ = [
    "AnalyticScenarioRunner", "AnalyticWorkload", "CHAOS_CLASSES",
    "ChaosCase", "ClusterScenarioRunner", "ClusterView", "ClusterWorkload",
    "DetectionChaosRunner", "FailureDomainMap", "FuzzCase", "GroupDelta",
    "MetricsCollector", "POLICY_NAMES", "SCENARIOS", "Scenario",
    "ScenarioResult", "ServeScenarioRunner", "ServeWorkload", "get_scenario",
    "make_analytic_case", "make_case", "make_chaos_case", "make_cluster_case",
    "make_pallas_case",
    "make_policy", "node_shrink_cells", "run_case", "run_chaos_case",
    "run_detector_chaos", "run_scenario", "run_serve_scenario", "shrink_case",
    "trace_is_legal", "validate_event_legality",
]
