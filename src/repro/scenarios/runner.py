"""Trace-driven ScenarioRunner: one engine for every elasticity experiment.

Two execution modes share the :class:`~repro.scenarios.metrics.MetricsCollector`
artifact schema:

* :class:`ClusterScenarioRunner` — drives a real
  :class:`~repro.core.cluster.VirtualCluster` step by step.  At each step the
  scenario's due events go through the paper's full recovery path
  (``Agent``-shaped event -> ``ScheduleEngine.plan`` -> executor inside
  ``VirtualCluster.apply_event``/``apply_plan``), then one real training step
  runs.  Records: loss, simulated step time, throughput, DP width, itemized
  MTTR per recovery — the substrate for convergence-consistency checks.

* :class:`AnalyticScenarioRunner` — evaluates paper-scale workloads through a
  recovery *policy* (ElasWave / ReCycle / TorchFT) plus the cost models,
  without training numerics.  The runner walks the event timeline, mutates
  the cluster view (alive / slow / freq), re-decides after every event
  boundary, and integrates throughput over intervals, optionally charging an
  MTTR penalty per capacity change (spot-trace replays).  It additionally
  accounts the data-plane alternatives at every shrink/grow: communicator
  edit vs partial vs full rebuild seconds, and — for directed MIGRATE
  probes — blocking vs non-blocking migration stall, which is how the MTTR
  micro-benchmarks ride the same engine.

``run_scenario`` picks the mode from the workload type.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

import numpy as np

from repro.core.clusterview import GroupDelta
from repro.core.communicator import DynamicCommunicator, build_hybrid_groups
from repro.core.events import ElasticEvent, EventKind
from repro.core.migration import MigrationSpec, migration_timing

from .metrics import MetricsCollector, ScenarioResult
from .spec import AnalyticWorkload, ClusterWorkload, Scenario


class ClusterScenarioRunner:
    """Numeric mode: scenario events against a live VirtualCluster.

    ``checkers`` — a list of :class:`repro.core.invariants.InvariantChecker`
    hooks, called after every event application and every training step, so
    the paper's consistency guarantees are asserted at each point of the
    trace rather than only at the end.
    """

    def __init__(self, scenario: Scenario, workload: ClusterWorkload, *,
                 checkers=()):
        self.scenario = scenario
        self.workload = workload
        self.checkers = list(checkers)

    def run(self) -> ScenarioResult:
        m = MetricsCollector()
        cl = self.workload.make_cluster()
        for c in self.checkers:
            c.on_cluster_start(self, cl)
        gb = self.workload.global_batch
        for step in range(self.scenario.horizon):
            for ev in self.scenario.events_at(step):
                rec = cl.apply_event(ev)
                m.record_recovery(step, ev, rec)
                for c in self.checkers:
                    c.after_cluster_event(step, ev, cl, rec)
            loss = cl.train_step()
            for c in self.checkers:
                c.after_cluster_step(step, cl, loss)
            t = cl.simulate_step_time()
            widths = [int(cl.alive[:, p].sum()) for p in range(cl.pp)]
            m.record_step(step, loss=float(loss), step_time=float(t),
                          throughput=gb / t, dp_width=int(min(widths)),
                          alive=int(cl.alive.sum()))
        losses = [s["loss"] for s in m.steps]
        summary = {
            "first_loss": losses[0] if losses else None,
            "final_loss": losses[-1] if losses else None,
            "n_recoveries": len(m.recoveries),
            "mttr_total": sum(r["mttr"].get("total", 0.0)
                              for r in m.recoveries),
            "final_step_time": m.steps[-1]["step_time"] if m.steps else None,
        }
        res = m.result(self.scenario, "cluster", self.workload.describe(),
                       summary)
        res.summary["losses"] = losses    # convergence-consistency record
        return res


class AnalyticScenarioRunner:
    """Policy mode: paper-scale what-if evaluation with MTTR accounting."""

    def __init__(self, scenario: Scenario, workload: AnalyticWorkload,
                 policy, *, reference_policy=None,
                 mttr_model: Optional[Dict[str, float]] = None,
                 zero_layout: str = "interleaved",
                 blocking_migration: bool = False,
                 account_communicator: bool = True,
                 comm_factory=DynamicCommunicator,
                 checkers=()):
        self.scenario = scenario
        self.workload = workload
        self.policy = policy
        self.reference_policy = reference_policy
        self.mttr_model = mttr_model or {}
        self.zero_layout = zero_layout
        self.blocking_migration = blocking_migration
        self.account_communicator = account_communicator
        # injection point for the dict/set oracle
        # (core.legacy_comm.LegacyDynamicCommunicator) in equivalence tests
        self.comm_factory = comm_factory
        # repro.core.invariants.InvariantChecker hooks, fired after every
        # event application and every decision boundary
        self.checkers = list(checkers)

    # -- data-plane accounting --------------------------------------------
    def delta_for_event(self, ev: ElasticEvent) -> GroupDelta:
        """The group-membership delta this runner's accounting applies for
        ``ev`` — shared with the MTTR invariant checker so its
        legacy-communicator oracle replays the exact same delta sequence."""
        if ev.is_grow:
            return GroupDelta.grow(
                [(f"dp_stage{r % self.workload.pp}_tp0", r)
                 for r in ev.ranks])
        return GroupDelta.shrink(list(ev.ranks))

    def _communicator_accounting(self, comm: DynamicCommunicator,
                                 ev: ElasticEvent) -> Dict[str, float]:
        """Price the three recovery modes from identical pre-event state
        (``price`` is pure — no clones), then commit the in-place edit
        (ElasWave's choice) to ``comm``."""
        delta = self.delta_for_event(ev)
        if ev.is_grow:
            return {"edit_seconds": comm.apply(delta, "edit").seconds}
        part = comm.price(delta, "partial_rebuild").seconds
        full = comm.price(delta, "full_rebuild").seconds
        edit = comm.apply(delta, "edit").seconds
        return {"edit_seconds": edit, "partial_rebuild_seconds": part,
                "full_rebuild_seconds": full}

    def _migration_accounting(self, seg, ev: ElasticEvent) -> Dict[str, float]:
        """Stall seconds of a directed migration under this runner's layout /
        blocking config, against one step's compute window."""
        w = self.workload
        L = w.cfg.num_layers
        fl = seg.seg_fwd_flops(0, L // w.pp - 1, w.mbs) * 3
        window = fl / (w.hw.peak_flops * w.hw.mfu) * w.num_micro
        pbytes = int(sum(seg.param_bytes[l] for l in ev.layers))
        obytes = int(sum(seg.opt_bytes[l] for l in ev.layers))
        spec = MigrationSpec(tuple(ev.layers), ev.src_stage, ev.dst_stage,
                             pbytes, obytes, dp=w.dp,
                             zero_layout=self.zero_layout,
                             blocking=self.blocking_migration)
        t = migration_timing(spec, w.hw.link_bw, window)
        return {"stall_seconds": t.stall_seconds,
                "param_seconds": t.param_seconds,
                "opt_seconds": t.opt_seconds,
                "overlapped_seconds": t.overlapped_seconds,
                "n_layers": len(ev.layers)}

    # -- main loop ---------------------------------------------------------
    def _decide(self, seg, view):
        t0 = time.perf_counter()
        d = self.policy.decide(seg, view.copy())
        wall = time.perf_counter() - t0
        thr = (self.workload.global_batch / d.step_time
               if d.feasible and np.isfinite(d.step_time) else 0.0)
        return d, thr, wall

    def run(self) -> ScenarioResult:
        w = self.workload
        m = MetricsCollector()
        seg = w.build_seg()
        # one persistent rank-vectorized view; every burst is applied as a
        # single fancy-indexed array op (no per-rank dict surgery)
        view = w.build_view(seg)
        comm = self.comm_factory(build_hybrid_groups(w.dp, w.pp))

        ref = self.reference_policy or self.policy
        base = ref.decide(seg, w.build_view(seg))
        thr0 = w.global_batch / base.step_time

        for c in self.checkers:
            c.on_analytic_start(self, seg, view, comm)

        boundaries = sorted({0} | set(self.scenario.event_steps))
        total_samples = 0.0
        decision = None
        for i, t in enumerate(boundaries):
            charge = 0.0
            for ev in self.scenario.events_at(t):
                extra: Dict = {}
                mttr: Dict[str, float] = {}
                if ev.kind == EventKind.MIGRATE:
                    mig = self._migration_accounting(seg, ev)
                    mttr = {"migration": mig["stall_seconds"],
                            "total": mig["stall_seconds"]}
                    extra["migration"] = mig
                else:
                    view.apply_elastic(ev)
                    if self.account_communicator and (ev.is_shrink or ev.is_grow):
                        comm_acct = self._communicator_accounting(comm, ev)
                        extra["communicator"] = comm_acct
                        mttr["communicator"] = comm_acct["edit_seconds"]
                    paid = self.mttr_model.get(
                        getattr(self.policy, "name", "")) \
                        if t > 0 and (ev.is_shrink or ev.is_grow) else None
                    if paid is not None:   # capacity change mid-run pays MTTR
                        charge = paid
                        mttr["total"] = paid
                    else:
                        mttr["total"] = sum(mttr.values())
                m.record_recovery(t, ev, mttr, **extra)
                for c in self.checkers:
                    c.after_analytic_event(t, ev, view, comm, extra)
            decision, thr, wall = self._decide(seg, view)
            for c in self.checkers:
                c.after_analytic_decision(t, view, decision, thr, thr0)
            end = boundaries[i + 1] if i + 1 < len(boundaries) else \
                self.scenario.horizon
            dur = end - t
            total_samples += thr * max(dur - charge, 0)
            m.record_step(t, duration=dur, rel_throughput=thr / thr0,
                          step_time=float(decision.step_time),
                          feasible=bool(decision.feasible),
                          policy=getattr(self.policy, "name", "?"),
                          mttr_charged=charge,
                          decide_wall_seconds=wall)
        horizon = max(self.scenario.horizon, 1)
        summary = {
            "policy": getattr(self.policy, "name", "?"),
            "time_avg_rel_throughput": total_samples / horizon / thr0,
            "final_rel_throughput": m.steps[-1]["rel_throughput"]
            if m.steps else None,
            "final_feasible": m.steps[-1]["feasible"] if m.steps else None,
            "n_events": len(self.scenario.events),
        }
        if decision is not None:
            summary["final_decision_detail"] = {
                k: v for k, v in decision.detail.items()
                if isinstance(v, (int, float, bool, str))}
        return m.result(self.scenario, "analytic", w.describe(), summary)


def run_scenario(scenario: Scenario, workload, **kw) -> ScenarioResult:
    """Mode is inferred from the workload type."""
    if isinstance(workload, ClusterWorkload):
        return ClusterScenarioRunner(scenario, workload, **kw).run()
    if isinstance(workload, AnalyticWorkload):
        return AnalyticScenarioRunner(scenario, workload, **kw).run()
    raise TypeError(f"unknown workload type: {type(workload)!r}")
