"""Step functions + input specs for every (arch x shape) dry-run cell.

Cell kinds:
  train_*   -> train_step(params, opt_state, batch) -> (params, opt_state, loss)
  prefill_* -> prefill_step(params, caches, tokens[, extras]) -> (logits, caches)
  decode_* / long_* -> decode_step(params, caches, tokens, index[, extras])

Everything lowers from ShapeDtypeStructs — no allocation at full scale.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import registry as R
from repro.models import transformer as T
from repro.models import encdec as E
from repro.models.config import ModelConfig
from repro.optim.adam import AdamConfig, adam_update, init_opt_state, opt_state_shapes
from repro.parallel import sharding as S


@dataclasses.dataclass
class Cell:
    kind: str                       # "train" | "prefill" | "decode"
    fn: Callable
    arg_shapes: Tuple[Any, ...]     # ShapeDtypeStruct pytrees
    arg_pspecs: Tuple[Any, ...]     # PartitionSpec pytrees
    out_pspecs: Any
    donate: Tuple[int, ...]


def shape_kind(shape_name: str) -> str:
    if shape_name.startswith("train"):
        return "train"
    if shape_name.startswith("prefill"):
        return "prefill"
    return "decode"


def _batch_shapes(cfg: ModelConfig, batch: int, seq: int) -> Dict[str, Any]:
    sh: Dict[str, Any] = {
        "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
    }
    if cfg.is_encdec:
        sh["frames"] = jax.ShapeDtypeStruct(
            (batch, cfg.max_source_positions, cfg.d_model), cfg.jnp_dtype)
    if cfg.frontend_embeds:
        sh["prefix_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.frontend_embeds, cfg.d_model), cfg.jnp_dtype)
    return sh


def build_cell(cfg: ModelConfig, shape_name: str, seq: int, batch: int,
               mesh, adam: Optional[AdamConfig] = None,
               remat: bool = True) -> Cell:
    kind = shape_kind(shape_name)
    adam = adam or AdamConfig()
    params_sh = R.model_param_shapes(cfg)
    pspec_params = S.param_pspecs(cfg, mesh, params_sh)

    if kind == "train":
        batch_sh = _batch_shapes(cfg, batch, seq)
        opt_sh = opt_state_shapes(params_sh, adam)
        pspec_opt = jax.tree.map(
            lambda _: None, opt_sh)  # replaced below: mirror params rules
        pspec_opt = _opt_pspecs_like(params_sh, pspec_params, opt_sh)
        pspec_batch = S.batch_pspecs(cfg, mesh, batch_sh)
        loss_fn = R.make_train_loss(cfg, remat=remat)

        def train_step(params, opt_state, batch_):
            loss, grads = jax.value_and_grad(loss_fn)(params, batch_)
            new_params, new_opt = adam_update(params, grads, opt_state, adam)
            return new_params, new_opt, loss

        from jax.sharding import PartitionSpec as P
        return Cell("train", train_step,
                    (params_sh, opt_sh, batch_sh),
                    (pspec_params, pspec_opt, pspec_batch),
                    (pspec_params, pspec_opt, P()),
                    donate=(0, 1))

    # serving cells
    if cfg.is_encdec:
        return _encdec_serving_cell(cfg, kind, seq, batch, mesh,
                                    params_sh, pspec_params)
    max_len = seq
    caches_sh = T.cache_shapes(cfg, batch, max_len)
    pspec_caches = S.cache_pspecs(cfg, mesh, caches_sh)
    from jax.sharding import PartitionSpec as P
    if kind == "prefill":
        tok_sh = jax.ShapeDtypeStruct((batch, seq), jnp.int32)

        def prefill_step(params, caches, tokens):
            return T.prefill(params, cfg, tokens, caches)

        return Cell("prefill", prefill_step,
                    (params_sh, caches_sh, tok_sh),
                    (pspec_params, pspec_caches, S._spec(mesh, (batch, seq),
                                                         S.dp_axes(mesh), None)),
                    (P(), pspec_caches),
                    donate=(1,))

    tok_sh = jax.ShapeDtypeStruct((batch, 1), jnp.int32)
    idx_sh = jax.ShapeDtypeStruct((), jnp.int32)

    def decode_step(params, caches, tokens, index):
        return T.decode_step(params, cfg, tokens, caches, index)

    return Cell("decode", decode_step,
                (params_sh, caches_sh, tok_sh, idx_sh),
                (pspec_params, pspec_caches,
                 S._spec(mesh, (batch, 1), S.dp_axes(mesh), None), P()),
                (P(), pspec_caches),
                donate=(1,))


def _encdec_serving_cell(cfg, kind, seq, batch, mesh, params_sh, pspec_params):
    from jax.sharding import PartitionSpec as P
    caches_sh = jax.eval_shape(lambda: E.init_decoder_caches(cfg, batch, seq))
    pspec_caches = S.cache_pspecs(cfg, mesh, caches_sh)
    enc_sh = jax.ShapeDtypeStruct((batch, cfg.max_source_positions, cfg.d_model),
                                  cfg.jnp_dtype)
    enc_spec = S._spec(mesh, enc_sh.shape, S.dp_axes(mesh), None, None)
    if kind == "prefill":
        tok_sh = jax.ShapeDtypeStruct((batch, seq), jnp.int32)

        def prefill_step(params, caches, tokens, frames):
            enc = E.encode(params, cfg, frames)
            logits, caches = E.decode(params, cfg, tokens, enc,
                                      caches=caches, cache_index=0)
            return logits[:, -1:, :], caches

        frames_sh = jax.ShapeDtypeStruct(
            (batch, cfg.max_source_positions, cfg.d_model), cfg.jnp_dtype)
        return Cell("prefill", prefill_step,
                    (params_sh, caches_sh, tok_sh, frames_sh),
                    (pspec_params, pspec_caches,
                     S._spec(mesh, (batch, seq), S.dp_axes(mesh), None), enc_spec),
                    (P(), pspec_caches), donate=(1,))

    tok_sh = jax.ShapeDtypeStruct((batch, 1), jnp.int32)
    idx_sh = jax.ShapeDtypeStruct((), jnp.int32)

    def decode_step(params, caches, tokens, enc_out, index):
        return E.encdec_decode_step(params, cfg, tokens, enc_out, caches, index)

    return Cell("decode", decode_step,
                (params_sh, caches_sh, tok_sh, enc_sh, idx_sh),
                (pspec_params, pspec_caches,
                 S._spec(mesh, (batch, 1), S.dp_axes(mesh), None),
                 enc_spec, P()),
                (P(), pspec_caches), donate=(1,))


def _opt_pspecs_like(params_sh, pspec_params, opt_sh):
    """Adam leaves {mu, nu, master} share their param's PartitionSpec; the
    scalar step is replicated."""
    from jax.sharding import PartitionSpec as P

    leaves_spec = jax.tree.map(
        lambda spec: {"mu": spec, "nu": spec, "master": spec},
        pspec_params, is_leaf=lambda x: isinstance(x, P))
    return {"leaves": leaves_spec, "step": P()}
