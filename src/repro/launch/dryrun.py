"""Multi-pod dry-run driver — see ``_DOC`` below for the full usage text
(kept separate because the XLA device-count env var must be set before any
jax import, and the argparse help reuses it)."""
import os
os.environ["XLA_FLAGS"] = os.environ.get("DRYRUN_XLA_FLAGS",
                                         "--xla_force_host_platform_device_count=512")
# ^ MUST precede any jax import: jax locks the device count on first init.

_DOC = """Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell:
  with mesh:
      lowered = jax.jit(step, in_shardings=..., out_shardings=...,
                        donate_argnums=...).lower(*input_specs)
      compiled = lowered.compile()
      memory_analysis / cost_analysis -> artifact JSON

Artifacts land in artifacts/dryrun/<arch>__<shape>__<mesh>.json and feed
benchmarks/roofline.py and EXPERIMENTS.md §Dry-run.

Usage:
  python -m repro.launch.dryrun --arch llama3_405b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--jobs 4]
"""

import argparse
import json
import subprocess
import sys
import time
from pathlib import Path

ART_DIR = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

MODEL_FLOPS_NOTE = ("MODEL_FLOPS = 6*N*D dense / 6*N_active*D MoE "
                    "(train); 2*N*D serving fwd")


def _layer_variants(cfg):
    """Two reduced-depth variants (L1, L2) whose cost difference isolates one
    repeat unit of the scanned segments — used to undo XLA's count-scan-body-
    once cost analysis by exact linear extrapolation to the full depth."""
    import dataclasses as _dc
    if cfg.is_encdec:
        c1 = _dc.replace(cfg, num_layers=1, encoder_layers=1, decoder_layers=1,
                         scan_layers=False)
        c2 = _dc.replace(cfg, num_layers=2, encoder_layers=2, decoder_layers=2,
                         scan_layers=False)
        return c1, c2, 1, 2, cfg.encoder_layers or cfg.num_layers
    period = 1
    if cfg.family == "hybrid":
        period = cfg.attn_period
    L1 = cfg.first_k_dense + period
    L2 = cfg.first_k_dense + 2 * period
    c1 = _dc.replace(cfg, num_layers=L1, scan_layers=False)
    c2 = _dc.replace(cfg, num_layers=L2, scan_layers=False)
    return c1, c2, L1, L2, cfg.num_layers


def _compile_cell(cfg, shape_name, seq, batch, mesh, remat=True):
    import jax
    from repro.launch.steps import build_cell
    from repro.parallel.sharding import to_shardings
    cell = build_cell(cfg, shape_name, seq, batch, mesh, remat=remat)
    in_sh = tuple(to_shardings(mesh, p) for p in cell.arg_pspecs)
    out_sh = to_shardings(mesh, cell.out_pspecs)
    with mesh:
        lowered = jax.jit(cell.fn, in_shardings=in_sh, out_shardings=out_sh,
                          donate_argnums=cell.donate).lower(*cell.arg_shapes)
        compiled = lowered.compile()
    return lowered, compiled


def _cell_costs(compiled):
    from repro.launch import hlo_analysis as H
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    coll = H.collective_bytes(compiled.as_text())
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "coll": coll["total"],
            "coll_by_kind": coll}


def extrapolated_costs(cfg, shape_name, seq, batch, mesh, remat=True):
    """Per-device (flops, bytes, collective bytes) at FULL depth, by linear
    extrapolation over two reduced-depth compiles (scan bodies are counted
    once by XLA's cost analysis; depth enters linearly)."""
    c1, c2, L1, L2, Lf = _layer_variants(cfg)
    _, k1 = _compile_cell(c1, shape_name, seq, batch, mesh, remat=remat)
    _, k2 = _compile_cell(c2, shape_name, seq, batch, mesh, remat=remat)
    a, b = _cell_costs(k1), _cell_costs(k2)
    out = {}
    for key in ("flops", "bytes", "coll"):
        delta = (b[key] - a[key]) / (L2 - L1)
        out[key] = a[key] + delta * (Lf - L1)
    out["coll_by_kind"] = {
        k: a["coll_by_kind"][k] + (b["coll_by_kind"][k] - a["coll_by_kind"][k])
        / (L2 - L1) * (Lf - L1)
        for k in a["coll_by_kind"]}
    return out


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             overrides: dict | None = None) -> dict:
    import jax
    import numpy as np
    from repro import configs
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import build_cell, shape_kind
    from repro.launch import hlo_analysis as H
    from repro.parallel.sharding import to_shardings

    cfg = configs.get_config(arch)
    shapes = {n: (s, b) for n, s, b in cfg.shapes}
    skip = {n: why for n, why in cfg.skip_shapes}
    if shape_name in skip:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped", "reason": skip[shape_name]}
    seq, batch = shapes[shape_name]
    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    remat = True
    if overrides:
        import dataclasses as _dc
        overrides = dict(overrides)
        remat = overrides.pop("remat", True)
        if overrides:
            cfg = _dc.replace(cfg, **overrides)
    cell = build_cell(cfg, shape_name, seq, batch, mesh, remat=remat)
    in_sh = tuple(to_shardings(mesh, p) for p in cell.arg_pspecs)
    out_sh = to_shardings(mesh, cell.out_pspecs)
    with mesh:
        lowered = jax.jit(cell.fn, in_shardings=in_sh, out_shardings=out_sh,
                          donate_argnums=cell.donate).lower(*cell.arg_shapes)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    # ---- analyses ----
    try:
        mem = compiled.memory_analysis()
        mem_d = {k: int(getattr(mem, k)) for k in
                 ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes")
                 if hasattr(mem, k)}
    except Exception as e:   # pragma: no cover
        mem_d = {"error": str(e)}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        cost = {k: float(v) for k, v in ca.items()
                if k in ("flops", "bytes accessed", "transcendentals",
                         "optimal_seconds")}
    except Exception as e:   # pragma: no cover
        cost = {"error": str(e)}
    # NOTE: cost_analysis() and the compiled HLO are PER-DEVICE after SPMD
    # partitioning (verified empirically) — so the roofline denominators are
    # per-chip rates (chips=1); the formulas in the spec are equivalent with
    # HLO_FLOPs_global = per_device * chips.  XLA counts scan bodies ONCE, so
    # depth-dependent costs come from two-point extrapolation over reduced
    # depths (exact: depth enters linearly).
    ext = extrapolated_costs(cfg, shape_name, seq, batch, mesh, remat=remat)
    flops = ext["flops"]
    bytes_acc = ext["bytes"]
    coll = ext["coll_by_kind"]
    terms = H.roofline_terms(flops, bytes_acc, ext["coll"], chips=1)

    # model flops (useful-work denominator)
    kind = shape_kind(shape_name)
    n_active = cfg.active_param_count()
    tokens = batch * seq if kind != "decode" else batch
    model_flops = (6 if kind == "train" else 2) * n_active * tokens

    # analytic per-chip state footprint
    n_total = cfg.param_count()
    state_bytes = n_total * (2 + 12 if kind == "train" else 2)
    art = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind, "chips": chips,
        "status": "ok", "kind": kind,
        "seq": seq, "batch": batch,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory_analysis": mem_d,
        "cost_analysis": cost,
        "collective_bytes": coll,
        "roofline": terms,
        "model_flops": model_flops,
        "hlo_flops_per_device": flops,
        "hlo_flops_global": flops * chips,
        "useful_fraction": model_flops / (flops * chips) if flops else None,
        "params_total": n_total, "params_active": n_active,
        "state_bytes_per_chip": state_bytes / chips,
        "note": MODEL_FLOPS_NOTE,
    }
    return art


def cell_list(mesh_kinds):
    from repro import configs
    cells = []
    for arch in configs.ARCH_IDS:
        cfg = configs.get_config(arch)
        for (name, _, _) in cfg.shapes:
            for mk in mesh_kinds:
                cells.append((arch, name, mk))
    return cells


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--jobs", type=int, default=4)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--override", action="append", default=[],
                    help="cfg field override key=value (perf iterations); "
                         "also accepts remat=false")
    ap.add_argument("--tag", default=None,
                    help="artifact tag: writes to artifacts/perf/ instead")
    args = ap.parse_args()
    ART_DIR.mkdir(parents=True, exist_ok=True)

    mesh_kinds = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if not args.all:
        assert args.arch and args.shape
        overrides = {}
        for ov in args.override:
            k, v = ov.split("=", 1)
            if v.lower() in ("true", "false"):
                v = v.lower() == "true"
            else:
                try:
                    v = int(v)
                except ValueError:
                    try:
                        v = float(v)
                    except ValueError:
                        pass
            overrides[k] = v
        art = run_cell(args.arch, args.shape, mesh_kinds[0],
                       overrides=overrides or None)
        if args.tag:
            art["tag"] = args.tag
            art["overrides"] = {k: str(v) for k, v in overrides.items()}
            pdir = ART_DIR.parent / "perf"
            pdir.mkdir(parents=True, exist_ok=True)
            out = pdir / (f"{args.arch}__{args.shape}__{mesh_kinds[0]}"
                          f"__{args.tag}.json")
        else:
            out = ART_DIR / f"{args.arch}__{args.shape}__{mesh_kinds[0]}.json"
        out.write_text(json.dumps(art, indent=2))
        print(json.dumps(art, indent=2))
        if art["status"] == "ok":
            print(f"OK {args.arch} {args.shape} {mesh_kinds[0]} "
                  f"bottleneck={art['roofline']['bottleneck']}")
        return

    # orchestrate subprocesses (each needs its own 512-device jax runtime)
    cells = cell_list(mesh_kinds)
    pending = []
    for (arch, shape, mk) in cells:
        out = ART_DIR / f"{arch}__{shape}__{mk}.json"
        if out.exists() and not args.force:
            continue
        pending.append((arch, shape, mk, out))
    print(f"{len(pending)} cells to run ({len(cells) - len(pending)} cached)")
    procs = []

    def launch(arch, shape, mk, out):
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape, "--mesh", mk]
        log = out.with_suffix(".log").open("w")
        return subprocess.Popen(cmd, stdout=log, stderr=subprocess.STDOUT), \
            (arch, shape, mk, out)

    i = 0
    while i < len(pending) or procs:
        while i < len(pending) and len(procs) < args.jobs:
            procs.append(launch(*pending[i])); i += 1
        done = [p for p in procs if p[0].poll() is not None]
        for p, meta in done:
            procs.remove((p, meta))
            status = "OK" if meta[3].exists() else f"FAIL(rc={p.returncode})"
            print(f"[{status}] {meta[0]} {meta[1]} {meta[2]}", flush=True)
        time.sleep(1.0)


if __name__ == "__main__":
    main()
