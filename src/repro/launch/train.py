"""Training launcher: real single-host training on a reduced config, or
--dryrun lowering of the full config on the production mesh.

    PYTHONPATH=src python -m repro.launch.train --arch nemotron_4_15b \
        --smoke --steps 20
    PYTHONPATH=src python -m repro.launch.train --arch llama3_405b --dryrun
"""
from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="train the reduced config on CPU")
    ap.add_argument("--dryrun", action="store_true",
                    help="lower+compile the full config on the 16x16 mesh")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    if args.dryrun:
        import os
        import subprocess
        import sys
        shape = "train_4k"
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", args.arch,
               "--shape", shape,
               "--mesh", "multi" if args.multi_pod else "single"]
        raise SystemExit(subprocess.call(cmd))

    import jax
    import numpy as np
    from repro import configs
    from repro.data.pipeline import GlobalBatchSampler, make_batch
    from repro.models import registry as R
    from repro.optim.adam import AdamConfig, adam_update, init_opt_state

    cfg = configs.get_smoke_config(args.arch) if args.smoke else \
        configs.get_config(args.arch)
    print(f"training {cfg.name}: {cfg.param_count() / 1e6:.1f}M params")
    params = R.init_model(jax.random.key(0), cfg)
    adam = AdamConfig(lr=1e-3)
    opt = init_opt_state(params, adam)
    loss_fn = R.make_train_loss(cfg)

    @jax.jit
    def step_fn(params, opt, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt = adam_update(params, grads, opt, adam)
        return params, opt, loss

    ckpt = None
    if args.ckpt_dir:
        from repro.checkpoint import CheckpointManager
        ckpt = CheckpointManager(args.ckpt_dir)

    sampler = GlobalBatchSampler(args.batch)
    t0 = time.time()
    for step in range(args.steps):
        batch = make_batch(sampler.sample_ids(step), args.seq, cfg.vocab_size)
        if cfg.is_encdec:
            batch["frames"] = jax.random.normal(
                jax.random.fold_in(jax.random.key(9), step),
                (args.batch, args.seq, cfg.d_model))
        if cfg.frontend_embeds:
            batch["prefix_embeds"] = jax.random.normal(
                jax.random.fold_in(jax.random.key(9), step),
                (args.batch, cfg.frontend_embeds, cfg.d_model))
        params, opt, loss = step_fn(params, opt, batch)
        if step % 5 == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss={float(loss):.4f} "
                  f"({(time.time() - t0) / (step + 1) * 1e3:.0f} ms/step)")
        if ckpt and step % 10 == 9:
            ckpt.save(step, params, opt, blocking=False)
    if ckpt:
        ckpt.wait()
        print(f"checkpoints: {sorted(p.name for p in ckpt.dir.glob('step_*'))}")


if __name__ == "__main__":
    main()
