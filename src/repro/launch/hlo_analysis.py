"""Roofline-term extraction from a lowered/compiled cell.

compute term    = HLO_FLOPs / (chips * peak)
memory term     = HLO_bytes / (chips * hbm_bw)
collective term = collective_bytes / (chips * link_bw)

HLO_FLOPs / bytes come from compiled.cost_analysis().  Collective bytes are
NOT in cost_analysis: we parse the compiled HLO text and sum *operand* sizes
of all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute,
deriving operand size from the printed result shape and replica-group size
(all-gather result = operand x G; reduce-scatter result = operand / G).
"""
from __future__ import annotations

import math
import re
from typing import Dict, Optional

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_INSTR = re.compile(
    r"=\s+(?:\()?([a-z0-9]+)\[([\d,]*)\][^\s]*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)
_TUPLE_INSTR = re.compile(
    r"=\s+\(((?:[a-z0-9]+\[[\d,]*\][^,)]*(?:,\s*)?)+)\)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)
_GROUPS_LIST = re.compile(r"replica_groups=\{(.*?)\}\}?", re.S)
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_SHAPE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST.search(line)
    if m:
        first = m.group(1).split("}")[0].strip("{} ")
        if first:
            return len([x for x in first.split(",") if x.strip() != ""])
    return 1


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum operand bytes per collective kind over the whole module."""
    out: Dict[str, float] = {k: 0.0 for k in COLLECTIVES}
    seen_done = set()
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue    # async pair: count only the -start
        m = _INSTR.search(line)
        shapes = []
        kind = None
        if m:
            shapes = [(m.group(1), m.group(2))]
            kind = m.group(3)
        else:
            mt = _TUPLE_INSTR.search(line)
            if mt:
                kind = mt.group(2)
                shapes = _SHAPE.findall(mt.group(1))
        if not kind:
            continue
        g = _group_size(line)
        result = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        if kind == "all-gather":
            operand = result / max(g, 1)
        elif kind == "reduce-scatter":
            operand = result * max(g, 1)
        else:
            operand = result
        out[kind] += operand
    out["total"] = sum(out[k] for k in COLLECTIVES)
    return out


def roofline_terms(flops: float, bytes_accessed: float, coll_bytes: float,
                   chips: int, peak_flops: float = 197e12,
                   hbm_bw: float = 819e9, link_bw: float = 50e9,
                   ) -> Dict[str, float]:
    compute = flops / (chips * peak_flops)
    memory = bytes_accessed / (chips * hbm_bw)
    collective = coll_bytes / (chips * link_bw)
    dom = max(("compute", compute), ("memory", memory),
              ("collective", collective), key=lambda kv: kv[1])
    return {
        "compute_s": compute, "memory_s": memory, "collective_s": collective,
        "bottleneck": dom[0],
        "roofline_s": max(compute, memory, collective),
    }
