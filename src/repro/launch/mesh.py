"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state; the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax use.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    types = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, axis_types=types)


def make_mesh(shape, axes):
    types = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(tuple(shape), tuple(axes), axis_types=types)
