"""Serving launcher: batched prefill + decode on a reduced config, or
--dryrun lowering of the full config's serving cells on the production mesh.

    PYTHONPATH=src python -m repro.launch.serve --arch codeqwen1p5_7b --smoke
    PYTHONPATH=src python -m repro.launch.serve --arch deepseek_v3_671b \
        --dryrun --shape decode_32k [--multi-pod]
"""
from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--dryrun", action="store_true")
    ap.add_argument("--shape", default="decode_32k",
                    choices=["prefill_32k", "decode_32k", "long_500k"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args()

    if args.dryrun:
        import subprocess
        import sys
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", args.arch,
               "--shape", args.shape,
               "--mesh", "multi" if args.multi_pod else "single"]
        raise SystemExit(subprocess.call(cmd))

    import jax
    import jax.numpy as jnp
    from repro import configs
    from repro.models import registry as R
    from repro.models import transformer as T
    from repro.models import encdec as E

    cfg = configs.get_smoke_config(args.arch)
    params = R.init_model(jax.random.key(0), cfg)
    max_len = args.prompt_len + args.tokens
    prompts = jax.random.randint(jax.random.key(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    print(f"serving {cfg.name}: batch={args.batch} prompt={args.prompt_len} "
          f"decode={args.tokens}")
    t0 = time.time()
    if cfg.is_encdec:
        frames = jax.random.normal(jax.random.key(2),
                                   (args.batch, 16, cfg.d_model))
        enc = E.encode(params, cfg, frames)
        caches = E.init_decoder_caches(cfg, args.batch, max_len)
        logits, caches = E.decode(params, cfg, prompts, enc,
                                  caches=caches, cache_index=0)
        step = jax.jit(lambda p, c, t, i: E.encdec_decode_step(p, cfg, t, enc, c, i))
    else:
        caches = T.init_caches(cfg, args.batch, max_len)
        logits, caches = T.prefill(params, cfg, prompts, caches)
        step = jax.jit(lambda p, c, t, i: T.decode_step(p, cfg, t, c, i))
    tok = jnp.argmax(logits[:, -1:, :], axis=-1)
    print(f"prefill: {(time.time() - t0) * 1e3:.0f} ms")
    t0 = time.time()
    for i in range(args.tokens - 1):
        logits, caches = step(params, caches, tok,
                              jnp.int32(args.prompt_len + i))
        tok = jnp.argmax(logits[:, -1:, :], axis=-1)
    jax.block_until_ready(tok)
    dt = (time.time() - t0) / max(args.tokens - 1, 1)
    print(f"decode: {dt * 1e3:.1f} ms/token "
          f"({args.batch / dt:.0f} tok/s aggregate)")


if __name__ == "__main__":
    main()
