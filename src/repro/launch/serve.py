"""Serving launcher: batched generation through the elastic serving engine
on a reduced config, or --dryrun lowering of the full config's serving cells
on the production mesh.

    PYTHONPATH=src python -m repro.launch.serve --arch codeqwen1p5_7b --smoke
    PYTHONPATH=src python -m repro.launch.serve --arch deepseek_v3_671b \
        --dryrun --shape decode_32k [--multi-pod]

The smoke path is a thin wrapper over
:func:`repro.serving.offline_generate` — the same continuous-batching engine
the elastic benchmarks drive, so every family the registry lowers (enc-dec
included) serves through one code path.
"""
from __future__ import annotations

import argparse


def add_generation_args(ap: argparse.ArgumentParser):
    """Generation flags shared with examples/serve.py."""
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy; > 0 enables seeded top-k sampling")
    ap.add_argument("--top-k", type=int, default=40)
    ap.add_argument("--seed", type=int, default=0)


def run_smoke(arch: str, args) -> dict:
    """Generate through the serving engine; returns offline_generate's dict."""
    from repro import configs
    from repro.serving import SamplerConfig, offline_generate

    cfg = configs.get_smoke_config(arch)
    sampler = (SamplerConfig() if args.temperature <= 0 else
               SamplerConfig(method="topk", temperature=args.temperature,
                             top_k=args.top_k, seed=args.seed))
    print(f"serving {cfg.name}: batch={args.batch} prompt={args.prompt_len} "
          f"decode={args.tokens} sampler={sampler.describe()}")
    out = offline_generate(cfg, batch=args.batch, prompt_len=args.prompt_len,
                           max_new_tokens=args.tokens, seed=args.seed,
                           sampler=sampler)
    s = out["summary"]
    total = s["tokens_decoded"]
    print(f"generated {total} tokens in {out['wall_seconds']:.2f}s wall "
          f"({total / out['wall_seconds']:.0f} tok/s aggregate)")
    for b in range(args.batch):
        print(f"  [{b}] {out['sequences'][b][:16].tolist()}...")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--dryrun", action="store_true")
    ap.add_argument("--shape", default="decode_32k",
                    choices=["prefill_32k", "decode_32k", "long_500k"])
    ap.add_argument("--multi-pod", action="store_true")
    add_generation_args(ap)
    args = ap.parse_args()

    if args.dryrun:
        import subprocess
        import sys
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", args.arch,
               "--shape", args.shape,
               "--mesh", "multi" if args.multi_pod else "single"]
        raise SystemExit(subprocess.call(cmd))

    run_smoke(args.arch, args)


if __name__ == "__main__":
    main()
