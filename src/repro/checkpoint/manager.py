"""Disk checkpointing for cold restart (complements the in-memory per-step
snapshots: warm elastic events never touch disk — see core/fabric).

Format: one .npz per pytree (params / opt state) + a JSON manifest with step,
config digest, and integrity hashes.  Atomic via write-to-tmp + rename.
Async flavor: `save(..., blocking=False)` hands the serialized buffers to a
background thread so the train loop is not stalled (paper §8 related work —
we keep it minimal since ElasWave's point is to avoid the rollback path).
"""
from __future__ import annotations

import hashlib
import json
import os
import threading
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # -------------------------------------------------------------- save --
    def save(self, step: int, params, opt_state=None, *, blocking=True,
             extra: Optional[Dict[str, Any]] = None):
        flats = {"params": _flatten(params)}
        if opt_state is not None:
            flats["opt"] = _flatten(opt_state)

        def _write():
            ckpt = self.dir / f"step_{step:08d}"
            tmp = self.dir / f".tmp_step_{step:08d}"
            tmp.mkdir(exist_ok=True)
            manifest = {"step": step, "arrays": {}, "extra": extra or {}}
            for name, flat in flats.items():
                fn = tmp / f"{name}.npz"
                np.savez(fn, **flat)
                h = hashlib.sha256(fn.read_bytes()).hexdigest()
                manifest["arrays"][name] = {"file": f"{name}.npz", "sha256": h}
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            if ckpt.exists():
                import shutil
                shutil.rmtree(ckpt)
            os.rename(tmp, ckpt)
            self._gc()

        if blocking:
            _write()
        else:
            if self._thread is not None:
                self._thread.join()
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        ckpts = sorted(self.dir.glob("step_*"))
        for c in ckpts[:-self.keep]:
            import shutil
            shutil.rmtree(c)

    # ----------------------------------------------------------- restore --
    def latest_step(self) -> Optional[int]:
        ckpts = sorted(self.dir.glob("step_*"))
        return int(ckpts[-1].name.split("_")[1]) if ckpts else None

    def restore(self, step: Optional[int] = None, *, verify: bool = True,
                ) -> Tuple[int, Dict[str, Dict[str, np.ndarray]], Dict]:
        step = step if step is not None else self.latest_step()
        assert step is not None, "no checkpoints"
        ckpt = self.dir / f"step_{step:08d}"
        manifest = json.loads((ckpt / "manifest.json").read_text())
        out = {}
        for name, meta in manifest["arrays"].items():
            fn = ckpt / meta["file"]
            if verify:
                h = hashlib.sha256(fn.read_bytes()).hexdigest()
                if h != meta["sha256"]:
                    raise IOError(f"checkpoint corrupted: {fn}")
            with np.load(fn) as z:
                out[name] = {k: z[k] for k in z.files}
        return manifest["step"], out, manifest.get("extra", {})

    def restore_into(self, tree, flat: Dict[str, np.ndarray]):
        """Rebuild a pytree with the same structure from flattened arrays."""
        paths = jax.tree_util.tree_flatten_with_path(tree)[0]
        leaves = []
        for path, leaf in paths:
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in path)
            leaves.append(flat[key].astype(np.asarray(leaf).dtype))
        treedef = jax.tree_util.tree_structure(tree)
        return jax.tree_util.tree_unflatten(treedef, leaves)
