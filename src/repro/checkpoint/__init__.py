from .manager import CheckpointManager
