"""Checkpoint manager: durable complement to the in-memory snapshot ring."""
from .manager import CheckpointManager
