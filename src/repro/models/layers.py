"""Primitive layers shared by all model families.

Design notes
------------
* Pure-functional: ``init_*`` returns a param pytree, ``apply_*`` consumes it.
* **Content-addressed RNG** (ElasWave RNG-resharding, JAX-native): every random
  op derives its key as ``fold_in(fold_in(step_key, layer_id), sample_id)``.
  The mask depends only on (step, layer, sample) identity — never on which rank
  or micro-batch slot computes it — so any elastic re-partitioning reproduces
  bit-identical randomness.  See core/planners/rng.py.
* Attention supports GQA (kv-head broadcast) and MLA (latent KV, deepseek-v3).
* KV caches are explicit pytrees so serve_step can be jitted/lowered.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig


# --------------------------------------------------------------------------
# RNG context (content-addressed randomness)
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class RngCtx:
    """Identity-addressed randomness for computation consistency."""
    step_key: Optional[jax.Array] = None      # fold_in(base_key, step)
    sample_ids: Optional[jax.Array] = None    # [batch] global sample ids
    deterministic: bool = True

    def layer(self, layer_id: int) -> "RngCtx":
        if self.deterministic or self.step_key is None:
            return self
        return dataclasses.replace(
            self, step_key=jax.random.fold_in(self.step_key, layer_id))


jax.tree_util.register_pytree_node(
    RngCtx,
    lambda c: ((c.step_key, c.sample_ids), c.deterministic),
    lambda det, xs: RngCtx(xs[0], xs[1], det),
)


def dropout(x: jax.Array, rate: float, ctx: RngCtx, op_id: int = 0) -> jax.Array:
    """Per-sample content-addressed dropout. x: [batch, seq, ...]."""
    if ctx.deterministic or rate <= 0.0 or ctx.step_key is None:
        return x
    key = jax.random.fold_in(ctx.step_key, op_id)

    def mask_one(sid):
        k = jax.random.fold_in(key, sid)
        return jax.random.bernoulli(k, 1.0 - rate, x.shape[1:])

    keep = jax.vmap(mask_one)(ctx.sample_ids)
    return jnp.where(keep, x / (1.0 - rate), 0.0).astype(x.dtype)


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------
def init_rmsnorm(d: int, dtype) -> Dict[str, Any]:
    return {"scale": jnp.ones((d,), dtype=jnp.float32)}


def rmsnorm(params, x, eps: float = 1e-5, use_pallas: bool = False):
    if use_pallas:
        from repro.kernels import ops as kops
        return kops.rmsnorm(x, params["scale"], eps=eps)
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * params["scale"]
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., seq, heads, head_dim]; positions: [..., seq]."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # [hd/2]
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., seq, hd/2]
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Dense init helper
# --------------------------------------------------------------------------
def _dense(key, shape, dtype, scale=None):
    fan_in = shape[0]
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, dtype=jnp.float32) * scale).astype(dtype)


# --------------------------------------------------------------------------
# Attention (GQA)
# --------------------------------------------------------------------------
def init_attention(key, cfg: ModelConfig) -> Dict[str, Any]:
    d, H, Hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    dt = cfg.jnp_dtype
    ks = jax.random.split(key, 4)
    return {
        "wq": _dense(ks[0], (d, H * hd), dt),
        "wk": _dense(ks[1], (d, Hkv * hd), dt),
        "wv": _dense(ks[2], (d, Hkv * hd), dt),
        "wo": _dense(ks[3], (H * hd, d), dt),
    }


def _sdpa_chunked(q, k, v, causal: bool, chunk_q: int = 512,
                  chunk_kv: int = 1024, q_offset=None):
    """Online-softmax attention in pure jnp (flash semantics): peak live
    logits are [B, Hkv, rep, cq, ckv] instead of [B, H, S, S].  This is the
    XLA-lowered twin of kernels/flash_attention.py, used by the production
    path when cfg.attn_chunked (the Pallas kernel takes over on real TPU).

    q_offset: optional [B] per-sample position of q[:, 0] within the key
    sequence (prefill-into-cache path).
    """
    B, S, H, hd = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    hd_v = v.shape[-1]                 # MLA: v head dim != qk head dim
    rep = H // Hkv
    cq = min(chunk_q, S)
    ckv = min(chunk_kv, T)
    # pad to multiples
    pad_q = (-S) % cq
    pad_kv = (-T) % ckv
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0))) if pad_q else q
    kp = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0))) if pad_kv else k
    vp = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0))) if pad_kv else v
    nq, nk = qp.shape[1] // cq, kp.shape[1] // ckv
    qb = qp.reshape(B, nq, cq, Hkv, rep, hd)
    kb = kp.reshape(B, nk, ckv, Hkv, hd)
    vb = vp.reshape(B, nk, ckv, Hkv, hd_v)
    scale = hd ** -0.5

    def q_block(qi, qblk):
        # qblk: [B, cq, Hkv, rep, hd]
        m0 = jnp.full((B, Hkv, rep, cq), -1e30, jnp.float32)
        l0 = jnp.zeros((B, Hkv, rep, cq), jnp.float32)
        acc0 = jnp.zeros((B, cq, Hkv, rep, hd_v), jnp.float32)

        def kv_step(carry, ki):
            m, l, acc = carry
            kblk, vblk = kb[:, ki], vb[:, ki]
            s = jnp.einsum("bqkrh,btkh->bkrqt", qblk.astype(jnp.float32),
                           kblk.astype(jnp.float32)) * scale
            rows = qi * cq + jax.lax.broadcasted_iota(jnp.int32, (cq, ckv), 0)
            cols = ki * ckv + jax.lax.broadcasted_iota(jnp.int32, (cq, ckv), 1)
            valid = (cols < T)[None]             # [1,cq,ckv]; mask KV padding
            if causal:
                if q_offset is None:
                    valid = valid & (rows >= cols)[None]
                else:
                    rows_b = q_offset[:, None, None] + rows[None]   # [B,cq,ckv]
                    valid = valid & (rows_b >= cols[None])
            s = jnp.where(valid[:, None, None], s, -1e30)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = alpha * l + jnp.sum(p, axis=-1)
            acc_new = acc * alpha.transpose(0, 3, 1, 2)[..., None] + jnp.einsum(
                "bkrqt,btkh->bqkrh", p, vblk.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, acc0),
                                      jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2)[..., None]
        return out.astype(q.dtype)

    outs = jax.lax.map(lambda i: q_block(i, qb[:, i]), jnp.arange(nq))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, nq * cq, H, hd_v)
    return out[:, :S]


def _sdpa(q, k, v, causal: bool, q_offset=None, use_pallas: bool = False):
    """q: [B,S,H,hd]; k,v: [B,T,Hkv,hd]. GQA broadcast. Returns [B,S,H,hd].

    q_offset: optional [B] vector of per-sample positions of q[:,0] within
    the key sequence (decode-with-cache); None means q and k are aligned.
    """
    B, S, H, hd = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    # Pallas kernel requires aligned square q/k (no cache offset, S == T);
    # covers training self-attention, causal or not (encoder blocks).
    if use_pallas and q_offset is None and S == T:
        from repro.kernels import ops as kops
        return kops.flash_attention(q, k, v, causal=causal)
    rep = H // Hkv
    qr = q.reshape(B, S, Hkv, rep, hd)
    logits = jnp.einsum("bskrh,btkh->bkrst", qr, k).astype(jnp.float32)
    logits *= hd ** -0.5
    if causal:
        if q_offset is None:
            mask = jnp.tril(jnp.ones((S, T), dtype=bool), k=T - S)
            mask = mask[None, None, None]                          # [1,1,1,S,T]
        else:
            qpos = q_offset[:, None] + jnp.arange(S)[None, :]      # [B,S]
            mask = qpos[..., None] >= jnp.arange(T)[None, None, :]  # [B,S,T]
            mask = mask[:, None, None, :, :]
        logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkrst,btkh->bskrh", probs, v)
    return out.reshape(B, S, H, v.shape[-1])


def apply_attention(params, cfg: ModelConfig, x, positions,
                    kv_cache: Optional[Dict] = None, cache_index=None,
                    causal: bool = True, use_pallas: bool = False,
                    ) -> Tuple[jax.Array, Optional[Dict]]:
    """x: [B,S,d].  If kv_cache given, append k/v at cache_index (decode)."""
    B, S, d = x.shape
    H, Hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ params["wq"]).reshape(B, S, H, hd)
    k = (x @ params["wk"]).reshape(B, S, Hkv, hd)
    v = (x @ params["wv"]).reshape(B, S, Hkv, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    new_cache = None
    if kv_cache is not None:
        idx = jnp.broadcast_to(jnp.asarray(cache_index, dtype=jnp.int32), (B,))
        ck = _scatter_seq(kv_cache["k"], k, idx)
        cv = _scatter_seq(kv_cache["v"], v, idx)
        new_cache = {"k": ck, "v": cv}
        if cfg.attn_chunked and S > 1:
            # prefill-into-cache: chunked path with per-sample offsets
            out = _sdpa_chunked(q, ck.astype(q.dtype), cv.astype(q.dtype),
                                causal=causal, chunk_q=cfg.attn_chunk_q,
                                chunk_kv=cfg.attn_chunk_kv, q_offset=idx)
        else:
            out = _sdpa(q, ck.astype(q.dtype), cv.astype(q.dtype),
                        causal=causal, q_offset=idx)
    elif cfg.attn_chunked:
        out = _sdpa_chunked(q, k, v, causal=causal, chunk_q=cfg.attn_chunk_q,
                            chunk_kv=cfg.attn_chunk_kv)
    else:
        out = _sdpa(q, k, v, causal=causal, use_pallas=use_pallas)
    return out.reshape(B, S, H * hd) @ params["wo"], new_cache


def _scatter_seq(cache, new, index):
    """cache: [B,T,...]; new: [B,S,...]; index: [B] per-sample write offset."""
    def one(c, n, i):
        return jax.lax.dynamic_update_slice_in_dim(c, n.astype(c.dtype), i, axis=0)
    return jax.vmap(one)(cache, new, index)


# --------------------------------------------------------------------------
# MLA attention (deepseek-v3)
# --------------------------------------------------------------------------
def init_mla(key, cfg: ModelConfig) -> Dict[str, Any]:
    d, H = cfg.d_model, cfg.num_heads
    r_kv, r_q = cfg.kv_lora_rank, cfg.q_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    dt = cfg.jnp_dtype
    ks = jax.random.split(key, 6)
    p = {
        "wkv_a": _dense(ks[0], (d, r_kv + dr), dt),
        "kv_norm": init_rmsnorm(r_kv, dt),
        "wkv_b": _dense(ks[1], (r_kv, H * (dn + dv)), dt),
        "wo": _dense(ks[2], (H * dv, d), dt),
    }
    if r_q:
        p["wq_a"] = _dense(ks[3], (d, r_q), dt)
        p["q_norm"] = init_rmsnorm(r_q, dt)
        p["wq_b"] = _dense(ks[4], (r_q, H * (dn + dr)), dt)
    else:
        p["wq"] = _dense(ks[5], (d, H * (dn + dr)), dt)
    return p


def apply_mla(params, cfg: ModelConfig, x, positions,
              kv_cache: Optional[Dict] = None, cache_index=None,
              ) -> Tuple[jax.Array, Optional[Dict]]:
    """Multi-head Latent Attention.  Latent cache = (c_kv, k_rope)."""
    B, S, d = x.shape
    H = cfg.num_heads
    dn, dr, dv, r_kv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim, cfg.kv_lora_rank
    if cfg.q_lora_rank:
        q = rmsnorm(params["q_norm"], x @ params["wq_a"], cfg.norm_eps) @ params["wq_b"]
    else:
        q = x @ params["wq"]
    q = q.reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv = x @ params["wkv_a"]                             # [B,S,r_kv+dr]
    c_kv, k_rope = kv[..., :r_kv], kv[..., r_kv:]
    c_kv = rmsnorm(params["kv_norm"], c_kv, cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]

    new_cache = None
    q_offset = None
    if kv_cache is not None:
        idx = jnp.broadcast_to(jnp.asarray(cache_index, dtype=jnp.int32), (B,))
        cc = _scatter_seq(kv_cache["c_kv"], c_kv, idx)
        cr = _scatter_seq(kv_cache["k_rope"], k_rope, idx)
        q_offset = idx
        new_cache = {"c_kv": cc, "k_rope": cr}
        c_kv, k_rope = cc.astype(x.dtype), cr.astype(x.dtype)

    if cfg.mla_absorb and kv_cache is not None:
        # Absorbed decode (§Perf): attention runs in the latent space.
        # scores = q_nope (W_kv_b^K)^T c_kv + q_rope k_rope; the O(T) latent
        # cache is never re-expanded to per-head K/V.
        kvb = params["wkv_b"].reshape(r_kv, H, dn + dv)
        qn_lat = jnp.einsum("bshn,rhn->bshr", q_nope.astype(jnp.float32),
                            kvb[..., :dn].astype(jnp.float32))  # [B,S,H,r]
        s_nope = jnp.einsum("bshr,btr->bhst", qn_lat,
                            c_kv.astype(jnp.float32))
        s_rope = jnp.einsum("bshd,btd->bhst", q_rope.astype(jnp.float32),
                            k_rope.astype(jnp.float32))
        scale = (dn + dr) ** -0.5
        logits = (s_nope + s_rope) * scale
        T = c_kv.shape[1]
        qpos = q_offset[:, None] + jnp.arange(S)[None, :]
        mask = qpos[..., None] >= jnp.arange(T)[None, None, :]
        logits = jnp.where(mask[:, None], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        o_lat = jnp.einsum("bhst,btr->bshr", probs,
                           c_kv.astype(jnp.float32))            # [B,S,H,r]
        out = jnp.einsum("bshr,rhn->bshn", o_lat,
                         kvb[..., dn:].astype(jnp.float32)).astype(x.dtype)
        return out.reshape(B, S, H * dv) @ params["wo"], new_cache

    # expand latent -> per-head keys/values
    kvb = params["wkv_b"].reshape(r_kv, H, dn + dv)
    k_nope = jnp.einsum("btr,rhn->bthn", c_kv, kvb[..., :dn])
    v = jnp.einsum("btr,rhn->bthn", c_kv, kvb[..., dn:])
    T = k_nope.shape[1]
    k_rope_b = jnp.broadcast_to(k_rope[:, :, None, :], (B, T, H, dr))
    k = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    qf = jnp.concatenate([q_nope, q_rope], axis=-1)
    if cfg.attn_chunked and (q_offset is None or S > 1):
        out = _sdpa_chunked(qf, k, v, causal=True, chunk_q=cfg.attn_chunk_q,
                            chunk_kv=cfg.attn_chunk_kv, q_offset=q_offset)
    else:
        out = _sdpa(qf, k, v, causal=True, q_offset=q_offset)
    return out.reshape(B, S, H * dv) @ params["wo"], new_cache


# --------------------------------------------------------------------------
# MLP
# --------------------------------------------------------------------------
def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> Dict[str, Any]:
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    dt = cfg.jnp_dtype
    ks = jax.random.split(key, 3)
    if cfg.activation == "relu2":          # nemotron: squared-ReLU, ungated
        return {"wi": _dense(ks[0], (d, ff), dt), "wo": _dense(ks[1], (ff, d), dt)}
    return {
        "wg": _dense(ks[0], (d, ff), dt),
        "wu": _dense(ks[1], (d, ff), dt),
        "wo": _dense(ks[2], (ff, d), dt),
    }


def apply_mlp(params, cfg: ModelConfig, x) -> jax.Array:
    if cfg.activation == "relu2":
        h = jax.nn.relu(x @ params["wi"])
        return (h * h) @ params["wo"]
    g = x @ params["wg"]
    act = jax.nn.gelu(g) if cfg.activation == "gelu" else jax.nn.silu(g)
    return (act * (x @ params["wu"])) @ params["wo"]


# --------------------------------------------------------------------------
# Embedding / head
# --------------------------------------------------------------------------
def init_embedding(key, cfg: ModelConfig) -> Dict[str, Any]:
    p = {"embedding": _dense(key, (cfg.vocab_size, cfg.d_model), cfg.jnp_dtype, scale=1.0)}
    return p


def embed(params, tokens):
    return jnp.take(params["embedding"], tokens, axis=0)


def init_lm_head(key, cfg: ModelConfig) -> Dict[str, Any]:
    return {"w": _dense(key, (cfg.d_model, cfg.vocab_size), cfg.jnp_dtype)}


def lm_logits(head_params, x):
    return x @ head_params["w"]
