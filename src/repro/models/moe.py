"""Mixture-of-Experts MLP with fixed-capacity token dispatch.

Expert-parallel friendly: the expert dimension is a leading axis of every
expert weight, so it shards cleanly over the `model` mesh axis (EP).  Dispatch
uses capacity buckets built with one-hot position ranking (dense, SPMD-safe —
no ragged ops), the standard TPU formulation (GShard/Switch-style).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import _dense


def init_moe(key, cfg: ModelConfig) -> Dict[str, Any]:
    d, ff, E = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    dt = cfg.jnp_dtype
    ks = jax.random.split(key, 5)
    p = {
        "router": _dense(ks[0], (d, E), jnp.dtype("float32")),
        "wg": _dense(ks[1], (E, d, ff), dt),
        "wu": _dense(ks[2], (E, d, ff), dt),
        "wo": _dense(ks[3], (E, ff, d), dt),
    }
    if cfg.num_shared_experts:
        sff = ff * cfg.num_shared_experts
        kk = jax.random.split(ks[4], 3)
        p["shared"] = {
            "wg": _dense(kk[0], (d, sff), dt),
            "wu": _dense(kk[1], (d, sff), dt),
            "wo": _dense(kk[2], (sff, d), dt),
        }
    return p


def apply_moe(params, cfg: ModelConfig, x) -> Tuple[jax.Array, jax.Array]:
    """x: [B,S,d] -> (out [B,S,d], aux_loss scalar).

    Fixed capacity C = ceil(T/E * top_k * capacity_factor) per expert.
    Overflow tokens are dropped (standard capacity semantics).

    Two dispatch modes:
      * global (default): one token ranking over the whole local batch —
        faithful single-queue capacity semantics.
      * row (cfg.moe_row_dispatch, §Perf): capacity per sample row; the
        rank-in-queue cumsum and the dispatch scatter stay local to the
        batch shard, so SPMD partitioning introduces no cross-device
        ranking collective.
    """
    if cfg.moe_row_dispatch:
        return _apply_moe_rowwise(params, cfg, x)
    B, S, d = x.shape
    E, K = cfg.num_experts, cfg.top_k
    T = B * S
    xt = x.reshape(T, d)
    logits = (xt.astype(jnp.float32) @ params["router"])       # [T,E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)              # [T,K]
    # normalize top-k gates (deepseek-style)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # aux load-balancing loss (Switch-style)
    me = jnp.mean(probs, axis=0)                               # [E]
    ce = jnp.mean(jax.nn.one_hot(gate_idx[:, 0], E, dtype=jnp.float32), axis=0)
    aux = jnp.sum(me * ce) * E * cfg.router_aux_loss

    cap = int(max(1, round(T * K / E * cfg.capacity_factor)))
    # position of each (token, k) within its expert's queue
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.int32)      # [T,K,E]
    flat = onehot.reshape(T * K, E)
    pos = jnp.cumsum(flat, axis=0) - flat                      # rank in queue
    pos = jnp.sum(pos * flat, axis=-1).reshape(T, K)           # [T,K]
    keep = pos < cap
    gate_vals = gate_vals * keep.astype(gate_vals.dtype)

    # dispatch: build [E, cap, d] buckets via scatter
    eidx = gate_idx.reshape(-1)                                # [T*K]
    pidx = pos.reshape(-1)
    kmask = keep.reshape(-1)
    src = jnp.repeat(jnp.arange(T), K)
    safe_p = jnp.where(kmask, pidx, cap - 1)
    buckets = jnp.zeros((E, cap, d), dtype=x.dtype)
    buckets = buckets.at[eidx, safe_p].add(
        jnp.where(kmask[:, None], xt[src], 0).astype(x.dtype))

    # expert compute: [E, cap, d] einsum with [E, d, ff]
    if cfg.activation == "relu2":
        h = jax.nn.relu(jnp.einsum("ecd,edf->ecf", buckets, params["wg"]))
        out_b = jnp.einsum("ecf,efd->ecd", h * h, params["wo"])
    else:
        g = jnp.einsum("ecd,edf->ecf", buckets, params["wg"])
        u = jnp.einsum("ecd,edf->ecf", buckets, params["wu"])
        out_b = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, params["wo"])

    # combine: gather back and weight by gates
    gathered = out_b[eidx, safe_p]                             # [T*K, d]
    w = (gate_vals.reshape(-1) * kmask).astype(x.dtype)
    out = jnp.zeros((T, d), dtype=x.dtype).at[src].add(gathered * w[:, None])

    if cfg.num_shared_experts:
        sp = params["shared"]
        if cfg.activation == "relu2":
            h = jax.nn.relu(xt @ sp["wg"])
            out = out + (h * h) @ sp["wo"]
        else:
            out = out + (jax.nn.silu(xt @ sp["wg"]) * (xt @ sp["wu"])) @ sp["wo"]
    return out.reshape(B, S, d), aux


def _apply_moe_rowwise(params, cfg: ModelConfig, x) -> Tuple[jax.Array, jax.Array]:
    """Row-local dispatch (§Perf): capacity per sample row, rank-in-queue
    cumsum over [S*K] per row, dispatch scatter vmapped over the batch dim —
    everything partitions cleanly along the batch shard."""
    B, S, d = x.shape
    E, K = cfg.num_experts, cfg.top_k
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)            # [B,S,K]
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    me = jnp.mean(probs.reshape(-1, E), axis=0)
    ce = jnp.mean(jax.nn.one_hot(gate_idx[..., 0].reshape(-1), E,
                                 dtype=jnp.float32), axis=0)
    aux = jnp.sum(me * ce) * E * cfg.router_aux_loss

    cap = int(max(1, round(S * K / E * cfg.capacity_factor)))
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.int32)    # [B,S,K,E]
    flat = onehot.reshape(B, S * K, E)
    pos = jnp.cumsum(flat, axis=1) - flat                    # row-local rank
    pos = jnp.sum(pos.reshape(B, S, K, E) * onehot, axis=-1)  # [B,S,K]
    keep = pos < cap
    gate_vals = gate_vals * keep.astype(gate_vals.dtype)

    eidx = gate_idx.reshape(B, S * K)
    safe_p = jnp.where(keep.reshape(B, S * K), pos.reshape(B, S * K), cap - 1)
    kmask = keep.reshape(B, S * K)
    src = jnp.broadcast_to(jnp.arange(S).repeat(K)[None], (B, S * K))

    def scatter_row(xr, er, pr, mr, sr):
        vals = jnp.where(mr[:, None], xr[sr], 0).astype(x.dtype)
        return jnp.zeros((E, cap, d), x.dtype).at[er, pr].add(vals)

    buckets = jax.vmap(scatter_row)(x, eidx, safe_p, kmask, src)  # [B,E,cap,d]

    if cfg.activation == "relu2":
        h = jax.nn.relu(jnp.einsum("becd,edf->becf", buckets, params["wg"]))
        out_b = jnp.einsum("becf,efd->becd", h * h, params["wo"])
    else:
        g = jnp.einsum("becd,edf->becf", buckets, params["wg"])
        u = jnp.einsum("becd,edf->becf", buckets, params["wu"])
        out_b = jnp.einsum("becf,efd->becd", jax.nn.silu(g) * u, params["wo"])

    def gather_row(ob, er, pr, wr, sr):
        vals = ob[er, pr] * wr[:, None]                      # [S*K, d]
        return jnp.zeros((S, d), ob.dtype).at[sr].add(vals)

    w = (gate_vals.reshape(B, S * K) * kmask).astype(out_b.dtype)
    out = jax.vmap(gather_row)(out_b, eidx, safe_p, w, src)  # [B,S,d]

    if cfg.num_shared_experts:
        sp = params["shared"]
        if cfg.activation == "relu2":
            h = jax.nn.relu(jnp.einsum("bsd,df->bsf", x, sp["wg"]))
            out = out + jnp.einsum("bsf,fd->bsd", h * h, sp["wo"])
        else:
            g = jnp.einsum("bsd,df->bsf", x, sp["wg"])
            u = jnp.einsum("bsd,df->bsf", x, sp["wu"])
            out = out + jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u, sp["wo"])
    return out.astype(x.dtype), aux
