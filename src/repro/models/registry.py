"""Model registry: uniform entry points over all families.

Also exposes the *per-layer* API used by the ElasWave VirtualCluster, where
each physical layer is an independently-owned pytree that can migrate between
pipeline stages.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig
from . import layers as L
from . import transformer as T
from . import encdec as E


def flat_layer_types(cfg: ModelConfig) -> List[str]:
    """Block type of each physical layer, in order."""
    out: List[str] = []
    for pat, rep in cfg.block_pattern():
        out.extend(list(pat) * rep)
    return out


# ---- per-layer (ElasWave cluster) API -------------------------------------
def init_layer(key, cfg: ModelConfig, layer_idx: int) -> Dict[str, Any]:
    blk = flat_layer_types(cfg)[layer_idx]
    return T.init_block(key, cfg, blk)


def apply_layer(params, cfg: ModelConfig, layer_idx: int, x, positions,
                rng_ctx: L.RngCtx, use_pallas: bool = False):
    blk = flat_layer_types(cfg)[layer_idx]
    x, _, aux = T.apply_block(params, cfg, blk, x, positions, rng_ctx,
                              layer_idx, use_pallas=use_pallas)
    return x, aux


def init_stem(key, cfg: ModelConfig):
    """Embedding (stage-0-owned) params."""
    return {"embed": L.init_embedding(key, cfg)}


def init_head(key, cfg: ModelConfig):
    """Final norm + lm head (last-stage-owned) params."""
    k1, k2 = jax.random.split(key)
    return {"final_norm": L.init_rmsnorm(cfg.d_model, cfg.jnp_dtype),
            "head": L.init_lm_head(k1, cfg)}


def apply_stem(params, cfg: ModelConfig, tokens, use_pallas: bool = False):
    del use_pallas          # embedding lookup has no kernel; uniform signature
    return L.embed(params["embed"], tokens)


def apply_head(params, cfg: ModelConfig, x, use_pallas: bool = False):
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps,
                  use_pallas=use_pallas)
    return L.lm_logits(params["head"], x)


# ---- whole-model API (pjit / dry-run path) ---------------------------------
def init_model(key, cfg: ModelConfig):
    if cfg.is_encdec:
        return E.init_encdec_params(key, cfg)
    return T.init_params(key, cfg)


def model_param_shapes(cfg: ModelConfig):
    return jax.eval_shape(lambda k: init_model(k, cfg), jax.random.key(0))


def make_train_loss(cfg: ModelConfig, use_pallas: bool = False, remat: bool = False):
    if cfg.is_encdec:
        def loss_fn(params, batch, rng_ctx=None):
            return E.encdec_train_loss(params, cfg, batch, rng_ctx,
                                       use_pallas=use_pallas, remat=remat)
    else:
        def loss_fn(params, batch, rng_ctx=None):
            return T.train_loss(params, cfg, batch, rng_ctx,
                                use_pallas=use_pallas, remat=remat)
    return loss_fn


# ---- serving decode-step hooks (repro.serving engine) ----------------------
@dataclasses.dataclass(frozen=True)
class ServingHooks:
    """Uniform prefill/decode interface over all families, used by the
    elastic serving engine (``repro.serving``).  Cache pytrees carry the
    slot/batch dimension on axis 1 (stacked layer axis first); ``extras`` is
    a per-slot pytree with the slot dimension on axis 0 (e.g. an enc-dec
    encoder output), or ``None`` for decoder-only families.

    * ``prefill(params, tokens [B,S], caches, extras)`` -> (logits [B,V],
      caches): writes the whole prefix at positions ``0..S-1``.
    * ``decode_step(params, tokens [B,1], caches, positions [B], extras)``
      -> (logits [B,V], caches): per-slot write offsets, so one batched call
      serves slots at different sequence lengths (continuous batching).
    """
    init_caches: Callable[[int, int], Any]          # (batch, max_len)
    prefill: Callable[..., Any]
    decode_step: Callable[..., Any]
    prepare_extras: Callable[..., Any]              # (params, request)


def serving_hooks(cfg: ModelConfig) -> ServingHooks:
    if cfg.is_encdec:
        def init_caches(batch, max_len):
            return E.init_decoder_caches(cfg, batch, max_len)

        def prepare_extras(params, req):
            frames = jnp.asarray(req.encoder_frames)[None]     # [1, T, d]
            return {"enc": E.encode(params, cfg, frames)}

        def prefill(params, tokens, caches, extras):
            logits, caches = E.decode(params, cfg, tokens, extras["enc"],
                                      caches=caches, cache_index=0)
            return logits[:, -1, :], caches

        def decode_step(params, tokens, caches, positions, extras):
            logits, caches = E.decode(params, cfg, tokens, extras["enc"],
                                      caches=caches, cache_index=positions)
            return logits[:, -1, :], caches
    else:
        def init_caches(batch, max_len):
            return T.init_caches(cfg, batch, max_len)

        def prepare_extras(params, req):
            del params, req
            return None

        def prefill(params, tokens, caches, extras):
            del extras
            logits, caches = T.prefill(params, cfg, tokens, caches)
            return logits[:, -1, :], caches

        def decode_step(params, tokens, caches, positions, extras):
            del extras
            logits, caches = T.decode_step(params, cfg, tokens, caches,
                                           cache_index=positions)
            return logits[:, -1, :], caches

    return ServingHooks(init_caches=init_caches, prefill=prefill,
                        decode_step=decode_step,
                        prepare_extras=prepare_extras)


def tiny_config(family: str = "dense", **kw) -> ModelConfig:
    """Reduced config of a family for CPU tests."""
    base = dict(name=f"tiny-{family}", family=family, num_layers=4, d_model=64,
                num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
                rope_theta=10000.0, dtype="float32")
    if family == "moe":
        base.update(num_experts=4, top_k=2, moe_d_ff=64, first_k_dense=1)
    if family == "ssm":
        base.update(num_heads=0, num_kv_heads=0, d_ff=0, ssm_state=16,
                    ssm_headdim=16, ssm_chunk=8)
        base["num_heads"] = 0
    if family == "hybrid":
        base.update(num_layers=4, attn_period=4, attn_layer_offset=0,
                    ssm_state=16, ssm_headdim=16, ssm_chunk=8,
                    num_experts=4, top_k=2, moe_d_ff=64, moe_layer_period=2)
    if family == "audio":
        base.update(is_encdec=True, encoder_layers=2, decoder_layers=2,
                    num_layers=2, max_source_positions=32)
    if family == "vlm":
        base.update(frontend_embeds=8)
    base.update(kw)
    return ModelConfig(**base)
