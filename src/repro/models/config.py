"""Model configuration covering all assigned architecture families.

One dataclass describes dense / MoE / SSM / hybrid / enc-dec / VLM-backbone
transformers.  A config is compiled into a sequence of *segments*
(pattern of block types, repeated), which the model applies with
``jax.lax.scan`` over repeats for compile-time compactness.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

# Block type ids used in segment patterns.
ATTN = "attn"          # full self-attention block (GQA or MLA) + MLP (or MoE)
MAMBA = "mamba"        # Mamba2 SSD block
MOE = "moe"            # attention + MoE MLP
MAMBA_MOE = "mamba_moe"  # Mamba2 block + MoE MLP (jamba-style)
ATTN_MOE = "attn_moe"  # attention + MoE MLP


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads
    activation: str = "silu"         # silu | relu2 | gelu
    norm_eps: float = 1e-5
    rope_theta: float = 500000.0
    dtype: str = "bfloat16"
    dropout_rate: float = 0.0
    tie_embeddings: bool = False

    # --- MoE ---
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                # expert hidden dim (0 -> d_ff)
    moe_layer_period: int = 1        # MoE every k-th layer (jamba: 2)
    first_k_dense: int = 0           # deepseek-v3: first 3 layers dense
    capacity_factor: float = 1.25
    router_aux_loss: float = 0.001

    # --- MLA (deepseek-v3) ---
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 512
    qk_rope_dim: int = 64
    qk_nope_dim: int = 128
    v_head_dim: int = 128

    # --- SSM (mamba2 SSD) ---
    ssm_state: int = 0               # >0 enables SSD blocks
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_ngroups: int = 1
    conv_kernel: int = 4

    # --- hybrid (jamba) ---
    attn_period: int = 0             # one attn layer per `attn_period` layers
    attn_layer_offset: int = 0

    # --- enc-dec (whisper) ---
    is_encdec: bool = False
    encoder_layers: int = 0
    decoder_layers: int = 0
    max_source_positions: int = 1500  # whisper frame positions (stub frontend)

    # --- modality frontend stub (vlm/audio) ---
    frontend_embeds: int = 0         # number of precomputed prefix embeddings

    # --- lowering control ---
    scan_layers: bool = True         # False: unroll (exact cost analysis)

    # --- perf knobs (§Perf hillclimbing) ---
    attn_chunked: bool = False       # online-softmax chunked attention (jnp
                                     # flash semantics; Pallas kernel on TPU)
    attn_chunk_q: int = 512
    attn_chunk_kv: int = 1024
    seq_shard_acts: bool = False     # shard activations' seq dim over `model`
                                     # between blocks (SP: RS+AG instead of AR)
    moe_row_dispatch: bool = False   # per-sample-row expert capacity: cumsum/
                                     # scatter stay local to the batch shard
                                     # (no global token ranking collective)
    mla_absorb: bool = False         # absorbed MLA decode: attention runs in
                                     # the latent space (w_kv_b folded into q
                                     # and o) — no per-token KV re-expansion
    mamba_split_proj: bool = False   # slice in_proj weights per component so
                                     # z/x/B/C/dt matmuls shard cleanly (the
                                     # packed-dim split boundaries misalign
                                     # with TP shards -> activation reshards)

    # --- assigned input shapes (overridable per arch) ---
    shapes: Tuple[Tuple[str, int, int], ...] = (
        ("train_4k", 4096, 256),
        ("prefill_32k", 32768, 32),
        ("decode_32k", 32768, 128),
        ("long_500k", 524288, 1),
    )
    # which shapes to skip and why (e.g. long_500k for pure full attention)
    skip_shapes: Tuple[Tuple[str, str], ...] = ()

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.moe_d_ff == 0 and self.num_experts:
            object.__setattr__(self, "moe_d_ff", self.d_ff)

    # ---- derived ----
    @property
    def jnp_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def block_pattern(self):
        """Return list of (pattern, repeats). pattern is a tuple of block ids."""
        L = self.num_layers
        if self.is_encdec:
            # handled by encdec model; pattern covers decoder blocks
            return [((ATTN,), self.decoder_layers or L)]
        if self.family == "ssm":
            return [((MAMBA,), L)]
        if self.family == "hybrid":
            p = self.attn_period
            pat = []
            for i in range(p):
                attn = (i == self.attn_layer_offset)
                moe = (i % self.moe_layer_period == 1) if self.num_experts else False
                if attn:
                    pat.append(ATTN_MOE if moe else ATTN)
                else:
                    pat.append(MAMBA_MOE if moe else MAMBA)
            assert L % p == 0
            return [(tuple(pat), L // p)]
        if self.num_experts:
            segs = []
            if self.first_k_dense:
                segs.append(((ATTN,), self.first_k_dense))
            rest = L - self.first_k_dense
            if self.moe_layer_period == 1:
                segs.append(((ATTN_MOE,), rest))
            else:
                p = self.moe_layer_period
                pat = tuple(ATTN_MOE if i % p == p - 1 else ATTN for i in range(p))
                assert rest % p == 0
                segs.append((pat, rest // p))
            return segs
        return [((ATTN,), L)]

    def param_count(self) -> int:
        """Approximate parameter count (embedding + blocks + head)."""
        d, V = self.d_model, self.vocab_size
        n = V * d  # embed
        if not self.tie_embeddings:
            n += V * d
        for pat, rep in self.block_pattern():
            for blk in pat:
                n += rep * self._block_params(blk)
        if self.is_encdec:
            n += self.encoder_layers * self._block_params(ATTN)
            # cross attention per decoder layer
            n += (self.decoder_layers or self.num_layers) * 4 * d * d
        return n

    def active_param_count(self) -> int:
        """Params active per token (MoE: only top_k + shared experts)."""
        d, V = self.d_model, self.vocab_size
        n = V * d
        if not self.tie_embeddings:
            n += V * d
        for pat, rep in self.block_pattern():
            for blk in pat:
                n += rep * self._block_params(blk, active=True)
        if self.is_encdec:
            n += self.encoder_layers * self._block_params(ATTN, active=True)
            n += (self.decoder_layers or self.num_layers) * 4 * d * d
        return n

    def _block_params(self, blk: str, active: bool = False) -> int:
        d = self.d_model
        n = 0
        if blk in (ATTN, ATTN_MOE, MOE):
            if self.use_mla:
                qr = self.q_lora_rank or d
                qdim = self.num_heads * (self.qk_nope_dim + self.qk_rope_dim)
                n += d * qr + qr * qdim if self.q_lora_rank else d * qdim
                n += d * (self.kv_lora_rank + self.qk_rope_dim)
                n += self.kv_lora_rank * self.num_heads * (self.qk_nope_dim + self.v_head_dim)
                n += self.num_heads * self.v_head_dim * d
            else:
                hd = self.head_dim
                n += d * self.num_heads * hd          # q
                n += 2 * d * self.num_kv_heads * hd   # k, v
                n += self.num_heads * hd * d          # o
        if blk in (MAMBA, MAMBA_MOE):
            di, ds, ng = self.d_inner, self.ssm_state, self.ssm_ngroups
            n += d * (2 * di + 2 * ng * ds + self.ssm_heads)  # in_proj
            n += self.conv_kernel * (di + 2 * ng * ds)        # conv
            n += 3 * self.ssm_heads                            # A, D, dt_bias
            n += di * d                                        # out_proj
        # MLP / MoE
        mlp_mats = 2 if self.activation == "relu2" else 3
        if blk in (ATTN, MAMBA):
            n += mlp_mats * d * self.d_ff
        elif blk in (ATTN_MOE, MAMBA_MOE, MOE):
            e = (self.top_k + self.num_shared_experts) if active else (
                self.num_experts + self.num_shared_experts)
            n += e * mlp_mats * d * self.moe_d_ff
            n += d * self.num_experts  # router
        n += 2 * d  # norms
        return n
