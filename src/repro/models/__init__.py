"""Model zoo: dense/MoE/SSM/hybrid families behind one registry so every
elasticity mechanism is exercised across architectures."""
from .config import ModelConfig
from . import layers, mamba, moe, transformer, encdec, registry
