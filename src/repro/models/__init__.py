from .config import ModelConfig
from . import layers, mamba, moe, transformer, encdec, registry
