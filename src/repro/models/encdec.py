"""Encoder-decoder (Whisper-style) model.

The conv/mel frontend is a STUB per the assignment: ``input_specs`` supplies
precomputed frame embeddings [B, frames, d].  Encoder: bidirectional attention
blocks.  Decoder: causal self-attention + cross-attention + MLP.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from . import layers as L


def _init_xattn(key, cfg: ModelConfig) -> Dict[str, Any]:
    return L.init_attention(key, cfg)


def init_encdec_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    ks = jax.random.split(key, 6)
    dt = cfg.jnp_dtype

    def enc_block(k):
        k1, k2 = jax.random.split(k)
        return {"ln1": L.init_rmsnorm(cfg.d_model, dt),
                "attn": L.init_attention(k1, cfg),
                "ln2": L.init_rmsnorm(cfg.d_model, dt),
                "mlp": L.init_mlp(k2, cfg)}

    def dec_block(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"ln1": L.init_rmsnorm(cfg.d_model, dt),
                "attn": L.init_attention(k1, cfg),
                "lnx": L.init_rmsnorm(cfg.d_model, dt),
                "xattn": _init_xattn(k2, cfg),
                "ln2": L.init_rmsnorm(cfg.d_model, dt),
                "mlp": L.init_mlp(k3, cfg)}

    ne = cfg.encoder_layers or cfg.num_layers
    nd = cfg.decoder_layers or cfg.num_layers
    return {
        "embed": L.init_embedding(ks[0], cfg),
        "enc_pos": (jax.random.normal(ks[1], (cfg.max_source_positions, cfg.d_model),
                                      jnp.float32) * 0.02).astype(dt),
        "encoder": jax.vmap(enc_block)(jax.random.split(ks[2], ne)),
        "enc_norm": L.init_rmsnorm(cfg.d_model, dt),
        "decoder": jax.vmap(dec_block)(jax.random.split(ks[3], nd)),
        "final_norm": L.init_rmsnorm(cfg.d_model, dt),
        "head": L.init_lm_head(ks[4], cfg),
    }


def encode(params, cfg: ModelConfig, frames, use_pallas: bool = False,
           remat: bool = False) -> jax.Array:
    """frames: [B, T, d] precomputed frame embeddings (frontend stub)."""
    B, T, _ = frames.shape
    x = frames.astype(cfg.jnp_dtype) + params["enc_pos"][None, :T, :]
    positions = jnp.broadcast_to(jnp.arange(T)[None, :], (B, T))

    def body(x, blkp):
        h = L.rmsnorm(blkp["ln1"], x, cfg.norm_eps, use_pallas=use_pallas)
        a, _ = L.apply_attention(blkp["attn"], cfg, h, positions,
                                 causal=False, use_pallas=use_pallas)
        x = x + a
        h = L.rmsnorm(blkp["ln2"], x, cfg.norm_eps, use_pallas=use_pallas)
        return x + L.apply_mlp(blkp["mlp"], cfg, h), None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, params["encoder"])
    else:
        ne = cfg.encoder_layers or cfg.num_layers
        for i in range(ne):
            x, _ = body(x, jax.tree.map(lambda a: a[i], params["encoder"]))
    return L.rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def decode(params, cfg: ModelConfig, tokens, enc_out,
           caches=None, cache_index=None, use_pallas: bool = False,
           remat: bool = False):
    """tokens: [B,S]; enc_out: [B,T,d]. Returns (logits, new_caches)."""
    B, S = tokens.shape
    x = L.embed(params["embed"], tokens)
    T = enc_out.shape[1]
    if cache_index is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    else:
        idx = jnp.broadcast_to(jnp.asarray(cache_index, jnp.int32), (B,))
        positions = idx[:, None] + jnp.arange(S)[None, :]
    enc_pos = jnp.broadcast_to(jnp.arange(T)[None, :], (B, T))

    def body(carry, xs):
        x = carry
        blkp, blkc = xs
        h = L.rmsnorm(blkp["ln1"], x, cfg.norm_eps, use_pallas=use_pallas)
        a, nc = L.apply_attention(blkp["attn"], cfg, h, positions,
                                  kv_cache=blkc, cache_index=cache_index,
                                  use_pallas=use_pallas)
        x = x + a
        # cross-attention over encoder output (non-causal, no cache needed:
        # enc_out K/V are recomputed — cheap at whisper scale).  use_pallas
        # only engages when S == T (the kernel needs square q/k), which the
        # _sdpa gate checks.
        h = L.rmsnorm(blkp["lnx"], x, cfg.norm_eps, use_pallas=use_pallas)
        Hh, hd = cfg.num_heads, cfg.head_dim
        q = (h @ blkp["xattn"]["wq"]).reshape(B, S, Hh, hd)
        k = (enc_out @ blkp["xattn"]["wk"]).reshape(B, T, cfg.num_kv_heads, hd)
        v = (enc_out @ blkp["xattn"]["wv"]).reshape(B, T, cfg.num_kv_heads, hd)
        a = L._sdpa(q, k, v, causal=False, use_pallas=use_pallas)
        x = x + a.reshape(B, S, Hh * hd) @ blkp["xattn"]["wo"]
        h = L.rmsnorm(blkp["ln2"], x, cfg.norm_eps, use_pallas=use_pallas)
        return x + L.apply_mlp(blkp["mlp"], cfg, h), nc

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    xs = (params["decoder"], caches)
    if cfg.scan_layers:
        x, new_caches = jax.lax.scan(body, x, xs)
    else:
        nd = cfg.decoder_layers or cfg.num_layers
        outs = []
        for i in range(nd):
            x, out_i = body(x, jax.tree.map(lambda a: a[i], xs))
            outs.append(out_i)
        new_caches = None if outs[0] is None else jax.tree.map(
            lambda *ls: jnp.stack(ls), *outs)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return L.lm_logits(params["head"], x), (new_caches if caches is not None else None)


def init_decoder_caches(cfg: ModelConfig, batch: int, max_len: int):
    nd = cfg.decoder_layers or cfg.num_layers
    one = {"k": jnp.zeros((batch, max_len, cfg.num_kv_heads, cfg.head_dim), cfg.jnp_dtype),
           "v": jnp.zeros((batch, max_len, cfg.num_kv_heads, cfg.head_dim), cfg.jnp_dtype)}
    return jax.tree.map(lambda a: jnp.broadcast_to(a[None], (nd,) + a.shape), one)


def encdec_train_loss(params, cfg: ModelConfig, batch, rng_ctx=None,
                      use_pallas: bool = False, remat: bool = False):
    enc_out = encode(params, cfg, batch["frames"], use_pallas=use_pallas,
                     remat=remat)
    logits, _ = decode(params, cfg, batch["tokens"], enc_out,
                       use_pallas=use_pallas, remat=remat)
    from .transformer import softmax_xent
    return softmax_xent(logits[:, :-1], batch["labels"][:, 1:])


def encdec_decode_step(params, cfg: ModelConfig, tokens, enc_out, caches, cache_index):
    logits, new_caches = decode(params, cfg, tokens, enc_out,
                                caches=caches, cache_index=cache_index)
    return logits[:, -1:, :], new_caches
