"""Full-model assembly for all decoder-only families.

A model = embed -> [segments] -> final_norm -> lm_head, where each segment is
(pattern of block types) x (repeats), applied with ``jax.lax.scan`` over
repeats so the lowered HLO stays compact for 61..126-layer configs.

Two parameter layouts are supported:
* **stacked** (default): per-pattern-position params with a leading `repeats`
  axis — used by the pjit/dry-run/serving paths.
* **per-layer list** (`init_layer_params` / `apply_single_layer`): one pytree
  per physical layer — used by the ElasWave VirtualCluster, where layers
  migrate between pipeline stages.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ATTN, ATTN_MOE, MAMBA, MAMBA_MOE, ModelConfig
from . import layers as L
from . import mamba as M
from . import moe as X


def _is_attn(blk: str) -> bool:
    return blk in (ATTN, ATTN_MOE)


def _is_moe(blk: str) -> bool:
    return blk in (ATTN_MOE, MAMBA_MOE)


def _maybe_seq_shard(x, cfg: ModelConfig):
    """SP-style activation constraint: shard the sequence dim over `model`
    between blocks, so XLA lowers TP boundary all-reduces as reduce-scatter +
    all-gather pairs (half the wire volume, overlappable)."""
    if not cfg.seq_shard_acts:
        return x
    from jax.sharding import PartitionSpec as P
    U = P.UNCONSTRAINED
    try:
        return jax.lax.with_sharding_constraint(x, P(U, "model", U))
    except Exception:     # no mesh in scope (unit tests)
        return x


# --------------------------------------------------------------------------
# Block init / apply
# --------------------------------------------------------------------------
def _has_mlp(cfg: ModelConfig, blk: str) -> bool:
    """Pure-SSM blocks (mamba2, d_ff=0) are mixer-only — no MLP sublayer."""
    return _is_moe(blk) or cfg.d_ff > 0


def init_block(key, cfg: ModelConfig, blk: str) -> Dict[str, Any]:
    ks = jax.random.split(key, 2)
    p: Dict[str, Any] = {"ln1": L.init_rmsnorm(cfg.d_model, cfg.jnp_dtype)}
    if _is_attn(blk):
        p["attn"] = L.init_mla(ks[0], cfg) if cfg.use_mla else L.init_attention(ks[0], cfg)
    else:
        p["mamba"] = M.init_mamba(ks[0], cfg)
    if _has_mlp(cfg, blk):
        p["ln2"] = L.init_rmsnorm(cfg.d_model, cfg.jnp_dtype)
        if _is_moe(blk):
            p["moe"] = X.init_moe(ks[1], cfg)
        else:
            p["mlp"] = L.init_mlp(ks[1], cfg)
    return p


def apply_block(params, cfg: ModelConfig, blk: str, x, positions,
                rng_ctx: L.RngCtx, layer_id, cache=None, cache_index=None,
                use_pallas: bool = False):
    """Returns (x, new_cache, aux_loss)."""
    ctx = rng_ctx if rng_ctx.deterministic else L.RngCtx(
        step_key=jax.random.fold_in(rng_ctx.step_key, layer_id),
        sample_ids=rng_ctx.sample_ids, deterministic=False)
    h = L.rmsnorm(params["ln1"], x, cfg.norm_eps, use_pallas=use_pallas)
    new_cache = None
    if _is_attn(blk):
        if cfg.use_mla:
            a, new_cache = L.apply_mla(params["attn"], cfg, h, positions,
                                       kv_cache=cache, cache_index=cache_index)
        else:
            a, new_cache = L.apply_attention(params["attn"], cfg, h, positions,
                                             kv_cache=cache, cache_index=cache_index,
                                             use_pallas=use_pallas)
    else:
        a, new_cache = M.apply_mamba(params["mamba"], cfg, h, state=cache,
                                     use_pallas=use_pallas)
    x = x + L.dropout(a, cfg.dropout_rate, ctx, op_id=0)
    aux = jnp.zeros((), jnp.float32)
    if _has_mlp(cfg, blk):
        h = L.rmsnorm(params["ln2"], x, cfg.norm_eps, use_pallas=use_pallas)
        if _is_moe(blk):
            m, aux = X.apply_moe(params["moe"], cfg, h)
        else:
            m = L.apply_mlp(params["mlp"], cfg, h)
        x = x + L.dropout(m, cfg.dropout_rate, ctx, op_id=1)
    return x, new_cache, aux


def init_block_cache(cfg: ModelConfig, blk: str, batch: int, max_len: int):
    if _is_attn(blk):
        if cfg.use_mla:
            return {"c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), cfg.jnp_dtype),
                    "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_dim), cfg.jnp_dtype)}
        return {"k": jnp.zeros((batch, max_len, cfg.num_kv_heads, cfg.head_dim), cfg.jnp_dtype),
                "v": jnp.zeros((batch, max_len, cfg.num_kv_heads, cfg.head_dim), cfg.jnp_dtype)}
    return M.init_mamba_state(cfg, batch)


# --------------------------------------------------------------------------
# Stacked (scan) model — pjit / dry-run / serving path
# --------------------------------------------------------------------------
def init_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    ks = jax.random.split(key, 4)
    params: Dict[str, Any] = {"embed": L.init_embedding(ks[0], cfg)}
    segs = []
    kseg = ks[1]
    for pat, rep in cfg.block_pattern():
        kseg, kuse = jax.random.split(kseg)
        pos_params = []
        for pi, blk in enumerate(pat):
            kblk = jax.random.fold_in(kuse, pi)
            stacked = jax.vmap(lambda k: init_block(k, cfg, blk))(
                jax.random.split(kblk, rep))
            pos_params.append(stacked)
        segs.append(pos_params)
    params["segments"] = segs
    params["final_norm"] = L.init_rmsnorm(cfg.d_model, cfg.jnp_dtype)
    if not cfg.tie_embeddings:
        params["head"] = L.init_lm_head(ks[2], cfg)
    return params


def param_shapes(cfg: ModelConfig):
    """ShapeDtypeStruct pytree of params without allocating (for dry-run)."""
    return jax.eval_shape(lambda k: init_params(k, cfg), jax.random.key(0))


def forward(params, cfg: ModelConfig, tokens, *,
            rng_ctx: Optional[L.RngCtx] = None,
            prefix_embeds=None, caches=None, cache_index=None,
            use_pallas: bool = False, remat: bool = False):
    """tokens: [B,S] -> (logits [B,S(,+P),V], new_caches, aux_loss).

    prefix_embeds: [B,P,d] precomputed modality embeddings (vlm/audio stub),
    prepended before token embeddings.
    """
    rng_ctx = rng_ctx or L.RngCtx()
    x = L.embed(params["embed"], tokens)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    B, S, _ = x.shape
    if cache_index is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    else:
        idx = jnp.broadcast_to(jnp.asarray(cache_index, jnp.int32), (B,))
        positions = idx[:, None] + jnp.arange(S)[None, :]

    aux_total = jnp.zeros((), jnp.float32)
    layer_base = 0
    new_caches = [] if caches is not None else None
    seg_caches = caches or [None] * len(params["segments"])

    for si, ((pat, rep), pos_params) in enumerate(
            zip(cfg.block_pattern(), params["segments"])):
        cache_in = seg_caches[si]

        def body(carry, xs):
            x, aux, lid = carry
            blkp, blkc = xs
            outc = []
            for pi, blk in enumerate(pat):
                c = blkc[pi] if blkc is not None else None
                fn = apply_block
                if remat:
                    # static: cfg, block-type, use_pallas (python values)
                    fn = jax.checkpoint(apply_block, static_argnums=(1, 2, 9),
                                        prevent_cse=False)
                x, nc, a = fn(blkp[pi], cfg, blk, x, positions, rng_ctx,
                              lid + pi, c, cache_index, use_pallas)
                x = _maybe_seq_shard(x, cfg)
                outc.append(nc)
                aux = aux + a
            outc = outc if blkc is not None else None
            return (x, aux, lid + len(pat)), outc

        xs = (pos_params, cache_in)
        if cfg.scan_layers:
            (x, aux_total, layer_base), out_caches = jax.lax.scan(
                body, (x, aux_total, jnp.int32(layer_base)), xs)
        else:
            # unrolled: exact per-layer cost analysis (scan bodies are counted
            # once by XLA; the dry-run's reduced-depth variants use this path)
            carry = (x, aux_total, jnp.int32(layer_base))
            outs = []
            for ri in range(rep):
                xs_i = jax.tree.map(lambda a: a[ri], xs)
                carry, out_i = body(carry, xs_i)
                outs.append(out_i)
            (x, aux_total, layer_base) = carry
            out_caches = None if outs[0] is None else jax.tree.map(
                lambda *ls: jnp.stack(ls), *outs)
        if new_caches is not None:
            new_caches.append(out_caches)

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps, use_pallas=use_pallas)
    if cfg.tie_embeddings:
        logits = x @ params["embed"]["embedding"].T
    else:
        logits = L.lm_logits(params["head"], x)
    return logits, new_caches, aux_total


def init_caches(cfg: ModelConfig, batch: int, max_len: int):
    """Stacked caches matching the scan layout: per segment, per pattern pos,
    leading `repeats` axis."""
    caches = []
    for pat, rep in cfg.block_pattern():
        pos_caches = []
        for blk in pat:
            one = init_block_cache(cfg, blk, batch, max_len)
            stacked = jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (rep,) + a.shape), one)
            pos_caches.append(stacked)
        caches.append(pos_caches)
    return caches


def cache_shapes(cfg: ModelConfig, batch: int, max_len: int):
    return jax.eval_shape(lambda: init_caches(cfg, batch, max_len))


# --------------------------------------------------------------------------
# Loss / steps
# --------------------------------------------------------------------------
def softmax_xent(logits, labels, mask=None):
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0] - logz
    if mask is None:
        return -jnp.mean(ll)
    return -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def train_loss(params, cfg: ModelConfig, batch, rng_ctx: Optional[L.RngCtx] = None,
               use_pallas: bool = False, remat: bool = False):
    logits, _, aux = forward(params, cfg, batch["tokens"], rng_ctx=rng_ctx,
                             prefix_embeds=batch.get("prefix_embeds"),
                             use_pallas=use_pallas, remat=remat)
    P = 0 if batch.get("prefix_embeds") is None else batch["prefix_embeds"].shape[1]
    tok_logits = logits[:, P:, :]
    loss = softmax_xent(tok_logits[:, :-1], batch["labels"][:, 1:])
    return loss + aux


def decode_step(params, cfg: ModelConfig, tokens, caches, cache_index,
                prefix_embeds=None):
    """One-token decode: tokens [B,1] -> (logits [B,1,V], new caches)."""
    logits, new_caches, _ = forward(params, cfg, tokens, caches=caches,
                                    cache_index=cache_index,
                                    prefix_embeds=prefix_embeds)
    return logits[:, -1:, :], new_caches


def prefill(params, cfg: ModelConfig, tokens, caches, prefix_embeds=None):
    """Prefill: write the whole prompt into the caches (index 0)."""
    logits, new_caches, _ = forward(params, cfg, tokens, caches=caches,
                                    cache_index=0, prefix_embeds=prefix_embeds)
    return logits[:, -1:, :], new_caches
