"""Mamba2 (SSD — state-space duality) block, arXiv:2405.21060.

Training path uses the chunked SSD algorithm (matmul-rich, MXU friendly);
decode path uses the O(1) recurrent state update.  The chunk scan's inner
computation is also available as a Pallas kernel (kernels/ssd_scan.py); the
pure-jnp path here doubles as its oracle.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import _dense, init_rmsnorm, rmsnorm


def init_mamba(key, cfg: ModelConfig) -> Dict[str, Any]:
    d, di = cfg.d_model, cfg.d_inner
    ds, ng, H = cfg.ssm_state, cfg.ssm_ngroups, cfg.ssm_heads
    dt = cfg.jnp_dtype
    ks = jax.random.split(key, 4)
    d_in_proj = 2 * di + 2 * ng * ds + H      # z, x, B, C, dt
    conv_dim = di + 2 * ng * ds
    return {
        "in_proj": _dense(ks[0], (d, d_in_proj), dt),
        "conv_w": _dense(ks[1], (cfg.conv_kernel, conv_dim), dt, scale=cfg.conv_kernel ** -0.5),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)),
        "D": jnp.ones((H,), dtype=jnp.float32),
        "dt_bias": jnp.zeros((H,), dtype=jnp.float32),
        "out_norm": init_rmsnorm(di, dt),
        "out_proj": _dense(ks[2], (di, d), dt),
    }


def _split_proj(cfg: ModelConfig, zxbcdt):
    di, ds, ng, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_ngroups, cfg.ssm_heads
    z = zxbcdt[..., :di]
    x = zxbcdt[..., di:2 * di]
    B = zxbcdt[..., 2 * di:2 * di + ng * ds]
    C = zxbcdt[..., 2 * di + ng * ds:2 * di + 2 * ng * ds]
    dt = zxbcdt[..., 2 * di + 2 * ng * ds:]
    return z, x, B, C, dt


def ssd_chunked(x, dt, A, B, C, chunk: int, initial_state=None,
                use_pallas: bool = False):
    """Chunked SSD scan (Mamba2 alg. 3).

    x: [b, s, h, p]   (p = headdim)
    dt: [b, s, h]     (softplus-activated step sizes, >= 0)
    A: [h]            (negative decay rates)
    B, C: [b, s, g, n] (g groups broadcast over heads; n = d_state)
    Returns y: [b, s, h, p], final_state: [b, h, p, n]
    """
    if use_pallas:
        from repro.kernels import ops as kops
        return kops.ssd_scan(x, dt, A, B, C, chunk=chunk, initial_state=initial_state)
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    pad = (-s) % chunk
    if pad:
        # zero-pad the tail: dt=0 -> no state update, padded y discarded
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
        s_orig, s = s, s + pad
    nc = s // chunk
    rep = h // g
    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    Bc = B.reshape(b, nc, chunk, g, n)
    Cc = C.reshape(b, nc, chunk, g, n)
    # broadcast groups -> heads
    Bh = jnp.repeat(Bc, rep, axis=3)                    # [b,nc,c,h,n]
    Ch = jnp.repeat(Cc, rep, axis=3)

    dA = dtc * A[None, None, None, :]                   # [b,nc,c,h]  (<=0)
    cum = jnp.cumsum(dA, axis=2)                        # within-chunk cumsum
    seg_total = cum[:, :, -1, :]                        # [b,nc,h]

    # ---- intra-chunk (quadratic within chunk, matmul form) ----
    # L[i,j] = exp(cum[i]-cum[j]) for i>=j.  Mask BEFORE exp: upper-triangle
    # diffs are positive and overflow, and grad-of-where(inf) is NaN.
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]    # [b,nc,c,c,h]
    mask = jnp.tril(jnp.ones((chunk, chunk), dtype=bool))
    diff = jnp.where(mask[None, None, :, :, None], diff, -1e30)
    L = jnp.exp(diff)
    CB = jnp.einsum("bzchn,bzkhn->bzckh", Ch, Bh)           # [b,nc,c,c,h]
    xdt = xc * dtc[..., None]                               # [b,nc,c,h,p]
    y_intra = jnp.einsum("bzckh,bzckh,bzkhp->bzchp", CB, L.astype(CB.dtype),
                         xdt.astype(CB.dtype))

    # ---- chunk states (fp32 for the carried recurrence) ----
    decay_to_end = jnp.exp(seg_total[:, :, None, :] - cum)   # [b,nc,c,h]
    states = jnp.einsum("bzchn,bzch,bzchp->bzhpn",
                        Bh.astype(jnp.float32),
                        (dtc * decay_to_end).astype(jnp.float32),
                        xc.astype(jnp.float32))              # [b,nc,h,p,n]

    # ---- inter-chunk recurrence over chunk states ----
    seg_decay = jnp.exp(seg_total)                           # [b,nc,h]
    if initial_state is None:
        initial_state = jnp.zeros((b, h, p, n), dtype=jnp.float32)
    else:
        initial_state = initial_state.astype(jnp.float32)

    def scan_fn(carry, inp):
        st, dec = inp                                        # [b,h,p,n], [b,h]
        new = st + dec[:, :, None, None] * carry
        return new, carry                                    # emit state *entering* chunk

    states_t = jnp.moveaxis(states, 1, 0)                    # [nc,b,h,p,n]
    decay_t = jnp.moveaxis(seg_decay, 1, 0)                  # [nc,b,h]
    final_state, entry_states = jax.lax.scan(
        scan_fn, initial_state, (states_t, decay_t))
    entry_states = jnp.moveaxis(entry_states, 0, 1)          # [b,nc,h,p,n]

    # ---- contribution of entering state to outputs ----
    state_decay = jnp.exp(cum)                               # [b,nc,c,h]
    y_inter = jnp.einsum("bzchn,bzhpn,bzch->bzchp", Ch, entry_states.astype(Ch.dtype),
                         state_decay.astype(Ch.dtype))
    y = (y_intra + y_inter).reshape(b, s, h, p)
    if pad:
        y = y[:, :s_orig]
    return y, final_state


def ssd_decode_step(x, dt, A, B, C, state):
    """Single-token recurrent update.
    x: [b,1,h,p]; dt: [b,1,h]; B,C: [b,1,g,n]; state: [b,h,p,n]."""
    b, _, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    Bh = jnp.repeat(B[:, 0], rep, axis=1)        # [b,h,n]
    Ch = jnp.repeat(C[:, 0], rep, axis=1)
    dA = jnp.exp(dt[:, 0] * A[None, :])          # [b,h]
    upd = jnp.einsum("bh,bhp,bhn->bhpn", dt[:, 0], x[:, 0].astype(jnp.float32),
                     Bh.astype(jnp.float32))
    new_state = dA[:, :, None, None] * state + upd
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch.astype(jnp.float32))
    return y[:, None].astype(x.dtype), new_state


def _causal_conv(x, w, conv_state=None):
    """Depthwise causal conv. x: [b,s,c]; w: [k,c]; conv_state: [b,k-1,c]."""
    k = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), dtype=x.dtype)
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)           # [b, s+k-1, c]
    new_state = xp[:, -(k - 1):, :]
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :] for i in range(k))
    return out, new_state


def apply_mamba(params, cfg: ModelConfig, x,
                state: Optional[Dict] = None, use_pallas: bool = False,
                ) -> Tuple[jax.Array, Optional[Dict]]:
    """Mamba2 block.  state = {"ssm": [b,h,p,n], "conv": [b,k-1,conv_dim]}
    enables single-token decode; None = full-sequence training."""
    B_, S, _ = x.shape
    H, p_ = cfg.ssm_heads, cfg.ssm_headdim
    if cfg.mamba_split_proj:
        # slice the WEIGHT per component (weight reshard is per-layer-constant
        # bytes; activation reshard of the packed output would be per-token)
        w = params["in_proj"]
        di, ds, ng = cfg.d_inner, cfg.ssm_state, cfg.ssm_ngroups
        o1, o2, o3, o4 = di, 2 * di, 2 * di + ng * ds, 2 * di + 2 * ng * ds
        z = x @ w[:, :o1]
        xs = x @ w[:, o1:o2]
        Bv = x @ w[:, o2:o3]
        Cv = x @ w[:, o3:o4]
        dt = x @ w[:, o4:]
    else:
        zxbcdt = x @ params["in_proj"]
        z, xs, Bv, Cv, dt = _split_proj(cfg, zxbcdt)
    xBC = jnp.concatenate([xs, Bv, Cv], axis=-1)
    new_state = None
    if state is not None:
        xBC, conv_state = _causal_conv(xBC, params["conv_w"], state["conv"])
    else:
        xBC, conv_state = _causal_conv(xBC, params["conv_w"])
    xBC = jax.nn.silu(xBC)
    di, ds, ng = cfg.d_inner, cfg.ssm_state, cfg.ssm_ngroups
    xs = xBC[..., :di].reshape(B_, S, H, p_)
    Bv = xBC[..., di:di + ng * ds].reshape(B_, S, ng, ds)
    Cv = xBC[..., di + ng * ds:].reshape(B_, S, ng, ds)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    if state is not None and S == 1:
        # single-token decode: O(1) recurrent update
        y, ssm_state = ssd_decode_step(xs, dt, A, Bv, Cv, state["ssm"])
        new_state = {"ssm": ssm_state, "conv": conv_state}
    elif state is not None:
        # prefill-with-state: chunked scan carrying the state forward
        y, ssm_state = ssd_chunked(xs, dt, A, Bv, Cv,
                                   chunk=min(cfg.ssm_chunk, S),
                                   initial_state=state["ssm"])
        new_state = {"ssm": ssm_state, "conv": conv_state}
    else:
        y, _ = ssd_chunked(xs, dt, A, Bv, Cv, chunk=min(cfg.ssm_chunk, S),
                           use_pallas=use_pallas)
    y = y + xs * params["D"][None, None, :, None]
    y = y.reshape(B_, S, di).astype(x.dtype)
    y = rmsnorm(params["out_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return y @ params["out_proj"], new_state


def init_mamba_state(cfg: ModelConfig, batch: int):
    H, p_, n = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    conv_dim = cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state
    return {
        "ssm": jnp.zeros((batch, H, p_, n), dtype=jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, conv_dim), dtype=cfg.jnp_dtype),
    }
