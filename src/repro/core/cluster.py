"""VirtualCluster — the executable embodiment of ElasWave.

An in-process cluster of virtual workers arranged as a DP x PP grid.  Every
paper mechanism operates on REAL state with REAL numerics:

* per-layer parameters owned by pipeline stages (migratable pytrees);
* ZeRO-1 optimizer shards per (stage, dp-rank) under contiguous or
  interleaved layouts (core/zero.py), stored on the flat-state backbone
  (core/statespace.py): one contiguous fp32 buffer per component per stage,
  with memoized interval tables replacing per-call ``owner_intervals``
  rebuilds;
* per-step ring snapshots to host memory (core/fabric/snapshot.py);
* live remap on shrink (core/fabric/remap.py) — actual array movement,
  integrity-checked;
* dynamic communicator group edits (core/communicator.py);
* dataflow resizing with exact gradient weighting (planners/dataflow.py);
* content-addressed RNG (= RNG resharding) vs a deliberately rank-addressed
  "naive" mode for the §7.5 ablation;
* DVFS / fail-slow factors feed the 1F1B timing simulator.

Gradients are computed with jax.grad over the *full* model per micro-batch
slice (the logically-centralized equivalent of the pipeline's math), so the
elastic run's loss trajectory can be compared bit-for-bit-ish against a
fault-free run.  The distribution layer (who owns what, what moves on which
event, what it costs) is exactly the paper's; see DESIGN.md §3.

Two step/recovery implementations share this state:

* the **fast path** (default) — one jitted, ``vmap``-batched call over the
  step's micro-batches with a single ``device_get``, one fused host-side
  Adam update per stage, indexed-scatter parameter write-back, and batched
  recovery that only rebuilds the stages an event actually touches;
* the **seed path** (``fast_path=False``, ``core/legacy.py``) — the original
  per-item / per-shard / per-entry loops, kept as the numerics oracle and
  benchmark baseline.  ``tests/test_fast_path_numerics.py`` asserts the two
  produce bit-identical loss trajectories and shard contents through
  fail-stop + scale-out events.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.flatten_util import ravel_pytree

from repro.data.pipeline import GlobalBatchSampler, materialize_samples
from repro.models import registry as R
from repro.models.config import ModelConfig
from repro.models.layers import RngCtx
from repro.optim.adam import AdamConfig, adam_update_flat_np
from . import legacy
from .agent import Agent, Probe
from .clusterview import GroupDelta
from .controller import ElasticController
from .communicator import DynamicCommunicator, build_hybrid_groups
from .cost_model import HardwareSpec, SegmentCosts
from .engine import RecoveryPlan, ScheduleEngine
from .events import ElasticEvent, EventKind
from .fabric.remap import LiveRemap, RemapPlan
from .fabric.snapshot import SnapshotPool
from .migration import MigrationSpec, migration_timing
from .pipeline import StageTiming, simulate_1f1b
from .statespace import (COMPONENTS, HEAD, STEM, EntryFlattener, StageState,
                         get_table)


def _recovery_record(*, detect: float = 0.0, plan: float = 0.0,
                     communicator: float = 0.0, remap: float = 0.0,
                     migration: float = 0.0, verify: float = 0.0,
                     rng_moves: int = 0, degraded: int = 0,
                     overlap_saved: float = 0.0) -> Dict[str, float]:
    """One schema for every recovery record, regardless of event kind, so
    ``_merge_recovery_records`` output shape never depends on the event.

    ``verify`` (snapshot integrity scan) is a timed phase included in the
    total; ``degraded`` counts tolerance-tier shard rebuilds (zeroed Adam
    moments) and ``overlap_saved`` is stall hidden inside a preemption-notice
    window — info counters, not stall time, so they stay out of the total."""
    return {"detect": detect, "plan": plan, "communicator": communicator,
            "remap": remap, "migration": migration, "verify": verify,
            "total": detect + plan + communicator + remap + migration + verify,
            "rng_moves": rng_moves, "degraded": degraded,
            "overlap_saved": overlap_saved}


class VirtualCluster:
    def __init__(self, cfg: ModelConfig, dp: int, pp: int, *,
                 global_batch: int, num_micro: int, seq_len: int,
                 seed: int = 0, zero_layout: str = "interleaved",
                 adam: Optional[AdamConfig] = None,
                 rng_mode: str = "reshard",        # "reshard" | "naive"
                 hw: Optional[HardwareSpec] = None,
                 mem_cap: Optional[float] = None,
                 snapshot_enabled: bool = True,
                 non_blocking_migration: bool = True,
                 fast_path: bool = True,
                 use_pallas: Optional[bool] = None):
        assert global_batch % num_micro == 0
        assert (global_batch // num_micro) % dp == 0, "initial even split"
        if use_pallas is None:
            # env knob mirrors the fast_path/legacy pattern: default off keeps
            # the plain-jnp path bit-identical; REPRO_USE_PALLAS=1 routes the
            # forward through the Pallas kernels (tolerance-tier numerics,
            # see core/invariants.KernelConsistencyChecker)
            import os
            use_pallas = os.environ.get("REPRO_USE_PALLAS", "0") == "1"
        self.use_pallas = bool(use_pallas)
        self.cfg = cfg
        self.dp0, self.pp = dp, pp
        self.global_batch, self.num_micro, self.seq = global_batch, num_micro, seq_len
        self.adam = adam or AdamConfig(master_weights=True)
        self.rng_mode = rng_mode
        self.hw = hw or HardwareSpec()
        self.zero_layout = zero_layout
        self.snapshot_enabled = snapshot_enabled
        self.non_blocking_migration = non_blocking_migration
        self.fast_path = fast_path
        self.sampler = GlobalBatchSampler(global_batch, seed)
        self.base_key = jax.random.key(seed)

        # ---- model state (fp32 for deterministic CPU math) ----
        L = cfg.num_layers
        key = jax.random.key(seed + 1)
        ks = jax.random.split(key, L + 2)
        self.stem = R.init_stem(ks[0], cfg)
        self.layer_params: List[Any] = [R.init_layer(ks[1 + i], cfg, i)
                                        for i in range(L)]
        self.head = R.init_head(ks[L + 1], cfg)
        self.flattener = EntryFlattener()
        self.flattener.build_model_unraveler(self.stem, self.layer_params,
                                             self.head)
        # balanced initial layer assignment
        per = L // pp
        rem = L % pp
        ranges, a = [], 0
        for p in range(pp):
            b = a + per + (1 if p < rem else 0) - 1
            ranges.append((a, b))
            a = b + 1
        self.layer_assignment: List[Tuple[int, int]] = ranges

        # ---- workers / health ----
        self.alive = np.ones((dp, pp), dtype=bool)
        self.freq = np.ones((dp, pp))
        self.slow = np.ones((dp, pp))
        self.mem_used = np.zeros((dp, pp))   # fraction of capacity (probes)

        # ---- ZeRO stage states + snapshots ----
        self.stages: List[StageState] = []
        self.snapshots: List[SnapshotPool] = []
        for p in range(pp):
            st = self._build_stage_state(p, list(range(dp)))
            self.stages.append(st)
            pool = SnapshotPool(dp, self.adam, batched=fast_path)
            if snapshot_enabled:
                pool.bootstrap(0, [st.shard(r) for r in st.dp_ranks])
            self.snapshots.append(pool)

        # ---- control plane ----
        self.comm = DynamicCommunicator(build_hybrid_groups(dp, pp))
        # rank = d * pp + p, so the agent's stage topology is rank % pp —
        # fail-slow verdicts compare against stage peers, not the fleet
        self.agent = Agent(dp * pp,
                           stage_of={r: r % pp for r in range(dp * pp)})
        self.controller = ElasticController(self.agent)
        self.engine = ScheduleEngine(cfg, seq_len, self.hw, mem_cap)
        self.remapper = LiveRemap()

        # ---- bookkeeping ----
        self.step_count = 0
        self.opt_step = 0
        self.per_rank_mbs: List[int] = [global_batch // num_micro // dp] * dp
        self.grad_weights: List[float] = [1.0 / dp] * dp
        self.losses: List[float] = []
        self.recoveries: List[Dict[str, float]] = []
        self.warnings: List[ElasticEvent] = []   # advisory (OOM_RISK) events
        self.seg = SegmentCosts.build(cfg, seq_len, self.hw)
        self._grad_fn_cache: Dict[int, Any] = {}
        self._scan_grad_cache: Dict[Tuple[int, int], Any] = {}

    # ------------------------------------------------------------------
    # state-space helpers
    # ------------------------------------------------------------------
    def _entry_tree(self, entry: int):
        if entry == STEM:
            return self.stem
        if entry == HEAD:
            return self.head
        return self.layer_params[entry]

    def _stage_entries(self, p: int) -> List[int]:
        a, b = self.layer_assignment[p]
        entries = list(range(a, b + 1))
        if p == 0:
            entries = [STEM] + entries
        if p == self.pp - 1:
            entries = entries + [HEAD]
        return entries

    def _build_stage_state(self, p: int, dp_ranks: List[int]) -> StageState:
        entries = self._stage_entries(p)
        vecs = [self.flattener.flatten_entry(e, self._entry_tree(e))
                for e in entries]
        sizes = [v.size for v in vecs]
        full = np.concatenate(vecs) if vecs else np.zeros(0, np.float32)
        return StageState.from_full(
            entries, sizes, self.zero_layout, dp_ranks,
            {"master": full, "mu": np.zeros_like(full),
             "nu": np.zeros_like(full)})

    def _stage_full_vec(self, st: StageState, comp: str = "master") -> np.ndarray:
        """All-gather equivalent: reassemble the stage's full state vector."""
        if self.fast_path:
            return st.full(comp)
        return legacy.stage_full_vec(st, comp)

    def _write_params_from_masters(self):
        if not self.fast_path:
            return legacy.write_params_from_masters(self)
        # indexed scatter (one fancy-index per stage, straight into the
        # model-flat buffer) + ONE jitted model unravel (a single
        # host->device transfer for the whole model)
        vec = np.empty(sum(st.total for st in self.stages), dtype=np.float32)
        off = 0
        for st in self.stages:
            st.table.scatter(st.flat["master"], out=vec[off:off + st.total])
            off += st.total
        self.stem, self.layer_params, self.head = \
            self.flattener.unflatten_model(vec)

    # ------------------------------------------------------------------
    # training math
    # ------------------------------------------------------------------
    def _loss_fn(self, stem, layers, head, tokens, labels, step_key, sample_ids):
        # self.use_pallas routes the forward through the Pallas kernels; the
        # legacy path shares this function via _grad_fn, so a fast/legacy twin
        # pair stays bit-identical in either kernel mode
        cfg = self.cfg
        x = R.apply_stem(stem, cfg, tokens, use_pallas=self.use_pallas)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
        ctx = RngCtx(step_key=step_key, sample_ids=sample_ids,
                     deterministic=cfg.dropout_rate <= 0.0)
        aux_total = jnp.zeros((), jnp.float32)
        for lid in range(cfg.num_layers):
            x, aux = R.apply_layer(layers[lid], cfg, lid, x, positions, ctx,
                                   use_pallas=self.use_pallas)
            aux_total = aux_total + aux
        logits = R.apply_head(head, cfg, x, use_pallas=self.use_pallas)
        from repro.models.transformer import softmax_xent
        return softmax_xent(logits[:, :-1], labels[:, 1:]) + aux_total

    def _grad_fn(self, batch_size: int):
        if batch_size not in self._grad_fn_cache:
            self._grad_fn_cache[batch_size] = jax.jit(
                jax.value_and_grad(self._loss_fn, argnums=(0, 1, 2)))
        return self._grad_fn_cache[batch_size]

    def _batched_grad_fn(self, batch_size: int, n_items: int):
        """One jitted call over ``n_items`` stacked micro-batches of
        ``batch_size``: per-item loss + flat gradient, no host sync inside
        the step.  ``vmap`` batches the independent per-item grads (measured
        bit-identical to the per-item jit calls across model families — a
        ``lax.scan`` over items is too, but ~1.5x slower on CPU)."""
        key = (batch_size, n_items)
        fn = self._scan_grad_cache.get(key)
        if fn is None:
            grad_one = jax.value_and_grad(self._loss_fn, argnums=(0, 1, 2))

            def batched(stem, layers, head, toks, labs, base_key, step, sids):
                # fold_in inside the jit: integer PRNG ops, bit-identical to
                # the eager fold, and one less host dispatch per step
                step_key = jax.random.fold_in(base_key, step)

                def one(tok, lab, sid):
                    loss, grads = grad_one(stem, layers, head, tok, lab,
                                           step_key, sid)
                    return loss, ravel_pytree(grads)[0]
                return jax.vmap(one)(toks, labs, sids)

            fn = jax.jit(batched)
            self._scan_grad_cache[key] = fn
        return fn

    def _micro_grads(self, step: int) -> Tuple[float, np.ndarray]:
        """Weighted accumulation over micro-batches and DP slices — the
        numerics of dataflow-resized hybrid-parallel training.

        Fast path: micro-batches are bucketed by size (uneven after a
        failure), each bucket runs as ONE jitted vmap-batched call, and one
        ``device_get`` per bucket (one per step in the common even-split
        case) fetches all losses + flat per-item gradients, which then
        accumulate host-side in the seed's exact (micro, rank) order.
        Returns ``(total_loss, model-flat gradient)``.
        """
        ids_by_rank = self.sampler.partition(step, self.per_rank_mbs,
                                             self.num_micro)
        items: List[Tuple[int, np.ndarray]] = []    # (rank, ids), seed order
        for m in range(self.num_micro):
            for r, rank_ids in enumerate(ids_by_rank):
                ids = rank_ids[m]
                if len(ids):
                    items.append((r, ids))
        n = len(items)
        buckets: Dict[int, List[int]] = {}
        for k, (r, ids) in enumerate(items):
            buckets.setdefault(len(ids), []).append(k)
        loss_rows: List[Any] = [None] * n
        flat_rows: List[Any] = [None] * n
        for B, idxs in buckets.items():
            # one hash-materialization for the whole bucket (elementwise in
            # (sample_id, position), so reshape == per-item materialize)
            ids_cat = np.concatenate([items[k][1] for k in idxs])
            toks = materialize_samples(ids_cat, self.seq,
                                       self.cfg.vocab_size
                                       ).reshape(len(idxs), B, self.seq)
            if self.rng_mode == "reshard":
                sids = ids_cat.astype(np.int32).reshape(len(idxs), B)
            else:   # naive: rank-addressed streams (the paper's "w/o")
                sids = np.stack([np.arange(B, dtype=np.int32)
                                 + np.int32(items[k][0] * 100003)
                                 for k in idxs])
            jt = jnp.asarray(toks)
            # one device_get per bucket (exactly one per step in the even-
            # split common case) for all losses + flat grads together
            losses, flats = jax.device_get(self._batched_grad_fn(B, len(idxs))(
                self.stem, self.layer_params, self.head, jt, jt,
                self.base_key, np.uint32(step), jnp.asarray(sids)))
            for i, k in enumerate(idxs):
                loss_rows[k] = losses[i]
                flat_rows[k] = flats[i]
        # host-side weighted accumulation in the seed's (micro, rank) order;
        # numpy f32 elementwise ops are bit-identical to the seed's eager
        # per-leaf jnp ops (IEEE correctly-rounded either way)
        acc = None
        total_loss = 0.0
        for k, (r, _ids) in enumerate(items):
            w = self.grad_weights[r] / self.num_micro
            gw = flat_rows[k] * np.float32(w)
            acc = gw if acc is None else acc + gw
            total_loss += float(loss_rows[k]) * w
        return total_loss, acc

    def train_step(self) -> float:
        if not self.fast_path:
            return legacy.train_step(self)
        step = self.step_count
        loss, gflat = self._micro_grads(step)
        self.opt_step += 1
        grad_shard_by_stage: List[List[np.ndarray]] = []
        off = 0
        for st in self.stages:
            # this stage's slice of the model-flat gradient, permuted to
            # shard order with one fancy-index
            gstage = gflat[off:off + st.total]
            off += st.total
            tbl = st.table
            gshard = tbl.gather(gstage)
            grad_shard_by_stage.append(tbl.split(gshard))
            if st.total:
                # ONE fused host-side Adam update over the stage's flat
                # buffers (bit-identical to the seed's per-shard eager
                # updates); the per-rank shards are views into the result
                st.flat = adam_update_flat_np(gshard, st.flat, self.opt_step,
                                              self.adam)
        self._write_params_from_masters()
        if self.snapshot_enabled:
            for p in range(self.pp):
                self.snapshots[p].snapshot_step(step, grad_shard_by_stage[p],
                                                self.opt_step)
        self.step_count += 1
        self.losses.append(loss)
        return loss

    # ------------------------------------------------------------------
    # timing model (feeds throughput benchmarks)
    # ------------------------------------------------------------------
    def simulate_step_time(self) -> float:
        stages = []
        per_micro = self.global_batch // self.num_micro
        for p, (a, b) in enumerate(self.layer_assignment):
            live = [d for d in range(self.dp0) if self.alive[d, p]]
            width = max(len(live), 1)
            mbs = -(-per_micro // width)
            worst = max((self.slow[d, p] / self.freq[d, p] for d in live),
                        default=1.0)
            eff = self.hw.peak_flops * self.hw.mfu / worst
            fl = self.seg.seg_fwd_flops(a, b, mbs)
            stages.append(StageTiming(fl / eff, 2 * fl / eff, self.num_micro))
        return simulate_1f1b(stages).step_time

    # ------------------------------------------------------------------
    # elasticity
    # ------------------------------------------------------------------
    def inject_fail_stop(self, d: int, p: int):
        self.alive[d, p] = False

    def inject_fail_slow(self, d: int, p: int, factor: float):
        self.slow[d, p] = factor

    def inject_mem_pressure(self, d: int, p: int, used_fraction: float):
        """Set the fraction of device memory worker (d, p) reports via its
        probes — feeds the Agent's OOM early-warning trend."""
        self.mem_used[d, p] = used_fraction

    def detect_and_recover(self) -> Optional[Dict[str, float]]:
        """Controller probes -> events -> ScheduleEngine plan -> executor.

        The loop bound is the controller's worst-case confirmation threshold
        (``max_confirm_misses``), not the bare miss limit: a rank that
        flapped earlier has an exponentially backed-off bar to clear."""
        probes = []
        base_t = self.simulate_step_time()
        for d in range(self.dp0):
            for p in range(self.pp):
                rank = d * self.pp + p
                probes.append(Probe(self.step_count, rank,
                                    heartbeat=bool(self.alive[d, p]),
                                    step_seconds=base_t * self.slow[d, p],
                                    mem_used=float(self.mem_used[d, p])))
        events: List[ElasticEvent] = []
        for _ in range(self.controller.max_confirm_misses()):
            events = self.controller.observe(probes)
            if events:
                break
        if not events:
            return None
        ev = events[0]
        return self.apply_event(ev)

    def apply_event(self, ev: ElasticEvent) -> Dict[str, float]:
        """Recovery Executor entry point: one elastic event -> itemized MTTR.

        Multi-rank events (failure bursts) are applied as a deterministic
        rank-ordered sequence of single-rank recoveries; detection is paid
        once (the heartbeats are missed concurrently) and the control-plane
        phases accumulate."""
        t_detect = 0.5  # heartbeat interval bound (modeled)
        cells = [(r // self.pp, r % self.pp) for r in sorted(ev.ranks)]
        if ev.kind in (EventKind.FAIL_STOP, EventKind.SCALE_IN):
            recs = [self.recover_fail_stop(d, p,
                                           t_detect=t_detect if i == 0 else 0.0)
                    for i, (d, p) in enumerate(cells)]
            return _merge_recovery_records(recs)
        if ev.kind == EventKind.FAIL_SLOW:
            recs = [self.recover_fail_slow(d, p, ev.slow_factor,
                                           t_detect=t_detect if i == 0 else 0.0)
                    for i, (d, p) in enumerate(cells)]
            return _merge_recovery_records(recs)
        if ev.kind == EventKind.PREEMPT_NOTICE:
            # proactive drain: no detection phase (the scheduler TOLD us),
            # and recovery work overlaps the notice window
            recs = [self.drain_rank(d, p, deadline=ev.deadline)
                    for d, p in cells]
            return _merge_recovery_records(recs)
        if ev.kind == EventKind.SCALE_OUT:
            recs = [self.recover_scale_out(d, p) for d, p in cells]
            return _merge_recovery_records(recs)
        if ev.kind == EventKind.DVFS_SET:
            for d, p in cells:
                self.freq[d, p] = ev.freq
            return _recovery_record()
        if ev.kind == EventKind.OOM_RISK:
            # advisory: record the warning, no state or liveness change
            self.warnings.append(ev)
            return _recovery_record()
        raise ValueError(f"unsupported elastic event kind here: {ev.kind}")

    def build_view(self):
        """The cluster's health/topology state as the shared rank-vectorized
        ``core.clusterview.ClusterView`` (the analytic-plane currency).  The
        view's buffers alias ``self.alive``/``self.freq``/``self.slow``, so
        it is a live window, not a snapshot."""
        from .clusterview import ClusterView
        return ClusterView(self.dp0, self.pp, self.global_batch,
                           self.num_micro, self.seq,
                           list(self.layer_assignment),
                           alive=self.alive, freq=self.freq, slow=self.slow,
                           mem_cap=self.engine.mem_cap)

    def plan_event(self, ev: ElasticEvent) -> RecoveryPlan:
        """Mark the event's (single) rank dead and ask the ScheduleEngine for
        a joint Dataflow/Graph/DVFS/RNG RecoveryPlan (paper §4)."""
        rank = ev.ranks[0]
        d, p = rank // self.pp, rank % self.pp
        st = self.stages[p]
        if d not in st.dp_ranks:
            raise ValueError(
                f"rank {rank} (dp={d}, stage={p}) was already removed from "
                f"the stage's DP group; scenario traces must not re-fail a "
                f"recovered rank")
        self.alive[d, p] = False
        old_sample_rank = self._current_sample_assignment()
        return self.engine.plan_view(
            ev, self.build_view(), failed_dp_ranks=[d],
            old_sample_rank=old_sample_rank, dp=len(st.dp_ranks))

    def recover_fail_stop(self, d: int, p: int, t_detect: float = 0.5,
                          ) -> Dict[str, float]:
        """Full ElasWave recovery: plan + communicator edit + live remap +
        layer migration + dataflow/DVFS/RNG application."""
        ev = ElasticEvent(EventKind.FAIL_STOP, self.step_count,
                          (d * self.pp + p,))
        return self.apply_plan(self.plan_event(ev), t_detect=t_detect)

    def apply_plan(self, plan: RecoveryPlan, t_detect: float = 0.5,
                   drain: bool = False) -> Dict[str, float]:
        """Execute a shrink RecoveryPlan (the paper's event -> plan -> apply
        path): snapshot verification, communicator edit, live remap, layer
        migration, dataflow resize, DVFS top-up.  Returns the itemized MTTR
        record.

        ``drain=True`` is the proactive PREEMPT_NOTICE path: the departing
        rank's device state is still addressable (corrupt snapshots re-derive
        from it bit-for-bit), and the communicator/remap/migration work
        overlaps the notice window — only the part exceeding
        ``plan.event.deadline`` stalls training; the hidden part is recorded
        as ``overlap_saved``."""
        ev = plan.event
        rank = ev.ranks[0]
        d, p = rank // self.pp, rank % self.pp

        # --- snapshot integrity: verify (and repair) recovery sources ---
        t_verify, n_degraded = self._verify_snapshot_sources(
            p, failed=[d], drain=drain)

        # --- communicator: in-place edit ---
        comm_stats = self.comm.apply(GroupDelta.shrink([d * self.pp + p]),
                                     "edit")

        # --- live remap of stage p's optimizer state ---
        t_remap, remap_plan = self._live_remap_stage(p, failed=[d])

        # --- layer migrations (graph plan) ---
        t_migr = 0.0
        if plan.graph.feasible and plan.migrations:
            t_migr = self._apply_migrations(plan.migrations,
                                            list(plan.graph.stage_ranges))

        # --- dataflow: resize micro batches over surviving width ---
        self._apply_dataflow()

        # --- DVFS ---
        for dv in plan.dvfs:
            if dv.rank >= 0:
                for dd in range(self.dp0):
                    if self.alive[dd, dv.rank]:
                        self.freq[dd, dv.rank] = max(self.freq[dd, dv.rank], dv.freq)

        # the departed rank leaves the Agent's monitored set (it must not
        # accrue misses forever; a SCALE_OUT rejoin re-registers it)
        self.agent.remove_rank(rank)

        # --- overlap accounting (proactive drain only) ---
        t_comm = comm_stats.seconds
        overlap_saved = 0.0
        work = t_comm + t_remap + t_migr
        if drain and work > 0:
            stall = max(0.0, work - ev.deadline)
            scale = stall / work
            overlap_saved = work - stall
            t_comm *= scale
            t_remap *= scale
            t_migr *= scale

        rec = _recovery_record(
            detect=t_detect, plan=plan.plan_seconds,
            communicator=t_comm, remap=t_remap, migration=t_migr,
            verify=t_verify,
            rng_moves=len(plan.rng.layer_stream_moves)
            + len(plan.rng.sample_stream_moves),
            degraded=n_degraded, overlap_saved=overlap_saved)
        self.recoveries.append(rec)
        return rec

    def drain_rank(self, d: int, p: int, deadline: float = 120.0,
                   ) -> Dict[str, float]:
        """Proactive drain on PREEMPT_NOTICE: run the full shrink recovery —
        verified snapshot flush, communicator edit, live remap, migration —
        *inside* the notice window, before the preemption lands.  Detection
        cost is zero (the scheduler told us) and up to ``deadline`` seconds
        of recovery work overlap ongoing training."""
        ev = ElasticEvent(EventKind.PREEMPT_NOTICE, self.step_count,
                          (d * self.pp + p,), deadline=deadline)
        return self.apply_plan(self.plan_event(ev), t_detect=0.0, drain=True)

    def _verify_snapshot_sources(self, p: int, failed: List[int],
                                 drain: bool = False) -> Tuple[float, int]:
        """Online verification (paper §5.1) of the ring-snapshot shards the
        remap is about to trust, with graceful degradation:

        * checksum intact → use the shard (``verified``);
        * corrupt + rank still draining → re-derive bit-for-bit from the
          departing rank's device shard (``rederived``);
        * corrupt + rank dead → rebuild the fp32 master from the replicated
          model parameters (bit-exact: after write-back params == masters)
          with zeroed Adam moments (``rebuilt``, counted as degraded).

        Repairs land in ``pool.host`` *before* ``_live_remap_stage`` reads
        it, so both the fast and the legacy remap paths stay untouched.
        Returns (modeled verify seconds, degraded-shard count).
        """
        if not self.snapshot_enabled:
            return 0.0, 0
        st = self.stages[p]
        pool = self.snapshots[p]
        if not pool.integrity:
            return 0.0, 0
        t_verify, degraded = 0.0, 0
        old_ranks = list(st.dp_ranks)
        for f in failed:
            j = old_ranks.index(f)
            if pool.host[pool.holder_of(j)] is None:
                continue    # holder dead: remap skips this source anyway
            t_verify += pool.verify_cost_seconds(j)
            tier, _ = pool.verify_and_repair(
                j,
                device_state=st.shard(f) if drain else None,
                master_fallback=None if drain else
                (lambda jj=j: self._master_shard_from_params(p, jj)))
            if tier == "rebuilt":
                degraded += 1
        return t_verify, degraded

    def _master_shard_from_params(self, p: int, j: int) -> np.ndarray:
        """Tolerance-tier rebuild source: shard ``j`` of stage ``p``'s fp32
        master, regenerated from the replicated model parameters (which equal
        the masters bit-for-bit after ``_write_params_from_masters``)."""
        st = self.stages[p]
        vecs = [self.flattener.flatten_entry(e, self._entry_tree(e))
                for e in st.entries]
        full = np.concatenate(vecs) if vecs else np.zeros(0, np.float32)
        return st.table.split(st.table.gather(full))[j]

    def recover_scale_out(self, d: int, p: int) -> Dict[str, float]:
        """Worker (d, p) (re)joins: communicator edit (only the new member's
        links), reverse live-remap widening the stage's ZeRO group, dataflow
        resize back to the wider DP width (paper Fig. 8 scale-up)."""
        assert not self.alive[d, p], "worker already alive"
        self.alive[d, p] = True
        # dynamic rank registration: the (re)joining worker gets fresh
        # heartbeat/step-time tracking (clears any stale dead verdict, so a
        # rejoin that later fails again is re-detected)
        self.agent.add_rank(d * self.pp + p, stage=p)
        self.controller.note_join(d * self.pp + p)
        comm_stats = self.comm.apply(
            GroupDelta.grow([(g, d * self.pp + p)
                             for g in self.comm.groups
                             if g == f"dp_stage{p}_tp0"]), "edit")
        t_remap = self._widen_stage(p, joining=[d])
        self._apply_dataflow()
        rec = _recovery_record(communicator=comm_stats.seconds, remap=t_remap)
        self.recoveries.append(rec)
        return rec

    def _widen_stage(self, p: int, joining: List[int]) -> float:
        """Reverse remap: redistribute the stage state over a WIDER group.
        Sources: current owners' device shards; targets: new layout."""
        if not self.fast_path:
            return legacy.widen_stage(self, p, joining)
        st = self.stages[p]
        old_ranks = list(st.dp_ranks)
        tbl = st.table
        new_ranks = old_ranks + [j for j in joining if j not in old_ranks]
        pre = {c: st.full(c) for c in COMPONENTS}
        device_parts = {r: tbl.owner_intervals(old_ranks.index(r))
                        for r in old_ranks}
        new_tbl = get_table(st.layout_kind, st.sizes, len(new_ranks))
        target_parts = {r: new_tbl.owner_intervals(j)
                        for j, r in enumerate(new_ranks)}
        plan = self.remapper.compute_plan(st.total, device_parts, {},
                                          target_parts)
        shards = st.shards      # views, built once for all components
        empty = np.zeros(0, np.float32)
        new_shards: Dict[int, Dict[str, np.ndarray]] = {r: {} for r in new_ranks}
        for comp in COMPONENTS:
            device_data = {r: tbl.segments(old_ranks.index(r), shards[r][comp])
                           for r in old_ranks}
            assembled = self.remapper.execute(plan, st.total, device_data, {})
            for r in new_ranks:
                new_shards[r][comp] = assembled.get(r, empty)
        st.replace_shards(new_ranks, new_shards)
        for comp in COMPONENTS:
            assert np.array_equal(st.full(comp), pre[comp]), \
                f"widen corrupted {comp}"
        self.snapshots[p] = SnapshotPool(len(new_ranks), self.adam,
                                         batched=True)
        if self.snapshot_enabled:
            self.snapshots[p].bootstrap(self.step_count,
                                        [st.shard(r) for r in new_ranks])
        return plan.est_seconds

    def recover_fail_slow(self, d: int, p: int, factor: float,
                          t_detect: float = 0.5) -> Dict[str, float]:
        """Straggler mitigation: rebalance layers away from the slow stage +
        DVFS top-up (no state loss)."""
        self.slow[d, p] = max(self.slow[d, p], factor)
        per_micro = self.global_batch // self.num_micro

        def t(pp_, a, b):
            live = [dd for dd in range(self.dp0) if self.alive[dd, pp_]]
            width = max(len(live), 1)
            mbs = -(-per_micro // width)
            worst = max((self.slow[dd, pp_] for dd in live), default=1.0)
            fl = self.seg.seg_fwd_flops(a, b, mbs)
            return 3 * fl / (self.hw.peak_flops * self.hw.mfu / worst)

        def mem(pp_, a, b):
            return self.seg.seg_mem(a, b, per_micro, inflight=self.pp)

        from .planners.graph import minimax_layer_partition
        plan = minimax_layer_partition(self.cfg.num_layers, self.pp, t, mem,
                                       [self.engine.mem_cap] * self.pp)
        t_migr = 0.0
        if plan.feasible:
            old_stage = _stage_of(self.layer_assignment, self.cfg.num_layers)
            new_stage = _stage_of(plan.stage_ranges, self.cfg.num_layers)
            moves = [(lid, old_stage[lid], new_stage[lid])
                     for lid in range(self.cfg.num_layers)
                     if old_stage[lid] != new_stage[lid]]
            if moves:
                t_migr = self._apply_migrations(moves, list(plan.stage_ranges))
        rec = _recovery_record(detect=t_detect, migration=t_migr)
        self.recoveries.append(rec)
        return rec

    # ------------------------------------------------------------------
    # executor pieces
    # ------------------------------------------------------------------
    def _current_sample_assignment(self) -> Dict[int, int]:
        out, cursor = {}, 0
        for r, sz in enumerate(self.per_rank_mbs):
            for _ in range(sz):
                out[cursor] = r
                cursor += 1
        return out

    def _apply_dataflow(self):
        # width of the narrowest stage defines surviving DP for data entry
        widths = [int(self.alive[:, p].sum()) for p in range(self.pp)]
        new_dp = max(min(widths), 1)
        from .planners.dataflow import plan_dataflow
        df = plan_dataflow(self.global_batch, self.num_micro, new_dp)
        self.per_rank_mbs = list(df.micro_batch_sizes)
        self.grad_weights = list(df.grad_weights)

    def _live_remap_stage(self, p: int, failed: List[int],
                          ) -> Tuple[float, RemapPlan]:
        if not self.fast_path:
            return legacy.live_remap_stage(self, p, failed)
        st = self.stages[p]
        pool = self.snapshots[p]
        tbl = st.table
        old_ranks = list(st.dp_ranks)
        # record pre-failure full vectors for verification
        pre = {c: self._stage_full_vec_with_snapshots(p, c, failed)
               for c in COMPONENTS}

        surviving = [r for r in old_ranks if r not in failed]
        device_parts = {r: tbl.owner_intervals(old_ranks.index(r))
                        for r in surviving}
        host_parts = {}
        for f in failed:
            holder = pool.holder_of(old_ranks.index(f))
            holder_rank = old_ranks[holder]
            if holder_rank in surviving and pool.host[holder] is not None:
                host_parts[f] = tbl.owner_intervals(old_ranks.index(f))
        new_tbl = get_table(st.layout_kind, st.sizes, len(surviving))
        target_parts = {r: new_tbl.owner_intervals(j)
                        for j, r in enumerate(surviving)}

        plan = self.remapper.compute_plan(st.total, device_parts, host_parts,
                                          target_parts)
        # execute with real arrays, per component; per-rank segment dicts are
        # zero-copy views of the flat buffers
        shards = st.shards
        empty = np.zeros(0, np.float32)
        new_shards: Dict[int, Dict[str, np.ndarray]] = {r: {} for r in surviving}
        for comp in COMPONENTS:
            device_data = {r: tbl.segments(old_ranks.index(r), shards[r][comp])
                           for r in surviving}
            host_data = {}
            for f in failed:
                holder = pool.holder_of(old_ranks.index(f))
                snap = pool.host[holder]
                if snap is None:
                    continue
                host_data[f] = tbl.segments(old_ranks.index(f), snap[comp])
            assembled = self.remapper.execute(plan, st.total, device_data,
                                              host_data)
            for r in surviving:
                new_shards[r][comp] = assembled.get(r, empty)
        st.replace_shards(surviving, new_shards)
        # verification (paper: online verification before resume)
        for comp in COMPONENTS:
            assert np.array_equal(st.full(comp), pre[comp]), \
                f"remap corrupted {comp}"
        # rebuild ring snapshot pool for the shrunken group
        self.snapshots[p] = SnapshotPool(len(surviving), self.adam,
                                         batched=True)
        if self.snapshot_enabled:
            self.snapshots[p].bootstrap(self.step_count,
                                        [st.shard(r) for r in surviving])
        return plan.est_seconds, plan

    def _stage_full_vec_with_snapshots(self, p: int, comp: str,
                                       failed: List[int]) -> np.ndarray:
        """Pre-failure ground truth: survivors' device state + failed ranks'
        snapshot state."""
        if not self.fast_path:
            return legacy.stage_full_vec_with_snapshots(self, p, comp, failed)
        st = self.stages[p]
        pool = self.snapshots[p]
        tbl = st.table
        full = np.zeros(st.total, dtype=np.float32)
        for j, r in enumerate(st.dp_ranks):
            if r not in failed:
                src = tbl.shard_view(st.flat[comp], j)
            else:
                snap = pool.host[pool.holder_of(j)]
                if snap is None:
                    continue
                src = snap[comp]
            tbl.scatter_shard(j, src, full)
        return full

    def _apply_migrations(self, moves: List[Tuple[int, int, int]],
                          new_ranges: List[Tuple[int, int]]) -> float:
        """Move layers between stages: optimizer-state slices (per layout) +
        parameter ownership.  Returns modeled stall seconds (MTTR).

        Fast path: only the stages whose entry list actually changes are
        rebuilt (a slice-move between two stages leaves the others' flat
        buffers and snapshot pools untouched); entry slices come from one
        gather per component per affected stage."""
        if not self.fast_path:
            return legacy.apply_migrations(self, moves, new_ranges)
        total_stall = 0.0
        # compute per-move timing with the migration model
        step_window = self.simulate_step_time()
        for (lid, src, dst) in moves:
            st_src = self.stages[src]
            pbytes = int(self.seg.param_bytes[lid])
            obytes = int(self.seg.opt_bytes[lid])
            spec = MigrationSpec((lid,), src, dst, pbytes, obytes,
                                 dp=len(st_src.dp_ranks),
                                 zero_layout=self.zero_layout,
                                 blocking=not self.non_blocking_migration)
            timing = migration_timing(spec, self.hw.link_bw, step_window)
            total_stall += timing.stall_seconds
        old_entries = {p: list(self.stages[p].entries) for p in range(self.pp)}
        self.layer_assignment = list(new_ranges)
        new_entries = {p: self._stage_entries(p) for p in range(self.pp)}
        affected = [p for p in range(self.pp)
                    if old_entries[p] != new_entries[p]]
        # batch-slice the moving/retained entry state out of affected stages
        entry_state: Dict[int, Dict[str, np.ndarray]] = {}
        for p in affected:
            st = self.stages[p]
            tbl = st.table
            for comp in COMPONENTS:
                fullc = st.full(comp)
                for pos, e in enumerate(st.entries):
                    s_, e_ = tbl.layer_interval(pos)
                    entry_state.setdefault(e, {})[comp] = fullc[s_:e_]
        for p in affected:
            survivors = list(self.stages[p].dp_ranks)
            entries = new_entries[p]
            sizes = [entry_state[e]["master"].size for e in entries]
            full_by_comp = {
                c: (np.concatenate([entry_state[e][c] for e in entries])
                    if entries else np.zeros(0, np.float32))
                for c in COMPONENTS}
            new_st = StageState.from_full(entries, sizes, self.zero_layout,
                                          survivors, full_by_comp)
            self.stages[p] = new_st
            self.snapshots[p] = SnapshotPool(len(survivors), self.adam,
                                             batched=True)
            if self.snapshot_enabled:
                self.snapshots[p].bootstrap(
                    self.step_count, [new_st.shard(r) for r in survivors])
        return total_stall

    def _entry_from_stage(self, e: int) -> Dict[str, np.ndarray]:
        if not self.fast_path:
            return legacy.entry_from_stage(self, e)
        for st in self.stages:
            if e in st.entries:
                pos = st.entries.index(e)
                s_, e_ = st.table.layer_interval(pos)
                return {c: st.full(c)[s_:e_] for c in COMPONENTS}
        raise KeyError(e)

    # convenience ------------------------------------------------------
    def run(self, steps: int) -> List[float]:
        return [self.train_step() for _ in range(steps)]


def _merge_recovery_records(recs: List[Dict[str, float]]) -> Dict[str, float]:
    """Combine per-rank recovery records of one burst into a single record:
    every itemized phase (and the total) accumulates; counters too."""
    if len(recs) == 1:
        return recs[0]
    out: Dict[str, float] = {}
    for rec in recs:
        for k, v in rec.items():
            out[k] = out.get(k, 0.0) + v
    return out


def _stage_of(ranges: Sequence[Tuple[int, int]], L: int) -> List[int]:
    out = [0] * L
    for p, (a, b) in enumerate(ranges):
        for l in range(a, b + 1):
            out[l] = p
    return out
