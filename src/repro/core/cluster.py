"""VirtualCluster — the executable embodiment of ElasWave.

An in-process cluster of virtual workers arranged as a DP x PP grid.  Every
paper mechanism operates on REAL state with REAL numerics:

* per-layer parameters owned by pipeline stages (migratable pytrees);
* ZeRO-1 optimizer shards per (stage, dp-rank) under contiguous or
  interleaved layouts (core/zero.py);
* per-step ring snapshots to host memory (core/fabric/snapshot.py);
* live remap on shrink (core/fabric/remap.py) — actual array movement,
  integrity-checked;
* dynamic communicator group edits (core/communicator.py);
* dataflow resizing with exact gradient weighting (planners/dataflow.py);
* content-addressed RNG (= RNG resharding) vs a deliberately rank-addressed
  "naive" mode for the §7.5 ablation;
* DVFS / fail-slow factors feed the 1F1B timing simulator.

Gradients are computed with jax.grad over the *full* model per micro-batch
slice (the logically-centralized equivalent of the pipeline's math), so the
elastic run's loss trajectory can be compared bit-for-bit-ish against a
fault-free run.  The distribution layer (who owns what, what moves on which
event, what it costs) is exactly the paper's; see DESIGN.md §3.
"""
from __future__ import annotations

import dataclasses
import time as _time
from functools import partial
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.flatten_util import ravel_pytree

from repro.data.pipeline import GlobalBatchSampler, make_batch
from repro.models import registry as R
from repro.models.config import ModelConfig
from repro.models.layers import RngCtx
from repro.optim.adam import AdamConfig, adam_update_flat
from . import zero
from .agent import Agent, Probe
from .communicator import DynamicCommunicator, build_hybrid_groups
from .cost_model import HardwareSpec, SegmentCosts
from .engine import RecoveryPlan, ScheduleEngine
from .events import ElasticEvent, EventKind
from .fabric.remap import LiveRemap, RemapPlan
from .fabric.snapshot import SnapshotPool
from .migration import MigrationSpec, migration_timing
from .pipeline import StageTiming, simulate_1f1b


STEM = -1      # pseudo layer ids for stage state-space entries
HEAD = -2


@dataclasses.dataclass
class StageState:
    """Optimizer state of one pipeline stage, ZeRO-1 sharded over its DP group."""
    entries: List[int]                       # [STEM?] + layer ids + [HEAD?]
    sizes: List[int]                         # element count per entry
    layout_kind: str
    dp_ranks: List[int]                      # surviving dp indices of this group
    # shards[dp_rank] = {"master": flat fp32 over owned intervals, "mu", "nu"}
    shards: Dict[int, Dict[str, np.ndarray]]

    def layout(self) -> zero.Layout:
        return zero.Layout(self.layout_kind, tuple(self.sizes), len(self.dp_ranks))

    @property
    def total(self) -> int:
        return sum(self.sizes)


class VirtualCluster:
    def __init__(self, cfg: ModelConfig, dp: int, pp: int, *,
                 global_batch: int, num_micro: int, seq_len: int,
                 seed: int = 0, zero_layout: str = "interleaved",
                 adam: Optional[AdamConfig] = None,
                 rng_mode: str = "reshard",        # "reshard" | "naive"
                 hw: Optional[HardwareSpec] = None,
                 mem_cap: Optional[float] = None,
                 snapshot_enabled: bool = True,
                 non_blocking_migration: bool = True):
        assert global_batch % num_micro == 0
        assert (global_batch // num_micro) % dp == 0, "initial even split"
        self.cfg = cfg
        self.dp0, self.pp = dp, pp
        self.global_batch, self.num_micro, self.seq = global_batch, num_micro, seq_len
        self.adam = adam or AdamConfig(master_weights=True)
        self.rng_mode = rng_mode
        self.hw = hw or HardwareSpec()
        self.zero_layout = zero_layout
        self.snapshot_enabled = snapshot_enabled
        self.non_blocking_migration = non_blocking_migration
        self.sampler = GlobalBatchSampler(global_batch, seed)
        self.base_key = jax.random.key(seed)

        # ---- model state (fp32 for deterministic CPU math) ----
        L = cfg.num_layers
        key = jax.random.key(seed + 1)
        ks = jax.random.split(key, L + 2)
        self.stem = R.init_stem(ks[0], cfg)
        self.layer_params: List[Any] = [R.init_layer(ks[1 + i], cfg, i)
                                        for i in range(L)]
        self.head = R.init_head(ks[L + 1], cfg)
        self._unravel = {}
        # balanced initial layer assignment
        per = L // pp
        rem = L % pp
        ranges, a = [], 0
        for p in range(pp):
            b = a + per + (1 if p < rem else 0) - 1
            ranges.append((a, b))
            a = b + 1
        self.layer_assignment: List[Tuple[int, int]] = ranges

        # ---- workers / health ----
        self.alive = np.ones((dp, pp), dtype=bool)
        self.freq = np.ones((dp, pp))
        self.slow = np.ones((dp, pp))

        # ---- ZeRO stage states + snapshots ----
        self.stages: List[StageState] = []
        self.snapshots: List[SnapshotPool] = []
        for p in range(pp):
            st = self._build_stage_state(p, list(range(dp)))
            self.stages.append(st)
            pool = SnapshotPool(dp, self.adam)
            if snapshot_enabled:
                pool.bootstrap(0, [st.shards[r] for r in st.dp_ranks])
            self.snapshots.append(pool)

        # ---- control plane ----
        self.comm = DynamicCommunicator(build_hybrid_groups(dp, pp))
        self.agent = Agent(dp * pp)
        self.engine = ScheduleEngine(cfg, seq_len, self.hw, mem_cap)
        self.remapper = LiveRemap()

        # ---- bookkeeping ----
        self.step_count = 0
        self.opt_step = 0
        self.per_rank_mbs: List[int] = [global_batch // num_micro // dp] * dp
        self.grad_weights: List[float] = [1.0 / dp] * dp
        self.losses: List[float] = []
        self.recoveries: List[Dict[str, float]] = []
        self.seg = SegmentCosts.build(cfg, seq_len, self.hw)
        self._grad_fn_cache: Dict[int, Any] = {}

    # ------------------------------------------------------------------
    # state-space helpers
    # ------------------------------------------------------------------
    def _entry_vec(self, entry: int) -> np.ndarray:
        if entry == STEM:
            v, unr = ravel_pytree(self.stem)
        elif entry == HEAD:
            v, unr = ravel_pytree(self.head)
        else:
            v, unr = ravel_pytree(self.layer_params[entry])
        self._unravel[entry] = unr
        return np.asarray(v, dtype=np.float32)

    def _stage_entries(self, p: int) -> List[int]:
        a, b = self.layer_assignment[p]
        entries = list(range(a, b + 1))
        if p == 0:
            entries = [STEM] + entries
        if p == self.pp - 1:
            entries = entries + [HEAD]
        return entries

    def _build_stage_state(self, p: int, dp_ranks: List[int]) -> StageState:
        entries = self._stage_entries(p)
        vecs = [self._entry_vec(e) for e in entries]
        sizes = [v.size for v in vecs]
        full = np.concatenate(vecs) if vecs else np.zeros(0, np.float32)
        st = StageState(entries, sizes, self.zero_layout, list(dp_ranks), {})
        lay = st.layout()
        for j, r in enumerate(st.dp_ranks):
            ivs = lay.owner_intervals(j)
            master = np.concatenate([full[s:e] for s, e in ivs]) if ivs else \
                np.zeros(0, np.float32)
            st.shards[r] = {"master": master,
                            "mu": np.zeros_like(master),
                            "nu": np.zeros_like(master)}
        return st

    def _stage_full_vec(self, st: StageState, comp: str = "master") -> np.ndarray:
        """All-gather equivalent: reassemble the stage's full state vector."""
        full = np.zeros(st.total, dtype=np.float32)
        lay = st.layout()
        for j, r in enumerate(st.dp_ranks):
            off = 0
            for s, e in lay.owner_intervals(j):
                n = e - s
                full[s:e] = st.shards[r][comp][off:off + n]
                off += n
        return full

    def _write_params_from_masters(self):
        for p, st in enumerate(self.stages):
            full = self._stage_full_vec(st)
            off = 0
            for e, sz in zip(st.entries, st.sizes):
                vec = jnp.asarray(full[off:off + sz])
                tree = self._unravel[e](vec)
                if e == STEM:
                    self.stem = tree
                elif e == HEAD:
                    self.head = tree
                else:
                    self.layer_params[e] = tree
                off += sz

    # ------------------------------------------------------------------
    # training math
    # ------------------------------------------------------------------
    def _loss_fn(self, stem, layers, head, tokens, labels, step_key, sample_ids):
        cfg = self.cfg
        x = R.apply_stem(stem, cfg, tokens)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
        ctx = RngCtx(step_key=step_key, sample_ids=sample_ids,
                     deterministic=cfg.dropout_rate <= 0.0)
        aux_total = jnp.zeros((), jnp.float32)
        for lid in range(cfg.num_layers):
            x, aux = R.apply_layer(layers[lid], cfg, lid, x, positions, ctx)
            aux_total = aux_total + aux
        logits = R.apply_head(head, cfg, x)
        from repro.models.transformer import softmax_xent
        return softmax_xent(logits[:, :-1], labels[:, 1:]) + aux_total

    def _grad_fn(self, batch_size: int):
        if batch_size not in self._grad_fn_cache:
            self._grad_fn_cache[batch_size] = jax.jit(
                jax.value_and_grad(self._loss_fn, argnums=(0, 1, 2)))
        return self._grad_fn_cache[batch_size]

    def _micro_grads(self, step: int) -> Tuple[float, Any]:
        """Weighted accumulation over micro-batches and DP slices — the
        numerics of dataflow-resized hybrid-parallel training."""
        ids_by_rank = self.sampler.partition(step, self.per_rank_mbs,
                                             self.num_micro)
        step_key = jax.random.fold_in(self.base_key, step)
        total_loss = 0.0
        acc = None
        for m in range(self.num_micro):
            for r, rank_ids in enumerate(ids_by_rank):
                ids = rank_ids[m]
                if len(ids) == 0:
                    continue
                batch = make_batch(ids, self.seq, self.cfg.vocab_size)
                if self.rng_mode == "reshard":
                    sids = batch["sample_ids"]
                else:   # naive: rank-addressed streams (the paper's "w/o")
                    sids = jnp.arange(len(ids)) + r * 100003
                loss, grads = self._grad_fn(len(ids))(
                    self.stem, self.layer_params, self.head,
                    batch["tokens"], batch["labels"], step_key, sids)
                w = self.grad_weights[r] / self.num_micro
                total_loss += float(loss) * w
                gs = jax.tree.map(lambda g: g * w, grads)
                acc = gs if acc is None else jax.tree.map(jnp.add, acc, gs)
        return total_loss, acc

    def train_step(self) -> float:
        step = self.step_count
        loss, (g_stem, g_layers, g_head) = self._micro_grads(step)
        self.opt_step += 1
        grad_shard_by_stage: List[List[np.ndarray]] = []
        for p, st in enumerate(self.stages):
            # assemble this stage's full gradient vector
            parts = []
            for e in st.entries:
                if e == STEM:
                    parts.append(np.asarray(ravel_pytree(g_stem)[0], np.float32))
                elif e == HEAD:
                    parts.append(np.asarray(ravel_pytree(g_head)[0], np.float32))
                else:
                    parts.append(np.asarray(ravel_pytree(g_layers[e])[0], np.float32))
            gfull = np.concatenate(parts) if parts else np.zeros(0, np.float32)
            lay = st.layout()
            shards = []
            for j, r in enumerate(st.dp_ranks):
                gs = np.concatenate([gfull[s:e] for s, e in lay.owner_intervals(j)]) \
                    if st.total else np.zeros(0, np.float32)
                newm, newst = adam_update_flat(
                    jnp.asarray(gs),
                    {k: jnp.asarray(v) for k, v in st.shards[r].items()},
                    self.opt_step, self.adam)
                st.shards[r] = {k: np.asarray(v) for k, v in newst.items()}
                shards.append(gs)
            grad_shard_by_stage.append(shards)
        self._write_params_from_masters()
        if self.snapshot_enabled:
            for p, st in enumerate(self.stages):
                self.snapshots[p].snapshot_step(step, grad_shard_by_stage[p],
                                                self.opt_step)
        self.step_count += 1
        self.losses.append(loss)
        return loss

    # ------------------------------------------------------------------
    # timing model (feeds throughput benchmarks)
    # ------------------------------------------------------------------
    def simulate_step_time(self) -> float:
        stages = []
        per_micro = self.global_batch // self.num_micro
        for p, (a, b) in enumerate(self.layer_assignment):
            live = [d for d in range(self.dp0) if self.alive[d, p]]
            width = max(len(live), 1)
            mbs = -(-per_micro // width)
            worst = max((self.slow[d, p] / self.freq[d, p] for d in live),
                        default=1.0)
            eff = self.hw.peak_flops * self.hw.mfu / worst
            fl = self.seg.seg_fwd_flops(a, b, mbs)
            stages.append(StageTiming(fl / eff, 2 * fl / eff, self.num_micro))
        return simulate_1f1b(stages).step_time

    # ------------------------------------------------------------------
    # elasticity
    # ------------------------------------------------------------------
    def inject_fail_stop(self, d: int, p: int):
        self.alive[d, p] = False

    def inject_fail_slow(self, d: int, p: int, factor: float):
        self.slow[d, p] = factor

    def detect_and_recover(self) -> Optional[Dict[str, float]]:
        """Agent probes -> events -> ScheduleEngine plan -> executor."""
        probes = []
        base_t = self.simulate_step_time()
        for d in range(self.dp0):
            for p in range(self.pp):
                rank = d * self.pp + p
                probes.append(Probe(self.step_count, rank,
                                    heartbeat=bool(self.alive[d, p]),
                                    step_seconds=base_t * self.slow[d, p]))
        events: List[ElasticEvent] = []
        for _ in range(self.agent.miss_limit):
            events = self.agent.observe(probes)
            if events:
                break
        if not events:
            return None
        ev = events[0]
        return self.apply_event(ev)

    def apply_event(self, ev: ElasticEvent) -> Dict[str, float]:
        """Recovery Executor entry point: one elastic event -> itemized MTTR.

        Multi-rank events (failure bursts) are applied as a deterministic
        rank-ordered sequence of single-rank recoveries; detection is paid
        once (the heartbeats are missed concurrently) and the control-plane
        phases accumulate."""
        t_detect = 0.5  # heartbeat interval bound (modeled)
        cells = [(r // self.pp, r % self.pp) for r in sorted(ev.ranks)]
        if ev.kind in (EventKind.FAIL_STOP, EventKind.SCALE_IN):
            recs = [self.recover_fail_stop(d, p,
                                           t_detect=t_detect if i == 0 else 0.0)
                    for i, (d, p) in enumerate(cells)]
            return _merge_recovery_records(recs)
        if ev.kind == EventKind.FAIL_SLOW:
            recs = [self.recover_fail_slow(d, p, ev.slow_factor,
                                           t_detect=t_detect if i == 0 else 0.0)
                    for i, (d, p) in enumerate(cells)]
            return _merge_recovery_records(recs)
        if ev.kind == EventKind.SCALE_OUT:
            recs = [self.recover_scale_out(d, p) for d, p in cells]
            return _merge_recovery_records(recs)
        if ev.kind == EventKind.DVFS_SET:
            for d, p in cells:
                self.freq[d, p] = ev.freq
            return {"detect": 0.0, "plan": 0.0, "communicator": 0.0,
                    "remap": 0.0, "migration": 0.0, "total": 0.0}
        raise ValueError(f"unsupported elastic event kind here: {ev.kind}")

    def plan_event(self, ev: ElasticEvent) -> RecoveryPlan:
        """Mark the event's (single) rank dead and ask the ScheduleEngine for
        a joint Dataflow/Graph/DVFS/RNG RecoveryPlan (paper §4)."""
        rank = ev.ranks[0]
        d, p = rank // self.pp, rank % self.pp
        st = self.stages[p]
        if d not in st.dp_ranks:
            raise ValueError(
                f"rank {rank} (dp={d}, stage={p}) was already removed from "
                f"the stage's DP group; scenario traces must not re-fail a "
                f"recovered rank")
        self.alive[d, p] = False
        old_sample_rank = self._current_sample_assignment()
        widths = [int(self.alive[:, q].sum()) for q in range(self.pp)]
        return self.engine.plan(
            ev, dp=len(st.dp_ranks), pp=self.pp,
            global_batch=self.global_batch, num_micro=self.num_micro,
            layer_assignment=self.layer_assignment,
            failed_dp_ranks=[d], old_sample_rank=old_sample_rank,
            stage_widths=widths)

    def recover_fail_stop(self, d: int, p: int, t_detect: float = 0.5,
                          ) -> Dict[str, float]:
        """Full ElasWave recovery: plan + communicator edit + live remap +
        layer migration + dataflow/DVFS/RNG application."""
        ev = ElasticEvent(EventKind.FAIL_STOP, self.step_count,
                          (d * self.pp + p,))
        return self.apply_plan(self.plan_event(ev), t_detect=t_detect)

    def apply_plan(self, plan: RecoveryPlan, t_detect: float = 0.5,
                   ) -> Dict[str, float]:
        """Execute a shrink RecoveryPlan (the paper's event -> plan -> apply
        path): communicator edit, live remap, layer migration, dataflow
        resize, DVFS top-up.  Returns the itemized MTTR record."""
        ev = plan.event
        rank = ev.ranks[0]
        d, p = rank // self.pp, rank % self.pp

        # --- communicator: in-place edit ---
        comm_stats = self.comm.edit(remove=[d * self.pp + p])

        # --- live remap of stage p's optimizer state ---
        t_remap, remap_plan = self._live_remap_stage(p, failed=[d])

        # --- layer migrations (graph plan) ---
        t_migr = 0.0
        if plan.graph.feasible and plan.migrations:
            t_migr = self._apply_migrations(plan.migrations,
                                            list(plan.graph.stage_ranges))

        # --- dataflow: resize micro batches over surviving width ---
        self._apply_dataflow()

        # --- DVFS ---
        for dv in plan.dvfs:
            if dv.rank >= 0:
                for dd in range(self.dp0):
                    if self.alive[dd, dv.rank]:
                        self.freq[dd, dv.rank] = max(self.freq[dd, dv.rank], dv.freq)

        rec = {"detect": t_detect, "plan": plan.plan_seconds,
               "communicator": comm_stats.seconds, "remap": t_remap,
               "migration": t_migr,
               "total": t_detect + plan.plan_seconds + comm_stats.seconds
               + t_remap + t_migr}
        rec["rng_moves"] = len(plan.rng.layer_stream_moves) + \
            len(plan.rng.sample_stream_moves)
        self.recoveries.append(rec)
        return rec

    def recover_scale_out(self, d: int, p: int) -> Dict[str, float]:
        """Worker (d, p) (re)joins: communicator edit (only the new member's
        links), reverse live-remap widening the stage's ZeRO group, dataflow
        resize back to the wider DP width (paper Fig. 8 scale-up)."""
        assert not self.alive[d, p], "worker already alive"
        self.alive[d, p] = True
        comm_stats = self.comm.edit(add=[(g, d * self.pp + p)
                                         for g in self.comm.groups
                                         if g == f"dp_stage{p}_tp0"])
        t_remap = self._widen_stage(p, joining=[d])
        self._apply_dataflow()
        rec = {"detect": 0.0, "plan": 0.0, "communicator": comm_stats.seconds,
               "remap": t_remap, "migration": 0.0,
               "total": comm_stats.seconds + t_remap}
        self.recoveries.append(rec)
        return rec

    def _widen_stage(self, p: int, joining: List[int]) -> float:
        """Reverse remap: redistribute the stage state over a WIDER group.
        Sources: current owners' device shards; targets: new layout."""
        st = self.stages[p]
        old_ranks = list(st.dp_ranks)
        old_lay = st.layout()
        new_ranks = old_ranks + [j for j in joining if j not in old_ranks]
        pre = {c: self._stage_full_vec(st, c) for c in ("master", "mu", "nu")}
        device_parts = {r: old_lay.owner_intervals(old_ranks.index(r))
                        for r in old_ranks}
        new_lay = zero.Layout(st.layout_kind, tuple(st.sizes), len(new_ranks))
        target_parts = {r: new_lay.owner_intervals(j)
                        for j, r in enumerate(new_ranks)}
        plan = self.remapper.compute_plan(st.total, device_parts, {},
                                          target_parts)
        new_shards: Dict[int, Dict[str, np.ndarray]] = {r: {} for r in new_ranks}
        for comp in ("master", "mu", "nu"):
            device_data = {}
            for r in old_ranks:
                ivs = old_lay.owner_intervals(old_ranks.index(r))
                segs, off = {}, 0
                for s, e in ivs:
                    segs[(s, e)] = st.shards[r][comp][off:off + (e - s)]
                    off += e - s
                device_data[r] = segs
            assembled = self.remapper.execute(plan, st.total, device_data, {})
            for r in new_ranks:
                new_shards[r][comp] = assembled.get(r, np.zeros(0, np.float32))
        st.dp_ranks = new_ranks
        st.shards = new_shards
        for comp in ("master", "mu", "nu"):
            post = self._stage_full_vec(st, comp)
            assert np.array_equal(post, pre[comp]), f"widen corrupted {comp}"
        self.snapshots[p] = SnapshotPool(len(new_ranks), self.adam)
        if self.snapshot_enabled:
            self.snapshots[p].bootstrap(self.step_count,
                                        [st.shards[r] for r in new_ranks])
        return plan.est_seconds

    def recover_fail_slow(self, d: int, p: int, factor: float,
                          t_detect: float = 0.5) -> Dict[str, float]:
        """Straggler mitigation: rebalance layers away from the slow stage +
        DVFS top-up (no state loss)."""
        self.slow[d, p] = max(self.slow[d, p], factor)
        per_micro = self.global_batch // self.num_micro

        def t(pp_, a, b):
            live = [dd for dd in range(self.dp0) if self.alive[dd, pp_]]
            width = max(len(live), 1)
            mbs = -(-per_micro // width)
            worst = max((self.slow[dd, pp_] for dd in live), default=1.0)
            fl = self.seg.seg_fwd_flops(a, b, mbs)
            return 3 * fl / (self.hw.peak_flops * self.hw.mfu / worst)

        def mem(pp_, a, b):
            return self.seg.seg_mem(a, b, per_micro, inflight=self.pp)

        from .planners.graph import minimax_layer_partition
        plan = minimax_layer_partition(self.cfg.num_layers, self.pp, t, mem,
                                       [self.engine.mem_cap] * self.pp)
        t_migr = 0.0
        if plan.feasible:
            old_stage = _stage_of(self.layer_assignment, self.cfg.num_layers)
            new_stage = _stage_of(plan.stage_ranges, self.cfg.num_layers)
            moves = [(lid, old_stage[lid], new_stage[lid])
                     for lid in range(self.cfg.num_layers)
                     if old_stage[lid] != new_stage[lid]]
            if moves:
                t_migr = self._apply_migrations(moves, list(plan.stage_ranges))
        rec = {"detect": t_detect, "plan": 0.0, "communicator": 0.0,
               "remap": 0.0, "migration": t_migr, "total": t_detect + t_migr}
        self.recoveries.append(rec)
        return rec

    # ------------------------------------------------------------------
    # executor pieces
    # ------------------------------------------------------------------
    def _current_sample_assignment(self) -> Dict[int, int]:
        out, cursor = {}, 0
        for r, sz in enumerate(self.per_rank_mbs):
            for _ in range(sz):
                out[cursor] = r
                cursor += 1
        return out

    def _apply_dataflow(self):
        # width of the narrowest stage defines surviving DP for data entry
        widths = [int(self.alive[:, p].sum()) for p in range(self.pp)]
        new_dp = max(min(widths), 1)
        from .planners.dataflow import plan_dataflow
        df = plan_dataflow(self.global_batch, self.num_micro, new_dp)
        self.per_rank_mbs = list(df.micro_batch_sizes)
        self.grad_weights = list(df.grad_weights)

    def _live_remap_stage(self, p: int, failed: List[int],
                          ) -> Tuple[float, RemapPlan]:
        st = self.stages[p]
        pool = self.snapshots[p]
        old_lay = st.layout()
        old_ranks = list(st.dp_ranks)
        # record pre-failure full vectors for verification
        pre = {c: self._stage_full_vec_with_snapshots(p, c, failed)
               for c in ("master", "mu", "nu")}

        surviving = [r for r in old_ranks if r not in failed]
        device_parts = {r: old_lay.owner_intervals(old_ranks.index(r))
                        for r in surviving}
        host_parts = {}
        for f in failed:
            holder = pool.holder_of(old_ranks.index(f))
            holder_rank = old_ranks[holder]
            if holder_rank in surviving and pool.host[holder] is not None:
                host_parts[f] = old_lay.owner_intervals(old_ranks.index(f))
        new_lay = zero.Layout(st.layout_kind, tuple(st.sizes), len(surviving))
        target_parts = {r: new_lay.owner_intervals(j)
                        for j, r in enumerate(surviving)}

        plan = self.remapper.compute_plan(st.total, device_parts, host_parts,
                                          target_parts)
        # execute with real arrays, per component
        new_shards: Dict[int, Dict[str, np.ndarray]] = {r: {} for r in surviving}
        for comp in ("master", "mu", "nu"):
            device_data = {}
            for r in surviving:
                ivs = old_lay.owner_intervals(old_ranks.index(r))
                segs, off = {}, 0
                for s, e in ivs:
                    segs[(s, e)] = st.shards[r][comp][off:off + (e - s)]
                    off += e - s
                device_data[r] = segs
            host_data = {}
            for f in failed:
                holder = pool.holder_of(old_ranks.index(f))
                snap = pool.host[holder]
                if snap is None:
                    continue
                ivs = old_lay.owner_intervals(old_ranks.index(f))
                segs, off = {}, 0
                for s, e in ivs:
                    segs[(s, e)] = snap[comp][off:off + (e - s)]
                    off += e - s
                host_data[f] = segs
            assembled = self.remapper.execute(plan, st.total, device_data,
                                              host_data)
            for r in surviving:
                new_shards[r][comp] = assembled.get(r, np.zeros(0, np.float32))
        st.dp_ranks = surviving
        st.shards = new_shards
        # verification (paper: online verification before resume)
        for comp in ("master", "mu", "nu"):
            post = self._stage_full_vec(st, comp)
            assert np.array_equal(post, pre[comp]), f"remap corrupted {comp}"
        # rebuild ring snapshot pool for the shrunken group
        self.snapshots[p] = SnapshotPool(len(surviving), self.adam)
        if self.snapshot_enabled:
            self.snapshots[p].bootstrap(self.step_count,
                                        [st.shards[r] for r in surviving])
        return plan.est_seconds, plan

    def _stage_full_vec_with_snapshots(self, p: int, comp: str,
                                       failed: List[int]) -> np.ndarray:
        """Pre-failure ground truth: survivors' device state + failed ranks'
        snapshot state."""
        st = self.stages[p]
        pool = self.snapshots[p]
        full = np.zeros(st.total, dtype=np.float32)
        lay = st.layout()
        for j, r in enumerate(st.dp_ranks):
            src = st.shards[r][comp] if r not in failed else None
            if src is None:
                snap = pool.host[pool.holder_of(j)]
                src = snap[comp] if snap is not None else None
            if src is None:
                continue
            off = 0
            for s, e in lay.owner_intervals(j):
                full[s:e] = src[off:off + (e - s)]
                off += e - s
        return full

    def _apply_migrations(self, moves: List[Tuple[int, int, int]],
                          new_ranges: List[Tuple[int, int]]) -> float:
        """Move layers between stages: optimizer-state slices (per layout) +
        parameter ownership.  Returns modeled stall seconds (MTTR)."""
        total_stall = 0.0
        # compute per-move timing with the migration model
        step_window = self.simulate_step_time()
        for (lid, src, dst) in moves:
            st_src = self.stages[src]
            pos = st_src.entries.index(lid)
            pbytes = int(self.seg.param_bytes[lid])
            obytes = int(self.seg.opt_bytes[lid])
            spec = MigrationSpec((lid,), src, dst, pbytes, obytes,
                                 dp=len(st_src.dp_ranks),
                                 zero_layout=self.zero_layout,
                                 blocking=not self.non_blocking_migration)
            timing = migration_timing(spec, self.hw.link_bw, step_window)
            total_stall += timing.stall_seconds
        # state movement: rebuild both stage states from the new assignment
        # (real arrays; correctness asserted by reconstructing masters)
        pre_masters = {e: self._entry_from_stage(e) for st in self.stages
                       for e in st.entries}
        self.layer_assignment = list(new_ranges)
        for p in range(self.pp):
            st_old = self.stages[p]
            survivors = list(st_old.dp_ranks)
            entries = self._stage_entries(p)
            vec_parts = [pre_masters[e] for e in entries]
            sizes = [v["master"].size for v in vec_parts]
            new_st = StageState(entries, sizes, self.zero_layout, survivors, {})
            lay = new_st.layout()
            for comp in ("master", "mu", "nu"):
                full = np.concatenate([v[comp] for v in vec_parts]) if vec_parts \
                    else np.zeros(0, np.float32)
                for j, r in enumerate(survivors):
                    shard = np.concatenate([full[s:e]
                                            for s, e in lay.owner_intervals(j)]) \
                        if new_st.total else np.zeros(0, np.float32)
                    new_st.shards.setdefault(r, {})[comp] = shard
            self.stages[p] = new_st
            self.snapshots[p] = SnapshotPool(len(survivors), self.adam)
            if self.snapshot_enabled:
                self.snapshots[p].bootstrap(self.step_count,
                                            [new_st.shards[r] for r in survivors])
        return total_stall

    def _entry_from_stage(self, e: int) -> Dict[str, np.ndarray]:
        for st in self.stages:
            if e in st.entries:
                pos = st.entries.index(e)
                iv = st.layout().layer_interval(pos) if st.layout_kind == "interleaved" \
                    else (sum(st.sizes[:pos]), sum(st.sizes[:pos + 1]))
                out = {}
                for comp in ("master", "mu", "nu"):
                    full = self._stage_full_vec(st, comp)
                    out[comp] = full[iv[0]:iv[1]]
                return out
        raise KeyError(e)

    # convenience ------------------------------------------------------
    def run(self, steps: int) -> List[float]:
        return [self.train_step() for _ in range(steps)]


def _merge_recovery_records(recs: List[Dict[str, float]]) -> Dict[str, float]:
    """Combine per-rank recovery records of one burst into a single record:
    every itemized phase (and the total) accumulates; counters too."""
    if len(recs) == 1:
        return recs[0]
    out: Dict[str, float] = {}
    for rec in recs:
        for k, v in rec.items():
            out[k] = out.get(k, 0.0) + v
    return out


def _stage_of(ranges: Sequence[Tuple[int, int]], L: int) -> List[int]:
    out = [0] * L
    for p, (a, b) in enumerate(ranges):
        for l in range(a, b + 1):
            out[l] = p
    return out
