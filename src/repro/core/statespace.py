"""Flat-state backbone: vectorized ZeRO interval tables + per-stage buffers.

The VirtualCluster's hot paths (train step, live remap, widening, layer
migration, ring snapshots) all operate on the same state space: per pipeline
stage, the concatenation of its entries' flattened fp32 optimizer vectors,
partitioned over the stage's DP group by a ``core.zero.Layout``.  The seed
implementation re-derived that partition in Python (``owner_intervals`` lists,
per-interval ``np.concatenate`` loops) at every call site on every step.

This module makes the state space a first-class, precomputed object:

* :class:`IntervalTable` — the vectorized, **memoized** equivalent of
  ``zero.Layout``: per-rank ``(starts, ends)`` numpy offset arrays, per-rank
  shard sizes/offsets, and a ``shard_index`` permutation that maps the
  *shard-order* flat buffer (rank 0's owned bytes, then rank 1's, ...) to
  stage-space offsets.  ``gather``/``scatter`` are each a single fancy-index
  instead of a Python interval loop.  Tables are keyed by
  ``(kind, layer_sizes, dp)`` via :func:`get_table`, so no per-step or
  per-recovery call site ever rebuilds interval lists.
* :class:`StageState` — one contiguous fp32 buffer per optimizer component
  (``master``/``mu``/``nu``) per stage, stored in shard order so every rank's
  ZeRO shard is a zero-copy **view**; an entry-offset index locates each
  layer's slice.
* :class:`EntryFlattener` — cached ``ravel_pytree`` unravelers per entry and
  for the whole model, so parameter write-back is one indexed scatter + one
  unravel instead of a per-entry re-unravel loop.

``zero.Layout`` remains the reference implementation; equivalence is enforced
by ``tests/test_statespace.py`` across dp × layer-size grids (including the
last-rank remainder case).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

Interval = Tuple[int, int]

COMPONENTS = ("master", "mu", "nu")

STEM = -1      # pseudo entry ids for stage state spaces
HEAD = -2


class IntervalTable:
    """Precomputed ownership tables for one ``(kind, layer_sizes, dp)``.

    Semantics match ``zero.Layout`` exactly, including empty intervals and the
    last-rank remainder.  Use :func:`get_table` to obtain memoized instances.
    """

    __slots__ = ("kind", "layer_sizes", "dp", "total", "entry_offsets",
                 "starts", "ends", "shard_sizes", "shard_offsets",
                 "_shard_index", "_runs", "_rank_runs", "_intervals")

    def __init__(self, kind: str, layer_sizes: Tuple[int, ...], dp: int):
        assert kind in ("contiguous", "interleaved"), kind
        self.kind = kind
        self.layer_sizes = tuple(int(s) for s in layer_sizes)
        self.dp = int(dp)
        sizes = np.asarray(self.layer_sizes, dtype=np.int64)
        self.total = int(sizes.sum())
        self.entry_offsets = np.concatenate(
            [np.zeros(1, np.int64), np.cumsum(sizes)])
        if kind == "contiguous":
            per = self.total // self.dp
            starts = (np.arange(self.dp, dtype=np.int64) * per)[:, None]
            ends = starts + per
            ends[self.dp - 1, 0] = self.total
        else:
            per = sizes // self.dp
            starts = (self.entry_offsets[:-1][None, :]
                      + np.arange(self.dp, dtype=np.int64)[:, None] * per[None, :])
            ends = starts + per[None, :]
            if len(self.layer_sizes):
                ends[self.dp - 1, :] = self.entry_offsets[1:]
        self.starts, self.ends = starts, ends
        lens = ends - starts
        self.shard_sizes = lens.sum(axis=1)
        self.shard_offsets = np.concatenate(
            [np.zeros(1, np.int64), np.cumsum(self.shard_sizes)])
        # contiguous-run copy lists (built once): gather/scatter walk a few
        # precomputed (stage_start, stage_end, shard_off) slices instead of
        # per-element fancy indexing — faster for realistic interval counts
        runs: List[Tuple[int, int, int]] = []
        rank_runs: List[List[Tuple[int, int, int]]] = []
        off = 0
        for j in range(self.dp):
            mine: List[Tuple[int, int, int]] = []
            local = 0
            for s, e in zip(starts[j], ends[j]):
                s, e = int(s), int(e)
                if e > s:
                    runs.append((s, e, off + local))
                    mine.append((s, e, local))
                    local += e - s
            rank_runs.append(mine)
            off += local
        self._runs = runs
        self._rank_runs = rank_runs
        self._shard_index: Optional[np.ndarray] = None
        self._intervals: List[Optional[List[Interval]]] = [None] * self.dp

    @property
    def shard_index(self) -> np.ndarray:
        """Shard-order -> stage-space permutation (lazy: O(total) int64, only
        materialized for callers that want elementwise indexing)."""
        if self._shard_index is None:
            pieces = [np.arange(s, e, dtype=np.int64)
                      for s, e, _o in self._runs]
            idx = np.concatenate(pieces) if pieces else np.zeros(0, np.int64)
            assert idx.size == self.total
            self._shard_index = idx
        return self._shard_index

    # -- Layout-compatible API -------------------------------------------
    def owner_intervals(self, rank: int) -> List[Interval]:
        """Intervals of the stage state space owned by ``rank`` (cached)."""
        cached = self._intervals[rank]
        if cached is None:
            cached = [(int(s), int(e)) for s, e in
                      zip(self.starts[rank], self.ends[rank])]
            self._intervals[rank] = cached
        return list(cached)

    def layer_interval(self, layer_pos: int) -> Interval:
        return (int(self.entry_offsets[layer_pos]),
                int(self.entry_offsets[layer_pos + 1]))

    # -- flat-buffer algebra ---------------------------------------------
    def gather(self, full: np.ndarray) -> np.ndarray:
        """Stage-space vector -> shard-order flat buffer (precomputed
        contiguous-run slice copies)."""
        out = np.empty(self.total, dtype=full.dtype)
        for s, e, o in self._runs:
            out[o:o + (e - s)] = full[s:e]
        return out

    def scatter(self, flat: np.ndarray,
                out: Optional[np.ndarray] = None) -> np.ndarray:
        """Shard-order flat buffer -> stage-space vector (precomputed
        contiguous-run slice copies)."""
        if out is None:
            out = np.empty(self.total, dtype=flat.dtype)
        for s, e, o in self._runs:
            out[s:e] = flat[o:o + (e - s)]
        return out

    def scatter_shard(self, j: int, shard: np.ndarray,
                      out: np.ndarray) -> np.ndarray:
        """Write rank ``j``'s 1-D shard into the stage-space vector ``out``."""
        for s, e, o in self._rank_runs[j]:
            out[s:e] = shard[o:o + (e - s)]
        return out

    def shard_slice(self, j: int) -> slice:
        return slice(int(self.shard_offsets[j]), int(self.shard_offsets[j + 1]))

    def shard_view(self, flat: np.ndarray, j: int) -> np.ndarray:
        """Rank ``j``'s shard as a zero-copy view of the flat buffer."""
        return flat[self.shard_slice(j)]

    def split(self, flat: np.ndarray) -> List[np.ndarray]:
        """All ranks' shards as views, in rank order."""
        return [self.shard_view(flat, j) for j in range(self.dp)]

    def segments(self, j: int, shard: np.ndarray) -> Dict[Interval, np.ndarray]:
        """Split rank ``j``'s 1-D shard into ``{interval: view}`` — the input
        format of ``fabric.remap.LiveRemap.execute``."""
        segs: Dict[Interval, np.ndarray] = {}
        off = 0
        for s, e in self.owner_intervals(j):
            segs[(s, e)] = shard[off:off + (e - s)]
            off += e - s
        return segs


_TABLE_CACHE: Dict[Tuple[str, Tuple[int, ...], int], IntervalTable] = {}


def get_table(kind: str, layer_sizes: Sequence[int], dp: int) -> IntervalTable:
    """Memoized IntervalTable lookup — the hot-path replacement for
    constructing ``zero.Layout`` and calling ``owner_intervals`` per rank."""
    key = (kind, tuple(int(s) for s in layer_sizes), int(dp))
    tbl = _TABLE_CACHE.get(key)
    if tbl is None:
        tbl = IntervalTable(*key)
        _TABLE_CACHE[key] = tbl
    return tbl


@dataclasses.dataclass
class StageState:
    """Optimizer state of one pipeline stage, ZeRO-1 sharded over its DP group.

    ``flat[comp]`` is ONE contiguous fp32 buffer in **shard order** (rank 0's
    owned bytes, then rank 1's, ...); each rank's shard is a zero-copy view.
    Stage-space (entry-concatenation-order) vectors are produced on demand via
    the memoized :class:`IntervalTable` permutation.
    """
    entries: List[int]                      # [STEM?] + layer ids + [HEAD?]
    sizes: List[int]                        # element count per entry
    layout_kind: str
    dp_ranks: List[int]                     # surviving dp indices of this group
    flat: Dict[str, np.ndarray]             # comp -> shard-order buffer

    # -- construction -----------------------------------------------------
    @classmethod
    def from_full(cls, entries: List[int], sizes: List[int], kind: str,
                  dp_ranks: List[int],
                  full_by_comp: Dict[str, np.ndarray]) -> "StageState":
        tbl = get_table(kind, sizes, len(dp_ranks))
        flat = {c: np.ascontiguousarray(tbl.gather(
                    np.asarray(full_by_comp[c], dtype=np.float32)))
                for c in COMPONENTS}
        return cls(list(entries), list(sizes), kind, list(dp_ranks), flat)

    # -- derived views ----------------------------------------------------
    @property
    def table(self) -> IntervalTable:
        return get_table(self.layout_kind, self.sizes, len(self.dp_ranks))

    @property
    def total(self) -> int:
        return sum(self.sizes)

    @property
    def shards(self) -> Dict[int, Dict[str, np.ndarray]]:
        """``{dp_rank: {comp: shard-view}}`` — zero-copy; mutate via
        ``view[:] = ...`` or :meth:`write_shard`, never by dict assignment."""
        tbl = self.table
        return {r: {c: tbl.shard_view(self.flat[c], j) for c in COMPONENTS}
                for j, r in enumerate(self.dp_ranks)}

    def shard(self, r: int) -> Dict[str, np.ndarray]:
        j = self.dp_ranks.index(r)
        tbl = self.table
        return {c: tbl.shard_view(self.flat[c], j) for c in COMPONENTS}

    def write_shard(self, r: int, state: Dict[str, Any]) -> None:
        j = self.dp_ranks.index(r)
        tbl = self.table
        for c in COMPONENTS:
            tbl.shard_view(self.flat[c], j)[...] = np.asarray(
                state[c], dtype=np.float32)

    def full(self, comp: str = "master") -> np.ndarray:
        """All-gather equivalent: the stage's full state-space vector."""
        return self.table.scatter(self.flat[comp])

    def replace_shards(self, new_ranks: List[int],
                       shards_by_rank: Dict[int, Dict[str, np.ndarray]]) -> None:
        """Adopt a new DP group whose per-rank shard arrays are given in
        shard order (e.g. the output of ``LiveRemap.execute``)."""
        empty = np.zeros(0, np.float32)
        self.flat = {
            c: np.ascontiguousarray(np.concatenate(
                [np.asarray(shards_by_rank[r][c], dtype=np.float32)
                 if r in shards_by_rank else empty for r in new_ranks])
                if new_ranks else empty)
            for c in COMPONENTS}
        self.dp_ranks = list(new_ranks)


class EntryFlattener:
    """Cached ``ravel_pytree`` unravelers: per entry and whole-model.

    Entry ids are the VirtualCluster's state-space entries (STEM / layer id /
    HEAD); the whole-model unraveler turns one flat fp32 vector back into
    ``(stem, [layer_0..layer_{L-1}], head)`` in a single call — the indexed-
    scatter replacement for the seed's per-entry re-unravel loop.
    """

    def __init__(self):
        self._entry_unravel: Dict[int, Any] = {}
        self._model_unravel = None

    def flatten_entry(self, entry: int, tree) -> np.ndarray:
        from jax.flatten_util import ravel_pytree
        vec, unravel = ravel_pytree(tree)
        self._entry_unravel[entry] = unravel
        return np.asarray(vec, dtype=np.float32)

    def unflatten_entry(self, entry: int, vec):
        return self._entry_unravel[entry](vec)

    def build_model_unraveler(self, stem, layers, head) -> None:
        import jax
        from jax.flatten_util import ravel_pytree
        _, unravel = ravel_pytree((stem, list(layers), head))
        # jit is bit-safe here: unravel is pure slicing/reshaping, and one
        # compiled call replaces ~2 eager dispatches per model leaf
        self._model_unravel = jax.jit(unravel)

    def unflatten_model(self, vec):
        """flat fp32 model vector -> (stem, [layers...], head)."""
        assert self._model_unravel is not None, "build_model_unraveler() first"
        stem, layers, head = self._model_unravel(vec)
        return stem, list(layers), head
