"""State fabric: per-step ring snapshots + live remap of ZeRO shards
(the data plane of the paper's \u00a75 parameter-consistency mechanism)."""
from .snapshot import SnapshotPool
from .remap import LiveRemap, RemapPlan, IntegrityError
