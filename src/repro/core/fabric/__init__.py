from .snapshot import SnapshotPool
from .remap import LiveRemap, RemapPlan, IntegrityError
