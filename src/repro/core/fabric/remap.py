"""Live Remap (paper §5.2, Fig. 7): four-step optimizer-state redistribution.

1. **Integrity check** — failed workers' state must be recoverable from the
   union of surviving on-device partitions (O^device) and host snapshots
   (O^host).
2. **Transfer plan** — consolidated source partitions intersected with the
   target partitions give the overlap matrix M_overlap: exact (src, dst,
   interval, channel) tuples.  Diagonal entries (src==dst, on-device) move
   nothing.
3. **Optimized redistribution** — D2D for device-resident bytes, H2D(+D2D)
   for snapshot-resident bytes; disjoint pairs proceed in parallel, so the
   modeled time is the max per-endpoint byte load over bandwidth.
4. **Finalization** — destination shards reassembled; coverage re-verified.

The state space is the stage's flat optimizer vector (see core/zero.Layout);
this module is pure interval algebra + actual numpy copies, so property tests
can assert exact coverage (every target byte written exactly once).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

Interval = Tuple[int, int]


class IntegrityError(RuntimeError):
    pass


@dataclasses.dataclass(frozen=True)
class Move:
    src: int                  # source worker (holder)
    dst: int                  # destination worker
    interval: Interval        # [start, end) in stage state space
    channel: str              # "local" | "d2d" | "h2d"

    @property
    def nbytes(self) -> int:
        return self.interval[1] - self.interval[0]


@dataclasses.dataclass
class RemapPlan:
    moves: List[Move]
    total_bytes: int
    d2d_bytes: int
    h2d_bytes: int
    est_seconds: float

    def overlap_matrix(self, n: int) -> np.ndarray:
        m = np.zeros((n, n), dtype=np.int64)
        for mv in self.moves:
            m[mv.src, mv.dst] += mv.nbytes
        return m


def _intersect(a: Interval, b: Interval) -> Optional[Interval]:
    s, e = max(a[0], b[0]), min(a[1], b[1])
    return (s, e) if s < e else None


def _coverage(ivs: Sequence[Interval]) -> int:
    return sum(e - s for s, e in ivs)


class LiveRemap:
    def __init__(self, d2d_bw: float = 25e9, h2d_bw: float = 12e9):
        self.d2d_bw = d2d_bw
        self.h2d_bw = h2d_bw

    def integrity_check(self, total: int,
                        device_parts: Dict[int, List[Interval]],
                        host_parts: Dict[int, List[Interval]]) -> None:
        """Union of available intervals must cover [0, total)."""
        ivs = sorted(iv for parts in (device_parts, host_parts)
                     for lst in parts.values() for iv in lst)
        cur = 0
        for s, e in ivs:
            if s > cur:
                raise IntegrityError(f"gap [{cur},{s}) unrecoverable")
            cur = max(cur, e)
        if cur < total:
            raise IntegrityError(f"gap [{cur},{total}) unrecoverable")

    def compute_plan(self, total: int,
                     device_parts: Dict[int, List[Interval]],
                     host_parts: Dict[int, List[Interval]],
                     target_parts: Dict[int, List[Interval]]) -> RemapPlan:
        """Step 2: overlap matrix.  Preference order per target byte:
        already-local device bytes > remote device (D2D) > host snapshot
        (H2D+D2D)."""
        self.integrity_check(total, device_parts, host_parts)
        moves: List[Move] = []
        for dst, tlist in target_parts.items():
            for tiv in tlist:
                remaining = [tiv]
                for source, channel_order in ((device_parts, "d2d"),
                                              (host_parts, "h2d")):
                    nxt: List[Interval] = []
                    for iv in remaining:
                        pieces = [iv]
                        for src, slist in source.items():
                            new_pieces: List[Interval] = []
                            for piece in pieces:
                                hit = None
                                for siv in slist:
                                    hit = _intersect(piece, siv)
                                    if hit:
                                        ch = ("local" if (channel_order == "d2d"
                                                          and src == dst) else channel_order)
                                        moves.append(Move(src, dst, hit, ch))
                                        if piece[0] < hit[0]:
                                            new_pieces.append((piece[0], hit[0]))
                                        if hit[1] < piece[1]:
                                            new_pieces.append((hit[1], piece[1]))
                                        break
                                if hit is None:
                                    new_pieces.append(piece)
                            pieces = new_pieces
                            if not pieces:
                                break
                        nxt.extend(pieces)
                    remaining = nxt
                    if not remaining:
                        break
                if remaining:
                    raise IntegrityError(f"target {dst} interval {remaining} uncovered")
        d2d = sum(m.nbytes for m in moves if m.channel == "d2d")
        h2d = sum(m.nbytes for m in moves if m.channel == "h2d")
        # disjoint endpoint pairs run in parallel: time = max endpoint load
        load: Dict[Tuple[str, int], float] = {}
        for m in moves:
            if m.channel == "local":
                continue
            bw = self.d2d_bw if m.channel == "d2d" else self.h2d_bw
            load[("s", m.src)] = load.get(("s", m.src), 0.0) + m.nbytes / bw
            load[("d", m.dst)] = load.get(("d", m.dst), 0.0) + m.nbytes / bw
        est = max(load.values()) if load else 0.0
        return RemapPlan(moves, d2d + h2d, d2d, h2d, est)

    def execute(self, plan: RemapPlan, total: int,
                device_data: Dict[int, Dict[Interval, np.ndarray]],
                host_data: Dict[int, Dict[Interval, np.ndarray]],
                ) -> Dict[int, np.ndarray]:
        """Step 3+4: materialize each destination's new shard bytes.

        device_data[rank][interval] / host_data[rank][interval] hold the flat
        fp32 state arrays for the intervals that rank owns/backs-up.
        Returns {dst_rank: assembled bytes} and verifies exact coverage.
        """
        # destination buffers
        out: Dict[int, Dict[Interval, np.ndarray]] = {}
        written: Dict[int, List[Interval]] = {}
        for mv in plan.moves:
            store = device_data if mv.channel in ("local", "d2d") else host_data
            src_map = store[mv.src]
            # find the owning interval containing mv.interval: exact match
            # first (the common whole-interval move), linear scan otherwise
            arr = src_map.get(mv.interval)
            if arr is not None:
                iv = mv.interval
            else:
                seg = None
                for iv, arr in src_map.items():
                    if iv[0] <= mv.interval[0] and mv.interval[1] <= iv[1]:
                        seg = (iv, arr)
                        break
                assert seg is not None, (mv, list(src_map))
                iv, arr = seg
            lo = mv.interval[0] - iv[0]
            hi = mv.interval[1] - iv[0]
            out.setdefault(mv.dst, {})[mv.interval] = np.array(arr[lo:hi])
            written.setdefault(mv.dst, []).append(mv.interval)
        # finalize: stitch intervals per destination in offset order.
        # (Interleaved layouts legitimately own disjoint intervals — verify
        # only that nothing overlaps, i.e. each byte written exactly once.)
        result: Dict[int, np.ndarray] = {}
        for dst, segs in out.items():
            ivs = sorted(segs)
            for (s0, e0), (s1, e1) in zip(ivs, ivs[1:]):
                if e0 > s1:
                    raise IntegrityError(f"dst {dst}: overlap {ivs}")
            result[dst] = np.concatenate([segs[iv] for iv in ivs]) if ivs else \
                np.zeros(0, dtype=np.float32)
        return result
