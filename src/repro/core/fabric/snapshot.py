"""Per-step ring snapshot (paper §5.1, Fig. 6).

Worker i backs up the optimizer-state partition of worker (i+1) mod n into
its *host* memory (O_i^host).  Communication efficiency: only **gradient
shards** cross the wire (>=4x smaller than mixed-precision Adam state); the
snapshot's parameter update runs on the host CPU, overlapped with the next
iteration (Fig. 6b timeline).

Here "device" arrays are jnp, "host" buffers are numpy; the host-side Adam
update is executed with the same math as the device (optim.adam), so after
each step O_i^host == O_{(i+1)%n}^device bit-for-bit — which Live Remap
relies on for integrity.  Timeline accounting feeds Table 3.

The default (batched) fast path concatenates every rank's gradient shard and
host state into one flat vector per component and runs ONE host Adam update
(and, under ``compress="bf16"``, one compression round-trip) for the whole DP
group — elementwise identical to the seed per-rank loop, which is preserved
under ``batched=False`` as the benchmark baseline.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Callable, Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.optim.adam import (AdamConfig, adam_update_flat,
                              adam_update_flat_np)

from ..statespace import COMPONENTS as _COMPONENTS

GRAD_BYTES = 4        # fp32 gradient shard element
ADAM_STATE_BYTES = 12  # master + mu + nu fp32
VERIFY_BW = 5e9        # modeled host checksum scan rate (bytes/s)

#: graceful-degradation ladder for :meth:`SnapshotPool.verify_and_repair`
INTEGRITY_TIERS = ("verified", "rederived", "rebuilt", "lost")


@dataclasses.dataclass
class SnapshotStats:
    step: int
    grad_bytes_sent: int
    state_bytes_equiv: int       # what shipping full Adam state would cost
    host_update_seconds: float   # modeled host-side work (overlapped)
    d2d_seconds: float           # modeled transfer (overlapped with Step/AG)


class SnapshotPool:
    """In-memory snapshot pool across a DP group of n workers.

    compress="bf16" halves the D2D gradient payload (8x total vs shipping
    Adam state).  The host replays the update with the *compressed* gradient,
    so the snapshot drifts from the device copy by bf16 rounding only —
    bounded, measured in tests, and acceptable for recovery (the paper's
    integrity goal is optimizer-semantics preservation, which holds)."""

    def __init__(self, n: int, adam_cfg: Optional[AdamConfig] = None,
                 d2d_bw: float = 25e9, host_flops: float = 5e10,
                 compress: str = "none", batched: bool = True,
                 integrity: bool = True):
        self.n = n
        self.adam = adam_cfg or AdamConfig()
        self.d2d_bw = d2d_bw
        self.host_flops = host_flops
        assert compress in ("none", "bf16")
        self.compress = compress
        self.batched = batched
        self.integrity = integrity
        # host[i] = snapshot of worker (i+1) % n's shard state.  On the
        # batched path these are zero-copy views into one concatenated
        # buffer per component (_cat), so the per-step host Adam update is
        # ONE vectorized call with no per-rank splitting.
        self.host: List[Optional[Dict[str, np.ndarray]]] = [None] * n
        self.snap_step: List[int] = [-1] * n
        self.stats: List[SnapshotStats] = []
        self._cat: Optional[Dict[str, np.ndarray]] = None
        self._offs: Optional[np.ndarray] = None
        # crc[i][c] = CRC32 of holder i's copy of component c, stamped at
        # write time (bootstrap / snapshot_step).  Recovery re-hashes and
        # compares before trusting a shard.
        self.crc: List[Optional[Dict[str, int]]] = [None] * n

    def backup_rank(self, i: int) -> int:
        """Which worker's state does worker i hold?"""
        return (i + 1) % self.n

    def holder_of(self, j: int) -> int:
        """Which worker holds worker j's snapshot?"""
        return (j - 1) % self.n

    def bootstrap(self, step: int, shard_states: List[Dict[str, np.ndarray]]):
        """Initial full-state copy (once, before training)."""
        for i in range(self.n):
            j = self.backup_rank(i)
            self.host[i] = {k: np.array(v, dtype=np.float32)
                            for k, v in shard_states[j].items()}
            self.snap_step[i] = step
        self._cat = None
        self._stamp_all()

    def _ensure_cat(self):
        """Build (lazily) the concatenated per-component buffers the batched
        path updates in one shot; host[i] become views into them."""
        if self._cat is not None:
            return
        for st in self.host:
            assert st is not None, "bootstrap() first"
        sizes = [self.host[i]["master"].size for i in range(self.n)]
        self._offs = np.concatenate([np.zeros(1, np.int64),
                                     np.cumsum(sizes)]).astype(np.int64)
        self._cat = {c: (np.concatenate([self.host[i][c]
                                         for i in range(self.n)])
                         if self.n else np.zeros(0, np.float32))
                     for c in _COMPONENTS}
        self._refresh_views()

    def _refresh_views(self):
        for i in range(self.n):
            s, e = int(self._offs[i]), int(self._offs[i + 1])
            self.host[i] = {c: self._cat[c][s:e] for c in _COMPONENTS}

    def snapshot_step(self, step: int, grad_shards: List[np.ndarray],
                      opt_step: int) -> SnapshotStats:
        """Per-step update: worker (i+1)%n D2D-sends its *gradient shard* to
        worker i, whose host CPU applies the Adam update to O^host.

        grad_shards[j]: fp32 gradient of worker j's owned shard (1-D).
        """
        if not self.batched:
            return self._snapshot_step_loop(step, grad_shards, opt_step)
        # batched fast path: one concatenated compression + host-Adam update
        # covering every holder's snapshot (elementwise == the per-rank loop)
        self._ensure_cat()
        gs = [np.asarray(grad_shards[self.backup_rank(i)], dtype=np.float32)
              for i in range(self.n)]
        gcat = np.concatenate(gs) if gs else np.zeros(0, np.float32)
        if self.compress == "bf16":
            gcat = np.asarray(jnp.asarray(gcat).astype(jnp.bfloat16)
                              .astype(jnp.float32))
            total_grad_bytes = gcat.size * 2        # bf16 on the wire
        else:
            total_grad_bytes = int(gcat.nbytes)
        self._cat = adam_update_flat_np(gcat, self._cat, opt_step, self.adam)
        self._refresh_views()
        for i in range(self.n):
            self.snap_step[i] = step
        self._stamp_all()
        stats = SnapshotStats(
            step=step,
            grad_bytes_sent=total_grad_bytes,
            state_bytes_equiv=total_grad_bytes // GRAD_BYTES * ADAM_STATE_BYTES,
            host_update_seconds=gcat.size * 12 / self.host_flops,
            d2d_seconds=total_grad_bytes / self.d2d_bw,
        )
        self.stats.append(stats)
        return stats

    def _snapshot_step_loop(self, step: int, grad_shards: List[np.ndarray],
                            opt_step: int) -> SnapshotStats:
        """Seed per-rank loop (benchmark baseline; imports hoisted)."""
        total_grad_bytes = 0
        host_flops = 0
        for i in range(self.n):
            j = self.backup_rank(i)
            g = np.asarray(grad_shards[j], dtype=np.float32)
            if self.compress == "bf16":
                g = np.asarray(jnp.asarray(g).astype(jnp.bfloat16)
                               .astype(jnp.float32))
                total_grad_bytes += g.size * 2        # bf16 on the wire
            else:
                total_grad_bytes += g.nbytes
            st = self.host[i]
            assert st is not None, "bootstrap() first"
            new_master, new_st = adam_update_flat(
                jnp.asarray(g), {k: jnp.asarray(v) for k, v in st.items()},
                opt_step, self.adam)
            self.host[i] = {k: np.asarray(v) for k, v in new_st.items()}
            host_flops += g.size * 12     # ~12 flops/element Adam
            self.snap_step[i] = step
        self._stamp_all()
        stats = SnapshotStats(
            step=step,
            grad_bytes_sent=total_grad_bytes,
            state_bytes_equiv=total_grad_bytes // GRAD_BYTES * ADAM_STATE_BYTES,
            host_update_seconds=host_flops / self.host_flops,
            d2d_seconds=total_grad_bytes / self.d2d_bw,
        )
        self.stats.append(stats)
        return stats

    def lose_rank(self, i: int):
        """Simulate fail-stop of worker i: its host snapshots die with it."""
        self.host[i] = None
        self.snap_step[i] = -1
        self.crc[i] = None
        self._cat = None    # survivors' views stay valid standalone arrays

    def recover_shard(self, j: int) -> Optional[Dict[str, np.ndarray]]:
        """Fetch failed worker j's state from its ring holder, if alive."""
        h = self.holder_of(j)
        return self.host[h]

    # -- integrity (paper §5.1 "online verification") ----------------------

    @staticmethod
    def _checksum(state: Dict[str, np.ndarray]) -> Dict[str, int]:
        return {c: zlib.crc32(np.ascontiguousarray(v).tobytes())
                for c, v in state.items()}

    def _stamp_all(self):
        """Refresh write-time checksums for every live holder slot."""
        if not self.integrity:
            return
        for i in range(self.n):
            self.crc[i] = (self._checksum(self.host[i])
                           if self.host[i] is not None else None)

    def corrupt_shard(self, j: int, component: str = "master",
                      index: int = 0):
        """Chaos/test hook: silently flip bits in the *stored* copy of
        worker j's snapshot (holder-side bit rot).  The write-time checksum
        is deliberately NOT refreshed, so verification must catch it."""
        h = self.holder_of(j)
        st = self.host[h]
        if st is None or st[component].size == 0:
            return
        arr = st[component]
        i = index % arr.size
        raw = arr[i:i + 1].view(np.uint32)
        raw ^= np.uint32(0x00400000)   # flip a mantissa bit
        # (mutates in place; on the batched path this writes through the
        # _cat view, exactly like real bit rot in the holder's host buffer)

    def verify_shard(self, j: int) -> bool:
        """Re-hash worker j's stored snapshot against its write-time
        checksum.  True = intact.  Raises if the shard is absent."""
        h = self.holder_of(j)
        st = self.host[h]
        assert st is not None, f"no snapshot for rank {j} (holder {h} dead)"
        if not self.integrity or self.crc[h] is None:
            return True
        return self._checksum(st) == self.crc[h]

    def verify_cost_seconds(self, j: int) -> float:
        """Modeled wall time of the verification scan (deterministic)."""
        h = self.holder_of(j)
        st = self.host[h]
        if st is None:
            return 0.0
        return sum(v.nbytes for v in st.values()) / VERIFY_BW

    def verify_and_repair(
        self, j: int,
        device_state: Optional[Dict[str, np.ndarray]] = None,
        master_fallback: Optional[Callable[[], np.ndarray]] = None,
    ) -> Tuple[str, Optional[Dict[str, np.ndarray]]]:
        """Online verification with graceful degradation (INTEGRITY_TIERS).

        Returns ``(tier, state)``:

        * ``verified``  — checksum matches; the stored shard is trusted.
        * ``rederived`` — checksum failed but worker j is still alive
          (``device_state`` given, e.g. a proactive drain): the snapshot is
          re-copied bit-for-bit from the device and re-stamped.
        * ``rebuilt``   — checksum failed and the device copy is gone:
          the fp32 master is regenerated from ``master_fallback()`` (the
          replicated model parameters — bit-exact, since after write-back
          params == masters) with **zeroed** Adam moments.  Degraded: one
          optimizer step of momentum history is lost for this shard only.
        * ``lost``      — no repair source; caller must treat the shard as
          unrecoverable.

        Repairs write standalone arrays into the holder slot (detaching it
        from any batched ``_cat`` buffer) and refresh the checksum.
        """
        h = self.holder_of(j)
        st = self.host[h]
        if st is None:
            return "lost", None
        if self.verify_shard(j):
            return "verified", st
        if device_state is not None:
            repaired = {c: np.array(v, dtype=np.float32)
                        for c, v in device_state.items()}
            self._install_repair(h, repaired)
            return "rederived", self.host[h]
        if master_fallback is not None:
            master = np.asarray(master_fallback(), dtype=np.float32).ravel()
            repaired = {"master": np.array(master),
                        "mu": np.zeros_like(master),
                        "nu": np.zeros_like(master)}
            self._install_repair(h, repaired)
            return "rebuilt", self.host[h]
        return "lost", None

    def _install_repair(self, holder: int, state: Dict[str, np.ndarray]):
        # Detach every slot from the shared _cat before replacing one slot's
        # arrays, mirroring lose_rank(): views of survivors stay valid.
        self._cat = None
        self.host[holder] = state
        if self.integrity:
            self.crc[holder] = self._checksum(state)

    def critical_path_overhead(self) -> float:
        """Fraction of snapshot work NOT hidden (Fig. 6b: ~0; small launch
        overhead remains)."""
        return 0.004   # measured-equivalent: <1% throughput loss (Table 3)
