"""Machine-checked forms of the paper's four elastic guarantees (§4, §6).

ElasWave's claim is that *every* legal elastic event sequence preserves four
invariants.  This module turns each one from prose into an
:class:`InvariantChecker` that the scenario runners
(``scenarios.runner.ClusterScenarioRunner`` / ``AnalyticScenarioRunner``,
``checkers=[...]``) call after every event application, every training step,
and every policy decision:

1. **Parameter consistency** — :class:`ParameterConsistencyChecker` drives a
   bit-exact twin cluster on the opposite code path (``fast_path=False`` =
   the preserved ``core/legacy.py`` seed implementation) through the
   identical event/step sequence and asserts shard-for-shard equality, and
   independently re-derives every rank's shard from the stage's reassembled
   master vector through the pure-Python ``zero.Layout`` ownership map.
2. **Dataflow consistency (§4.1)** — :class:`DataflowConsistencyChecker`:
   the global batch size is preserved exactly across every dataflow resize
   (``sum(mbs) * num_micro == global_batch``), per-rank gradient weights sum
   to 1 and equal each rank's sample share, and the sampler partition covers
   the step's global sample ids exactly once.  Analytic mode additionally
   checks each policy's decision covers the global batch.
3. **RNG / computation consistency (§4.4)** — :class:`RngConsistencyChecker`:
   the per-(sample, layer) stream map is content-addressed, so the stream of
   every surviving sample is unchanged by any reassignment.  The checker
   recomputes the normalized sample->stream map after every event; the
   paper's "naive" rank-addressed ablation mode trips it on the first
   dataflow resize.
4. **Bounded MTTR / throughput recovery (§6.1)** —
   :class:`MttrThroughputChecker` (analytic) replays the runner's exact
   ``GroupDelta`` sequence through the dict/set
   ``legacy_comm.LegacyDynamicCommunicator`` oracle and requires equal
   ``OpStats`` seconds, bounds the committed edit cost by the O(degree)
   budget (independent of cluster size), and brackets post-event throughput:
   pristine view -> exactly base throughput; any legal degraded view ->
   within (DVFS-capped upper bound, width/straggler floor).
   :class:`MttrBoundChecker` is the numeric-mode counterpart over the
   itemized recovery records.

A violation raises :class:`InvariantViolation` (an ``AssertionError``
subclass); ``scenarios.fuzz.run_case`` decorates it with the fuzz seed and a
one-line repro command.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from .communicator import EDIT_CONST_S, LINK_SETUP_S


class InvariantViolation(AssertionError):
    """One of the paper's four elastic guarantees failed on a trace."""


class InvariantChecker:
    """Hook interface called by the scenario runners; all hooks are no-ops.

    Cluster (numeric) mode: ``on_cluster_start`` once, then
    ``after_cluster_event`` per applied event and ``after_cluster_step`` per
    training step.  Analytic mode: ``on_analytic_start`` once, then
    ``after_analytic_event`` per event and ``after_analytic_decision`` per
    re-decision boundary.
    """

    name = "invariant"

    # -- numeric (VirtualCluster) hooks ------------------------------------
    def on_cluster_start(self, runner, cluster):
        pass

    def after_cluster_event(self, step, event, cluster, record):
        pass

    def after_cluster_step(self, step, cluster, loss):
        pass

    # -- analytic (ClusterView / policy) hooks -----------------------------
    def on_analytic_start(self, runner, seg, view, comm):
        pass

    def after_analytic_event(self, step, event, view, comm, extra):
        pass

    def after_analytic_decision(self, step, view, decision, throughput,
                                base_throughput):
        pass

    def fail(self, msg: str):
        raise InvariantViolation(f"[{self.name}] {msg}")


# ---------------------------------------------------------------------------
# 1. parameter consistency (fast path == legacy oracle, shards == zero.Layout)
# ---------------------------------------------------------------------------
class ParameterConsistencyChecker(InvariantChecker):
    """Twin-oracle lockstep: a second cluster on the opposite code path
    receives the identical event/step sequence; state must stay bit-identical
    (float ``==``, no tolerance) after every event and every step."""

    name = "parameter-consistency"

    def __init__(self):
        self.twin = None

    def on_cluster_start(self, runner, cluster):
        self.twin = runner.workload.make_cluster(
            fast_path=not cluster.fast_path)
        self._compare_state("start", cluster)

    def after_cluster_event(self, step, event, cluster, record):
        twin_rec = self.twin.apply_event(event)
        for k in ("detect", "communicator", "rng_moves"):
            if twin_rec.get(k) != record.get(k):
                self.fail(f"step {step} {event.describe()}: recovery record "
                          f"field {k!r} diverged (fast={record.get(k)!r}, "
                          f"legacy={twin_rec.get(k)!r})")
        self._compare_state(f"step {step} after {event.describe()}", cluster)

    def after_cluster_step(self, step, cluster, loss):
        twin_loss = self.twin.train_step()
        if float(twin_loss) != float(loss):
            self.fail(f"step {step}: loss diverged from legacy oracle "
                      f"(fast={float(loss)!r}, legacy={float(twin_loss)!r})")
        self._compare_state(f"step {step} after train_step", cluster)

    def _compare_state(self, where: str, cl):
        from .statespace import COMPONENTS
        tw = self.twin
        if cl.layer_assignment != tw.layer_assignment:
            self.fail(f"{where}: layer assignment diverged "
                      f"({cl.layer_assignment} vs {tw.layer_assignment})")
        if list(cl.per_rank_mbs) != list(tw.per_rank_mbs):
            self.fail(f"{where}: per-rank micro-batch sizes diverged")
        if list(cl.grad_weights) != list(tw.grad_weights):
            self.fail(f"{where}: gradient weights diverged")
        for p, (st, ts) in enumerate(zip(cl.stages, tw.stages)):
            if (list(st.entries) != list(ts.entries)
                    or list(st.sizes) != list(ts.sizes)
                    or list(st.dp_ranks) != list(ts.dp_ranks)):
                self.fail(f"{where}: stage {p} structure diverged")
            for comp in COMPONENTS:
                a = cl._stage_full_vec(st, comp)
                b = tw._stage_full_vec(ts, comp)
                if not np.array_equal(a, b):
                    i = int(np.flatnonzero(a != b)[0])
                    self.fail(f"{where}: stage {p} {comp} full vector "
                              f"diverged from legacy oracle at element {i} "
                              f"({a[i]!r} vs {b[i]!r})")
                for r in st.dp_ranks:
                    if not np.array_equal(st.shard(r)[comp],
                                          ts.shard(r)[comp]):
                        self.fail(f"{where}: stage {p} rank {r} {comp} shard "
                                  f"diverged from legacy oracle")
            self._check_layout(where, p, st)

    def _check_layout(self, where: str, p: int, st):
        """Every rank's shard must equal the reassembled master gathered
        through the pure-Python ``zero.Layout`` ownership intervals."""
        from .statespace import COMPONENTS
        from .zero import Layout
        layout = Layout(st.layout_kind, tuple(st.sizes), len(st.dp_ranks))
        for comp in COMPONENTS:
            full = st.full(comp)
            for j, r in enumerate(st.dp_ranks):
                parts = [full[s:e] for s, e in layout.owner_intervals(j)]
                want = (np.concatenate(parts) if parts
                        else np.zeros(0, np.float32))
                if not np.array_equal(st.shard(r)[comp], want):
                    self.fail(f"{where}: stage {p} rank {r} {comp} shard "
                              f"does not match zero.Layout reassembly")


# ---------------------------------------------------------------------------
# 1b. kernel consistency (pallas-mode parameter consistency, tolerance tiers)
# ---------------------------------------------------------------------------
class KernelConsistencyChecker(InvariantChecker):
    """Pallas-mode replacement for the bit-exact parameter twin.

    The Pallas kernels are numerically equivalent but not bit-identical to
    plain jnp (blocked online softmax, chunked scan), so a pallas-mode trace
    cannot be held to float ``==``.  This checker relaxes invariant 1 to the
    *declared* tolerance instead of dropping it:

    * at cluster start, every kernel is spot-checked against its
      ``kernels/ref.py`` oracle under ``kernels.ops.TOLERANCE_TIERS``
      (the corpus in ``kernels/check.py``);
    * a ``use_pallas``-flipped twin cluster (plain jnp, same fast_path)
      receives the identical event/step sequence; structure (layer
      assignment, dataflow shape, stage entries/sizes/dp_ranks) and the
      control-plane recovery-record fields stay EXACT, while losses and the
      master/mu/nu state vectors are compared under a tolerance that grows
      with the optimizer step count — each Adam step can move an element of
      the two runs apart by at most ~2*lr (sign flip of the bounded update)
      plus the forward tolerance, so ``atol = ATOL0 + 2*lr*opt_step``.
      Observed drift on the fuzz corpus is orders of magnitude below this
      bound (the kernels' custom VJPs backpropagate exact oracle gradients).

    Note the bit-exact fast/legacy ``ParameterConsistencyChecker`` remains
    valid in pallas mode (both paths share ``_loss_fn``, hence the same
    kernels); this checker covers the pallas-vs-jnp axis.
    """

    name = "kernel-consistency"

    LOSS_RTOL = 1e-4
    LOSS_ATOL = 1e-6
    PARAM_RTOL = 1e-4
    PARAM_ATOL0 = 1e-5

    def __init__(self, spot_check: bool = True):
        self.twin = None
        self.spot_check = spot_check

    def on_cluster_start(self, runner, cluster):
        if self.spot_check:
            from repro.kernels.check import check_kernels
            for row in check_kernels(seed=0):
                if not row["within_tolerance"]:
                    self.fail(
                        f"kernel-vs-ref spot check failed: {row['case']} "
                        f"max_abs_err={row['max_abs_err']:.3e} exceeds tier "
                        f"rtol={row['rtol']} atol={row['atol']}")
        self.twin = runner.workload.make_cluster(
            use_pallas=not cluster.use_pallas)
        self._compare_state("start", cluster)

    def after_cluster_event(self, step, event, cluster, record):
        twin_rec = self.twin.apply_event(event)
        for k in ("detect", "communicator", "rng_moves"):
            if twin_rec.get(k) != record.get(k):
                self.fail(f"step {step} {event.describe()}: recovery record "
                          f"field {k!r} diverged across kernel modes "
                          f"({record.get(k)!r} vs {twin_rec.get(k)!r})")
        self._compare_state(f"step {step} after {event.describe()}", cluster)

    def after_cluster_step(self, step, cluster, loss):
        twin_loss = self.twin.train_step()
        a, b = float(loss), float(twin_loss)
        if abs(a - b) > self.LOSS_ATOL + self.LOSS_RTOL * abs(b):
            self.fail(f"step {step}: loss diverged across kernel modes "
                      f"beyond tolerance ({a!r} vs {b!r})")
        self._compare_state(f"step {step} after train_step", cluster)

    def _param_atol(self, cl) -> float:
        return self.PARAM_ATOL0 + 2.0 * cl.adam.lr * cl.opt_step

    def _compare_state(self, where: str, cl):
        from .statespace import COMPONENTS
        tw = self.twin
        if cl.layer_assignment != tw.layer_assignment:
            self.fail(f"{where}: layer assignment diverged "
                      f"({cl.layer_assignment} vs {tw.layer_assignment})")
        if list(cl.per_rank_mbs) != list(tw.per_rank_mbs):
            self.fail(f"{where}: per-rank micro-batch sizes diverged")
        if list(cl.grad_weights) != list(tw.grad_weights):
            self.fail(f"{where}: gradient weights diverged")
        atol = self._param_atol(cl)
        for p, (st, ts) in enumerate(zip(cl.stages, tw.stages)):
            if (list(st.entries) != list(ts.entries)
                    or list(st.sizes) != list(ts.sizes)
                    or list(st.dp_ranks) != list(ts.dp_ranks)):
                self.fail(f"{where}: stage {p} structure diverged")
            for comp in COMPONENTS:
                a = cl._stage_full_vec(st, comp)
                b = tw._stage_full_vec(ts, comp)
                if not np.allclose(a, b, rtol=self.PARAM_RTOL, atol=atol):
                    err = np.abs(a - b) - atol - self.PARAM_RTOL * np.abs(b)
                    i = int(np.argmax(err))
                    self.fail(
                        f"{where}: stage {p} {comp} diverged across kernel "
                        f"modes beyond tolerance (element {i}: {a[i]!r} vs "
                        f"{b[i]!r}, atol={atol:.3e} after {cl.opt_step} "
                        f"optimizer steps)")


# ---------------------------------------------------------------------------
# 2. dataflow consistency (§4.1)
# ---------------------------------------------------------------------------
class DataflowConsistencyChecker(InvariantChecker):
    """Global batch size and gradient scale preserved across every resize."""

    name = "dataflow-consistency"

    # -- numeric mode ------------------------------------------------------
    def on_cluster_start(self, runner, cluster):
        self._check_cluster("start", cluster)

    def after_cluster_event(self, step, event, cluster, record):
        self._check_cluster(f"step {step} after {event.describe()}", cluster)

    def after_cluster_step(self, step, cluster, loss):
        self._check_cluster(f"step {step}", cluster)

    def _check_cluster(self, where: str, cl):
        gb, nm = cl.global_batch, cl.num_micro
        if sum(cl.per_rank_mbs) * nm != gb:
            self.fail(f"{where}: global batch not preserved — "
                      f"sum(mbs)={sum(cl.per_rank_mbs)} x num_micro={nm} "
                      f"!= {gb}")
        s = float(sum(cl.grad_weights))
        if abs(s - 1.0) > 1e-9:
            self.fail(f"{where}: gradient weights sum to {s!r}, not 1.0")
        per_micro = gb // nm
        for r, (sz, wgt) in enumerate(zip(cl.per_rank_mbs, cl.grad_weights)):
            if abs(wgt - sz / per_micro) > 1e-12:
                self.fail(f"{where}: rank {r} weight {wgt!r} != sample share "
                          f"{sz}/{per_micro}")
        ids = cl.sampler.partition(cl.step_count, cl.per_rank_mbs, nm)
        got = np.sort(np.concatenate([i for rr in ids for i in rr]))
        want = cl.sampler.sample_ids(cl.step_count)
        if not np.array_equal(got, want):
            self.fail(f"{where}: sampler partition does not cover the global "
                      f"batch exactly once")

    # -- analytic mode -----------------------------------------------------
    def on_analytic_start(self, runner, seg, view, comm):
        self._gb0, self._nm0 = view.global_batch, view.num_micro

    def after_analytic_event(self, step, event, view, comm, extra):
        if (view.global_batch, view.num_micro) != (self._gb0, self._nm0):
            self.fail(f"step {step}: event mutated global batch shape "
                      f"({view.global_batch} x {view.num_micro}, was "
                      f"{self._gb0} x {self._nm0})")
        if int(view.stage_width().min()) >= 1:
            from .planners.dataflow import plan_dataflow_view
            try:
                plan_dataflow_view(view)    # validate() asserts exactness
            except AssertionError as e:
                self.fail(f"step {step}: dataflow plan over surviving width "
                          f"violates batch exactness: {e}")

    def after_analytic_decision(self, step, view, decision, throughput,
                                base_throughput):
        if not decision.feasible:
            return
        d = decision.detail
        per_micro = view.global_batch // view.num_micro
        if "mbs_stage" in d and "width" in d:       # elaswave
            for p, (m, wd) in enumerate(zip(d["mbs_stage"], d["width"])):
                if m * wd < per_micro:
                    self.fail(f"step {step}: stage {p} under-covers the "
                              f"per-micro slice ({m} x {wd} < {per_micro})")
        elif {"mbs", "num_micro", "alive_reps"} <= set(d):  # torchft/oobleck
            got = d["mbs"] * d["num_micro"] * d["alive_reps"]
            if got < view.global_batch:
                self.fail(f"step {step}: replica split covers {got} < "
                          f"global batch {view.global_batch}")


# ---------------------------------------------------------------------------
# 3. RNG / computation consistency (§4.4)
# ---------------------------------------------------------------------------
def _normalized_stream_map(cl) -> np.ndarray:
    """``map[sample_offset] -> stream id`` for the cluster's next step, with
    the step's contiguous id base removed.  Content-addressed ("reshard")
    streams make this the identity regardless of rank assignment; the naive
    rank-addressed mode makes it a function of the current dataflow."""
    step = cl.step_count
    base = step * cl.global_batch
    ids_by_rank = cl.sampler.partition(step, cl.per_rank_mbs, cl.num_micro)
    out = np.full(cl.global_batch, -1, dtype=np.int64)
    for m in range(cl.num_micro):
        for r, rank_ids in enumerate(ids_by_rank):
            ids = rank_ids[m]
            if not len(ids):
                continue
            if cl.rng_mode == "reshard":
                sids = ids.astype(np.int64) - base
            else:           # naive: position-in-rank + rank offset
                sids = np.arange(len(ids), dtype=np.int64) + r * 100003
            out[ids - base] = sids
    return out


class RngConsistencyChecker(InvariantChecker):
    """Per-(sample, layer) streams unchanged for surviving work (§4.4)."""

    name = "rng-consistency"

    def on_cluster_start(self, runner, cluster):
        from .planners.rng import verify_equivalence
        self._ref = _normalized_stream_map(cluster)
        L = cluster.cfg.num_layers
        if not verify_equivalence(cluster.base_key, cluster.step_count,
                                  [0, L - 1], [0, 1]):
            self.fail("content-addressed stream keys are not "
                      "owner-independent (key derivation regressed)")

    def after_cluster_event(self, step, event, cluster, record):
        self._check(f"step {step} after {event.describe()}", cluster)

    def after_cluster_step(self, step, cluster, loss):
        self._check(f"step {step}", cluster)

    def _check(self, where: str, cl):
        now = _normalized_stream_map(cl)
        moved = np.flatnonzero(now != self._ref)
        if moved.size:
            o = int(moved[0])
            self.fail(f"{where}: {moved.size}/{now.size} per-sample RNG "
                      f"streams moved under rng_mode={cl.rng_mode!r} (e.g. "
                      f"sample offset {o}: stream {self._ref[o]} -> {now[o]})"
                      f" — computation consistency (§4.4) broken")


# ---------------------------------------------------------------------------
# 4. bounded MTTR / throughput recovery
# ---------------------------------------------------------------------------
class MttrBoundChecker(InvariantChecker):
    """Numeric-mode MTTR: itemized records are internally consistent and the
    committed communicator edit stays within the O(degree) budget."""

    name = "mttr-bound"

    # detection interval bound modeled in VirtualCluster.apply_event
    DETECT_BOUND_S = 0.5
    # links an in-place edit may create per touched rank (ring reconnects on
    # its two hybrid groups), i.e. the "degree" of the O(degree) claim
    LINKS_PER_RANK = 4

    def after_cluster_event(self, step, event, cluster, record):
        parts = sum(record.get(k, 0.0) for k in
                    ("detect", "plan", "communicator", "remap", "migration",
                     "verify"))
        if abs(record.get("total", 0.0) - parts) > 1e-9:
            self.fail(f"step {step} {event.describe()}: MTTR total "
                      f"{record.get('total')!r} != sum of itemized phases "
                      f"{parts!r}")
        if record.get("detect", 0.0) > self.DETECT_BOUND_S + 1e-9:
            self.fail(f"step {step}: detection {record['detect']!r}s exceeds "
                      f"the heartbeat bound {self.DETECT_BOUND_S}s")
        if event.is_shrink or event.is_grow:
            k = max(1, len(event.ranks))
            budget = k * (EDIT_CONST_S
                          + LINK_SETUP_S * self.LINKS_PER_RANK)
            got = record.get("communicator", 0.0)
            if got > budget + 1e-9:
                self.fail(f"step {step} {event.describe()}: communicator "
                          f"edit {got!r}s exceeds the O(degree) budget "
                          f"{budget!r}s for {k} rank(s) — edit cost must not "
                          f"scale with cluster size")


class MttrThroughputChecker(InvariantChecker):
    """Analytic-mode MTTR + throughput recovery.

    * communicator: the runner's ``OpStats`` accounting must equal a
      dict/set ``LegacyDynamicCommunicator`` oracle replaying the same
      ``GroupDelta`` sequence, and the committed edit must stay within the
      O(degree) budget;
    * migration: stall bounded by the un-overlapped transfer time;
    * throughput: policy-contract feasibility, and for every feasible
      decision ``0 < thr <= thr0 * max_freq`` with a pristine view recovering
      ``thr0`` exactly and a degraded view held above the width/straggler
      floor (``floor_slack`` absorbs pipeline-shape rounding, validated
      empirically over the deterministic fuzz corpus).
    """

    name = "mttr-throughput"

    LINKS_PER_RANK = 4

    def __init__(self, floor_slack: float = 8.0):
        self.floor_slack = floor_slack

    def on_analytic_start(self, runner, seg, view, comm):
        from .communicator import build_hybrid_groups
        from .legacy_comm import LegacyDynamicCommunicator
        self._runner = runner
        w = runner.workload
        self._hw = w.hw
        self._oracle = LegacyDynamicCommunicator(
            build_hybrid_groups(w.dp, w.pp))

    def after_analytic_event(self, step, event, view, comm, extra):
        mig = extra.get("migration")
        if mig is not None:
            from .migration import ORCH_OVERHEAD_S
            stall = mig["stall_seconds"]
            orch = ORCH_OVERHEAD_S * max(mig["n_layers"], 1)
            # ceiling: orchestration + fully-unhidden copy + payback grads
            # (2x params at the 20% unhidden fraction); floor: orchestration
            # is never hidden (§6.2)
            hi = orch + 1.4 * mig["param_seconds"] + mig["opt_seconds"]
            if not (orch - 1e-9 <= stall <= hi + 1e-9):
                self.fail(f"step {step}: migration stall {stall!r}s outside "
                          f"[{orch!r}, {hi!r}]s (orch + param/opt copy + "
                          f"payback bound)")
            return
        acct = extra.get("communicator")
        if acct is None:
            return
        delta = self._runner.delta_for_event(event)
        if not event.is_grow:
            for policy, key in (("partial_rebuild", "partial_rebuild_seconds"),
                                ("full_rebuild", "full_rebuild_seconds")):
                want = self._oracle.price(delta, policy).seconds
                if acct.get(key) != want:
                    self.fail(f"step {step} {event.describe()}: {policy} "
                              f"pricing diverged from the legacy oracle "
                              f"({acct.get(key)!r} vs {want!r})")
        edit = self._oracle.apply(delta, "edit").seconds
        if acct["edit_seconds"] != edit:
            self.fail(f"step {step} {event.describe()}: vectorized "
                      f"communicator edit {acct['edit_seconds']!r}s != "
                      f"legacy oracle {edit!r}s")
        k = max(1, len(event.ranks))
        budget = EDIT_CONST_S + LINK_SETUP_S * self.LINKS_PER_RANK * k
        if acct["edit_seconds"] > budget + 1e-9:
            self.fail(f"step {step} {event.describe()}: edit "
                      f"{acct['edit_seconds']!r}s exceeds the O(degree) "
                      f"budget {budget!r}s for {k} rank(s)")

    def after_analytic_decision(self, step, view, decision, throughput,
                                base_throughput):
        min_width = int(view.stage_width().min())
        if decision.name == "elaswave" and min_width >= 1 \
                and not decision.feasible:
            self.fail(f"step {step}: elaswave infeasible although every "
                      f"stage keeps >= 1 replica (detail={decision.detail})")
        if decision.name == "torchft":
            expect = bool(view.alive.all(axis=1).any())
            if bool(decision.feasible) != expect:
                self.fail(f"step {step}: torchft feasibility "
                          f"{decision.feasible} != fully-alive-replica "
                          f"predicate {expect}")
        if not decision.feasible:
            return
        thr, thr0 = throughput, base_throughput
        if not (thr > 0.0 and np.isfinite(thr)):
            self.fail(f"step {step}: feasible decision with non-positive "
                      f"throughput {thr!r}")
        cap = thr0 * self._hw.max_freq * (1.0 + 1e-6)
        if thr > cap:
            self.fail(f"step {step}: throughput {thr!r} exceeds the "
                      f"DVFS-capped bound {cap!r} (thr0 x max_freq)")
        alive = view.rank_alive
        pristine = (bool(alive.all())
                    and bool((view.rank_slow == 1.0).all())
                    and bool((view.rank_freq == 1.0).all()))
        if pristine:
            if abs(thr - thr0) > 1e-9 * max(thr0, 1.0):
                self.fail(f"step {step}: pristine cluster did not recover "
                          f"base throughput ({thr!r} vs {thr0!r})")
            return
        if not alive.any():
            return
        max_slow = float(view.rank_slow[alive].max())
        min_freq = min(1.0, float(view.rank_freq[alive].min()))
        floor = (thr0 * (min_width / view.dp) * min_freq
                 / (max_slow * self.floor_slack))
        if thr < floor:
            self.fail(f"step {step}: recovered throughput {thr!r} below the "
                      f"floor {floor!r} (min_width={min_width}/{view.dp}, "
                      f"max_slow={max_slow}, slack={self.floor_slack}) — "
                      f"throughput did not recover after the event")


def default_cluster_checkers(use_pallas: bool = False) -> List[InvariantChecker]:
    """The four paper guarantees for numeric (VirtualCluster) traces.

    ``use_pallas=True`` swaps the bit-exact fast/legacy parameter twin for
    the tolerance-tier :class:`KernelConsistencyChecker` (pallas/jnp twin) —
    invariant 1 relaxed to the kernels' declared tolerance, the other three
    unchanged."""
    param: InvariantChecker = (KernelConsistencyChecker() if use_pallas
                               else ParameterConsistencyChecker())
    return [param, DataflowConsistencyChecker(),
            RngConsistencyChecker(), MttrBoundChecker()]


def default_analytic_checkers() -> List[InvariantChecker]:
    """The analytic-plane guarantees (dataflow + MTTR/throughput)."""
    return [DataflowConsistencyChecker(), MttrThroughputChecker()]
