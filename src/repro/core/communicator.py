"""Dynamic Communicator (paper §6.1): in-place communication-group edits.

The communicator tracks the *link graph* (established point-to-point
connections, NCCL/HCCL-ring style: a group of n ranks maintains n ring links)
and the group table.  Three recovery modes, matching the paper's Fig. 12b:

* ``full_rebuild``   — tear down everything, global barrier, re-init every
                       group (what restart-based systems pay).
* ``partial_rebuild``— re-init only groups containing an affected rank.
* ``edit``           — ElasWave: keep every intact link; for each affected
                       group, drop the failed rank's links and create only the
                       single reconnecting link between its ring neighbors
                       (scale-down), or only the new member's links (scale-up).

Cost model (calibrated to the paper's measurements on 200Gbps RoCE):
  link setup ~ LINK_SETUP_S each (QP/transport handshake), plus per-rank
  bootstrap/barrier costs for rebuild modes.  Paper: full 12–16 s,
  partial 0.54–1.09 s, edit 0.15–0.37 s over 8–64 ranks; our constants land
  in those bands and, more importantly, reproduce the *scaling shape*:
  edit is O(degree) (flat), rebuilds grow with rank count.

On a real TPU deployment the "links" are XLA-managed ICI channels; editing
means re-making only the affected `Mesh` axes and re-jitting programs whose
collectives touch them — the planning layer (which groups are affected) is
identical.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, FrozenSet, List, Optional, Sequence, Set, Tuple

Link = FrozenSet[int]

# calibrated constants (seconds)
LINK_SETUP_S = 0.012          # per point-to-point transport setup
BOOTSTRAP_PER_RANK_S = 0.18   # store/rendezvous + context init per rank (full)
PARTIAL_PER_RANK_S = 0.055    # re-init cost per rank in affected groups
EDIT_CONST_S = 0.10           # plan + group-table swap (in-place edit)


def ring_links(ranks: Sequence[int]) -> Set[Link]:
    n = len(ranks)
    if n < 2:
        return set()
    return {frozenset((ranks[i], ranks[(i + 1) % n])) for i in range(n)}


@dataclasses.dataclass
class OpStats:
    mode: str
    links_created: int
    links_reused: int
    links_destroyed: int
    ranks_touched: int
    seconds: float


class DynamicCommunicator:
    def __init__(self, groups: Dict[str, List[int]]):
        self.groups: Dict[str, List[int]] = {k: list(v) for k, v in groups.items()}
        self.links: Set[Link] = set()
        for g in self.groups.values():
            self.links |= ring_links(g)
        self.history: List[OpStats] = []

    # ---- helpers ----
    def clone(self) -> "DynamicCommunicator":
        """Independent copy with the same group table and established links —
        used by the scenario engine to price the rebuild alternatives (edit
        vs partial vs full) against identical starting state."""
        c = DynamicCommunicator(self.groups)
        c.links = set(self.links)
        return c

    def _group_links(self) -> Set[Link]:
        s: Set[Link] = set()
        for g in self.groups.values():
            s |= ring_links(g)
        return s

    def affected_groups(self, ranks: Sequence[int]) -> List[str]:
        rs = set(ranks)
        return [k for k, g in self.groups.items() if rs & set(g)]

    def all_ranks(self) -> Set[int]:
        out: Set[int] = set()
        for g in self.groups.values():
            out |= set(g)
        return out

    # ---- recovery modes ----
    def full_rebuild(self, new_groups: Dict[str, List[int]]) -> OpStats:
        old_links = set(self.links)
        self.groups = {k: list(v) for k, v in new_groups.items()}
        new_links = self._group_links()
        n_ranks = len(self.all_ranks())
        secs = (BOOTSTRAP_PER_RANK_S * n_ranks + LINK_SETUP_S * len(new_links))
        self.links = new_links
        st = OpStats("full_rebuild", len(new_links), 0, len(old_links), n_ranks, secs)
        self.history.append(st)
        return st

    def partial_rebuild(self, remove: Sequence[int] = (),
                        add: Sequence[Tuple[str, int]] = ()) -> OpStats:
        affected = set(self.affected_groups(remove)) | {g for g, _ in add}
        created = destroyed = reused = 0
        touched: Set[int] = set()
        for name in affected:
            old = ring_links(self.groups[name])
            self.groups[name] = [r for r in self.groups[name] if r not in set(remove)]
            for g, r in add:
                if g == name:
                    self.groups[name].append(r)
            new = ring_links(self.groups[name])
            # partial rebuild: tears down & re-creates ALL links of the group
            destroyed += len(old)
            created += len(new)
            touched |= set(self.groups[name])
            self.links -= old
            self.links |= new
        secs = PARTIAL_PER_RANK_S * len(touched) + LINK_SETUP_S * created
        st = OpStats("partial_rebuild", created, 0, destroyed, len(touched), secs)
        self.history.append(st)
        return st

    def edit(self, remove: Sequence[int] = (),
             add: Sequence[Tuple[str, int]] = ()) -> OpStats:
        """ElasWave in-place edit: reuse intact links, create only missing."""
        affected = set(self.affected_groups(remove)) | {g for g, _ in add}
        created = destroyed = reused = 0
        touched: Set[int] = set()
        for name in affected:
            old = ring_links(self.groups[name])
            self.groups[name] = [r for r in self.groups[name] if r not in set(remove)]
            for g, r in add:
                if g == name:
                    self.groups[name].append(r)
            new = ring_links(self.groups[name])
            newly = new - self.links          # only links not yet established
            dead = old - new
            created += len(newly)
            reused += len(new & self.links)
            destroyed += len(dead)
            touched |= set(self.groups[name])
            self.links -= dead
            self.links |= newly
        secs = EDIT_CONST_S + LINK_SETUP_S * created
        st = OpStats("edit", created, reused, destroyed, len(touched), secs)
        self.history.append(st)
        return st


def build_hybrid_groups(dp: int, pp: int, tp: int = 1) -> Dict[str, List[int]]:
    """Rank layout: rank = ((d * pp) + p) * tp + t (DP-major, then PP, TP)."""
    groups: Dict[str, List[int]] = {}

    def rank(d, p, t=0):
        return (d * pp + p) * tp + t

    for p in range(pp):
        for t in range(tp):
            groups[f"dp_stage{p}_tp{t}"] = [rank(d, p, t) for d in range(dp)]
    for d in range(dp):
        for t in range(tp):
            groups[f"pp_rep{d}_tp{t}"] = [rank(d, p, t) for p in range(pp)]
    if tp > 1:
        for d in range(dp):
            for p in range(pp):
                groups[f"tp_rep{d}_stage{p}"] = [rank(d, p, t) for t in range(tp)]
    return groups
