"""Dynamic Communicator (paper §6.1): in-place communication-group edits.

The communicator tracks the *link graph* (established point-to-point
connections, NCCL/HCCL-ring style: a group of n ranks maintains n ring links)
and the group table.  Three recovery policies, matching the paper's Fig. 12b,
unified behind one entrypoint — ``apply(GroupDelta, policy) -> OpStats``:

* ``full_rebuild``   — tear down everything, global barrier, re-init every
                       group (what restart-based systems pay).
* ``partial_rebuild``— re-init only groups containing an affected rank.
* ``edit``           — ElasWave: keep every intact link; for each affected
                       group, drop the failed rank's links and create only the
                       single reconnecting link between its ring neighbors
                       (scale-down), or only the new member's links (scale-up).

``price(delta, policy)`` computes the same ``OpStats`` *without* committing,
so the scenario runner prices the rebuild alternatives against identical
pre-event state with no ``clone()``/deep-copy.  The legacy per-mode methods
(``edit``/``partial_rebuild``/``full_rebuild``) remain as thin deprecated
shims over ``apply``.

Internally the link graph is rank-vectorized so a 10^5-rank table prices a
correlated burst in milliseconds (ISSUE 7 / ROADMAP "scale the system model
to 10^5–10^6 ranks"):

* links are canonical **int64 codes** (``min << 32 | max``) instead of
  ``frozenset`` pairs; the established-link set is a set of codes, per-group
  ring codes are numpy arrays;
* per-group ring codes are **memoized** (``_ring_cache``), invalidated only
  for groups a delta actually edits — the seed recomputed every group's links
  from scratch on every ``affected_groups``/accounting call;
* the group table keeps a lazily rebuilt **CSR index** (flat member array +
  offsets + rank-sorted permutation), so ``affected_groups`` over a burst is
  one ``np.isin`` instead of a scan of every group's membership.

Cost model (calibrated to the paper's measurements on 200Gbps RoCE):
  link setup ~ LINK_SETUP_S each (QP/transport handshake), plus per-rank
  bootstrap/barrier costs for rebuild modes.  Paper: full 12–16 s,
  partial 0.54–1.09 s, edit 0.15–0.37 s over 8–64 ranks; our constants land
  in those bands and, more importantly, reproduce the *scaling shape*:
  edit is O(degree) (flat), rebuilds grow with rank count.

The seed dict/set implementation survives as
``core.legacy_comm.LegacyDynamicCommunicator``, the equivalence oracle
enforced at ≤ 64 ranks by ``tests/test_comm_oracle.py``.

On a real TPU deployment the "links" are XLA-managed ICI channels; editing
means re-making only the affected `Mesh` axes and re-jitting programs whose
collectives touch them — the planning layer (which groups are affected) is
identical.
"""
from __future__ import annotations

import dataclasses
import itertools
import warnings
from typing import Dict, FrozenSet, List, Optional, Sequence, Set, Tuple

import numpy as np

from .clusterview import GroupDelta

Link = FrozenSet[int]

# calibrated constants (seconds)
LINK_SETUP_S = 0.012          # per point-to-point transport setup
BOOTSTRAP_PER_RANK_S = 0.18   # store/rendezvous + context init per rank (full)
PARTIAL_PER_RANK_S = 0.055    # re-init cost per rank in affected groups
EDIT_CONST_S = 0.10           # plan + group-table swap (in-place edit)

RECOVERY_POLICIES = ("edit", "partial_rebuild", "full_rebuild")

_CODE_SHIFT = np.int64(32)    # link {u, v} -> (min << 32) | max; ranks < 2^31


def ring_links(ranks: Sequence[int]) -> Set[Link]:
    n = len(ranks)
    if n < 2:
        return set()
    return {frozenset((ranks[i], ranks[(i + 1) % n])) for i in range(n)}


def _ring_codes(members: np.ndarray) -> np.ndarray:
    """Sorted unique int64 link codes of one ring group (vectorized
    ``ring_links``; a 2-ring's two directed edges collapse to one code)."""
    if members.shape[0] < 2:
        return np.empty(0, np.int64)
    u = members.astype(np.int64, copy=False)
    v = np.roll(u, -1)
    return np.unique((np.minimum(u, v) << _CODE_SHIFT) | np.maximum(u, v))


def _decode_codes(codes) -> Set[Link]:
    mask = np.int64((1 << 32) - 1)
    out = set()
    for c in codes:
        c = np.int64(c)
        out.add(frozenset((int(c >> _CODE_SHIFT), int(c & mask))))
    return out


def _table_codes(groups: Dict[str, List[int]]) -> Tuple[np.ndarray, int]:
    """(unique link codes, distinct rank count) over a whole group table —
    one vectorized pass over the flat membership, no per-group Python ring
    construction."""
    sizes = np.fromiter((len(v) for v in groups.values()), np.int64,
                        len(groups))
    total = int(sizes.sum())
    if total == 0:
        return np.empty(0, np.int64), 0
    members = np.fromiter(itertools.chain.from_iterable(groups.values()),
                          np.int64, total)
    offsets = np.concatenate([np.zeros(1, np.int64), np.cumsum(sizes)])
    nxt = np.arange(1, total + 1, dtype=np.int64)
    nz = sizes > 0
    nxt[offsets[1:][nz] - 1] = offsets[:-1][nz]      # ring wrap per group
    u, v = members, members[nxt]
    valid = np.repeat(sizes >= 2, sizes)
    lo = np.minimum(u, v)[valid]
    hi = np.maximum(u, v)[valid]
    return np.unique((lo << _CODE_SHIFT) | hi), int(np.unique(members).size)


@dataclasses.dataclass
class OpStats:
    mode: str
    links_created: int
    links_reused: int
    links_destroyed: int
    ranks_touched: int
    seconds: float


class DynamicCommunicator:
    def __init__(self, groups: Dict[str, List[int]]):
        self.groups: Dict[str, List[int]] = {k: list(v) for k, v in groups.items()}
        self.history: List[OpStats] = []
        self._ring_cache: Dict[str, np.ndarray] = {}
        self._version = 0          # bumped on any membership change
        self._csr = None           # (version, names, members, sizes, sorted_members, sorted_gid)
        codes, _ = _table_codes(self.groups)
        self._link_codes: Set[int] = set(codes.tolist())

    # ---- vectorized state ------------------------------------------------
    @property
    def links(self) -> Set[Link]:
        """The established link set, materialized as the seed's
        frozenset-pair representation (tests / debugging; O(|links|))."""
        return _decode_codes(self._link_codes)

    def _codes(self, name: str) -> np.ndarray:
        """Memoized ring-link codes of one group; invalidated on group edit."""
        c = self._ring_cache.get(name)
        if c is None:
            c = _ring_codes(np.asarray(self.groups[name], dtype=np.int64))
            self._ring_cache[name] = c
        return c

    def _table(self):
        """Lazily rebuilt CSR group index: flat members + per-member group id,
        rank-sorted for O(log) membership lookups."""
        if self._csr is None or self._csr[0] != self._version:
            names = list(self.groups)
            sizes = np.fromiter((len(self.groups[n]) for n in names),
                                np.int64, len(names))
            members = np.fromiter(
                itertools.chain.from_iterable(self.groups[n] for n in names),
                np.int64, int(sizes.sum()))
            gid = np.repeat(np.arange(len(names), dtype=np.int64), sizes)
            order = np.argsort(members, kind="stable")
            self._csr = (self._version, names, members, sizes,
                         members[order], gid[order])
        return self._csr

    # ---- helpers ----
    def clone(self) -> "DynamicCommunicator":
        """Independent copy with the same group table and established links.
        The scenario engine now prices alternatives via :meth:`price`; clone
        remains for API compatibility."""
        c = DynamicCommunicator.__new__(DynamicCommunicator)
        c.groups = {k: list(v) for k, v in self.groups.items()}
        c.history = []
        c._ring_cache = dict(self._ring_cache)
        c._version = 0
        c._csr = None
        c._link_codes = set(self._link_codes)
        return c

    def _group_links(self) -> Set[Link]:
        out: Set[Link] = set()
        for name in self.groups:
            out |= _decode_codes(self._codes(name))
        return out

    def affected_groups(self, ranks: Sequence[int]) -> List[str]:
        """Groups containing any of ``ranks`` (table insertion order, like
        the seed) — one vectorized membership test over the CSR index."""
        rs = np.asarray(list(ranks), dtype=np.int64)
        if rs.size == 0:
            return []
        _, names, _, _, sorted_members, sorted_gid = self._table()
        hit = sorted_gid[np.isin(sorted_members, rs)]
        return [names[g] for g in np.unique(hit)]

    def all_ranks(self) -> Set[int]:
        _, _, members, _, _, _ = self._table()
        return set(np.unique(members).tolist())

    # ---- unified entrypoint ----------------------------------------------
    def apply(self, delta: GroupDelta, policy: str = "edit") -> OpStats:
        """Commit one membership delta under a recovery policy and return its
        priced ``OpStats``.  The single entrypoint replacing the per-mode
        methods (which remain as deprecated shims)."""
        st = self._execute(delta, policy, commit=True)
        self.history.append(st)
        return st

    def price(self, delta: GroupDelta, policy: str = "edit") -> OpStats:
        """Price a delta under a policy *without* mutating any state — the
        runner prices edit vs partial vs full from identical pre-event state
        with no clone."""
        return self._execute(delta, policy, commit=False)

    def _execute(self, delta: GroupDelta, policy: str, commit: bool) -> OpStats:
        if policy not in RECOVERY_POLICIES:
            raise ValueError(f"unknown recovery policy {policy!r}; "
                             f"expected one of {RECOVERY_POLICIES}")
        if policy == "full_rebuild":
            rem = set(delta.remove)
            new_groups = {k: [r for r in v if r not in rem]
                          for k, v in self.groups.items()}
            for g, r in delta.add:
                new_groups.setdefault(g, []).append(r)
            return self._full_rebuild(new_groups, commit)

        removed = set(delta.remove)
        adds_by_group: Dict[str, List[int]] = {}
        for g, r in delta.add:
            adds_by_group.setdefault(g, []).append(r)
        affected = set(self.affected_groups(delta.remove)) | set(adds_by_group)
        created = destroyed = reused = 0
        touched: Set[int] = set()
        links = self._link_codes if commit else set(self._link_codes)
        for name in sorted(affected):
            old_codes = self._codes(name)
            new_members = [r for r in self.groups[name] if r not in removed]
            new_members += adds_by_group.get(name, [])
            new_codes = _ring_codes(np.asarray(new_members, dtype=np.int64))
            if policy == "edit":
                in_links = np.fromiter((c in links for c in new_codes.tolist()),
                                       np.bool_, new_codes.size)
                newly = new_codes[~in_links]
                dead = np.setdiff1d(old_codes, new_codes, assume_unique=True)
                created += int(newly.size)
                reused += int(in_links.sum())
                destroyed += int(dead.size)
                links.difference_update(dead.tolist())
                links.update(newly.tolist())
            else:        # partial_rebuild: tear down + re-create ALL links
                created += int(new_codes.size)
                destroyed += int(old_codes.size)
                links.difference_update(old_codes.tolist())
                links.update(new_codes.tolist())
            touched.update(new_members)
            if commit:
                self.groups[name] = new_members
                self._ring_cache[name] = new_codes
                self._version += 1
        if policy == "edit":
            secs = EDIT_CONST_S + LINK_SETUP_S * created
            return OpStats("edit", created, reused, destroyed, len(touched), secs)
        secs = PARTIAL_PER_RANK_S * len(touched) + LINK_SETUP_S * created
        return OpStats("partial_rebuild", created, 0, destroyed, len(touched),
                       secs)

    def _full_rebuild(self, new_groups: Dict[str, List[int]],
                      commit: bool) -> OpStats:
        new_codes, n_ranks = _table_codes(new_groups)
        old_links = len(self._link_codes)
        secs = BOOTSTRAP_PER_RANK_S * n_ranks + LINK_SETUP_S * new_codes.size
        if commit:
            self.groups = {k: list(v) for k, v in new_groups.items()}
            self._ring_cache = {}
            self._version += 1
            self._link_codes = set(new_codes.tolist())
        return OpStats("full_rebuild", int(new_codes.size), 0, old_links,
                       n_ranks, secs)

    # ---- deprecated per-mode shims ---------------------------------------
    def edit(self, remove: Sequence[int] = (),
             add: Sequence[Tuple[str, int]] = ()) -> OpStats:
        """Deprecated: use ``apply(GroupDelta(remove, add), "edit")``."""
        warnings.warn("DynamicCommunicator.edit is deprecated; use "
                      "apply(GroupDelta(...), 'edit')", DeprecationWarning,
                      stacklevel=2)
        return self.apply(GroupDelta(tuple(remove), tuple(add)), "edit")

    def partial_rebuild(self, remove: Sequence[int] = (),
                        add: Sequence[Tuple[str, int]] = ()) -> OpStats:
        """Deprecated: use ``apply(GroupDelta(remove, add),
        "partial_rebuild")``."""
        warnings.warn("DynamicCommunicator.partial_rebuild is deprecated; "
                      "use apply(GroupDelta(...), 'partial_rebuild')",
                      DeprecationWarning, stacklevel=2)
        return self.apply(GroupDelta(tuple(remove), tuple(add)),
                          "partial_rebuild")

    def full_rebuild(self, new_groups: Dict[str, List[int]]) -> OpStats:
        """Deprecated: use ``apply(delta, "full_rebuild")`` (the new-group
        table is derived from the delta); this shim keeps the seed's explicit
        new-table signature."""
        warnings.warn("DynamicCommunicator.full_rebuild is deprecated; use "
                      "apply(GroupDelta(...), 'full_rebuild')",
                      DeprecationWarning, stacklevel=2)
        st = self._full_rebuild({k: list(v) for k, v in new_groups.items()},
                                commit=True)
        self.history.append(st)
        return st


def build_hybrid_groups(dp: int, pp: int, tp: int = 1) -> Dict[str, List[int]]:
    """Rank layout: rank = ((d * pp) + p) * tp + t (DP-major, then PP, TP)."""
    groups: Dict[str, List[int]] = {}

    def rank(d, p, t=0):
        return (d * pp + p) * tp + t

    for p in range(pp):
        for t in range(tp):
            groups[f"dp_stage{p}_tp{t}"] = [rank(d, p, t) for d in range(dp)]
    for d in range(dp):
        for t in range(tp):
            groups[f"pp_rep{d}_tp{t}"] = [rank(d, p, t) for p in range(pp)]
    if tp > 1:
        for d in range(dp):
            for p in range(pp):
                groups[f"tp_rep{d}_stage{p}"] = [rank(d, p, t) for t in range(tp)]
    return groups
