"""DVFS planner (paper §4.3, Alg. 2): minimum bisection frequency scaling.

Up-clock *only* the residual straggler stage, to the **lowest** frequency that
aligns its mini-step with the target T* (sustained high frequency ages
hardware).  Feasibility is tested at f_max first; UNACHIEVABLE means the gap
is not compute-bound.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Tuple

ACHIEVABLE = "ACHIEVABLE"
UNACHIEVABLE = "UNACHIEVABLE"


@dataclasses.dataclass(frozen=True)
class DvfsPlan:
    rank: int                  # stage/rank to up-clock (-1: none)
    freq: float
    status: str


def bisect_min_feasible(f_lo: float, f_hi: float,
                        feasible: Callable[[float], bool],
                        df_min: float) -> float:
    """Smallest f in [f_lo, f_hi] with feasible(f), assuming monotonicity.
    Precondition: feasible(f_hi)."""
    lo, hi = f_lo, f_hi
    while hi - lo > df_min:
        mid = 0.5 * (lo + hi)
        if feasible(mid):
            hi = mid
        else:
            lo = mid
    return hi


def plan_dvfs_stages(stage_times, f_max: float, target: float = None,
                     eps_frac: float = 0.02, df_min: float = 0.01,
                     tol: float = 1.001) -> Tuple["DvfsPlan", ...]:
    """Alg. 2 over a whole stage-time vector: up-clock every residual
    straggler stage (time > tol * target) to the lowest aligning frequency.
    The shared per-stage loop of ``ScheduleEngine.plan`` and
    ``ElasWavePolicy.decide`` — stages, not ranks, so it is scale-free."""
    times = list(stage_times)
    if target is None:
        target = min(times)
    plans = []
    for p, tt in enumerate(times):
        if tt <= target * tol:
            continue

        def obs(f, tt=tt):
            return tt / f

        plans.append(plan_dvfs(obs, 1.0, f_max, target,
                               eps=eps_frac * target, df_min=df_min, rank=p))
    return tuple(plans)


def plan_dvfs(obs_time: Callable[[float], float],
              f_cur: float, f_max: float, target: float,
              eps: float, df_min: float, rank: int = -1) -> DvfsPlan:
    """Alg. 2.  obs_time(f) = measured mini-step time at frequency f over the
    observation window W (the simulator/hardware hook)."""
    t_cur = obs_time(f_cur)
    if abs(t_cur - target) <= eps or t_cur <= target + eps:
        return DvfsPlan(rank, f_cur, ACHIEVABLE)
    t_max = obs_time(f_max)
    if t_max > target + eps:
        return DvfsPlan(rank, f_max, UNACHIEVABLE)
    f_star = bisect_min_feasible(
        f_cur, f_max, lambda f: obs_time(f) <= target + eps, df_min)
    return DvfsPlan(rank, f_star, ACHIEVABLE)
