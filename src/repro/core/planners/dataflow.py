"""Dataflow planner (paper §4.1): micro-batch resizing, not rerouting.

On DP shrink from D to D', each surviving rank's micro-batch size grows so
that  D' x mbs' x num_micro == global_batch  is preserved exactly; the
per-rank gradient weights (= samples contributed / global_batch) keep the
global gradient identical to the fault-free run (§4.4 "adjust the computation
of average gradient ... so that the unevenly divided micro batch will not
affect the final gradient results").
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class DataflowPlan:
    micro_batch_sizes: Tuple[int, ...]    # per surviving DP rank
    num_micro_batches: int
    grad_weights: Tuple[float, ...]       # per rank, sums to 1 per micro-batch
    global_batch: int

    def validate(self):
        assert sum(self.micro_batch_sizes) * self.num_micro_batches == self.global_batch
        s = sum(self.grad_weights)
        assert abs(s - 1.0) < 1e-9, s


def plan_dataflow_view(view, new_dp: int = None) -> DataflowPlan:
    """View-level dataflow resize: the surviving DP width defaults to the
    narrowest stage of the shared ``ClusterView`` (one reduction — callers
    stop recounting rank membership)."""
    if new_dp is None:
        new_dp = int(view.stage_width().min())
    return plan_dataflow(view.global_batch, view.num_micro, new_dp)


def plan_dataflow(global_batch: int, num_micro_batches: int,
                  surviving_dp: int) -> DataflowPlan:
    """Split each micro-batch's global slice among surviving DP ranks.

    If the per-micro-batch slice (global_batch / num_micro) does not divide
    evenly by D', sizes differ by at most 1 (handled by per-rank grad
    weights, keeping the global gradient exact).
    """
    assert global_batch % num_micro_batches == 0
    per_micro = global_batch // num_micro_batches
    base = per_micro // surviving_dp
    rem = per_micro % surviving_dp
    sizes = tuple(base + (1 if r < rem else 0) for r in range(surviving_dp))
    weights = tuple(s / per_micro for s in sizes)
    plan = DataflowPlan(sizes, num_micro_batches, weights, global_batch)
    plan.validate()
    return plan
