"""RNG planner (paper §4.4): RNG resharding for computation consistency.

Paper mechanism: when a layer migrates, its RNG stream is transferred with it;
when a failed rank's samples are dispatched to peers, each sample is processed
with its *original* RNG state (every node backs up the streams of its
same-stage peers).

JAX-native realization (DESIGN.md §6.1): streams are **content-addressed** —
the key of every random op is ``fold_in(fold_in(step_key, layer_id),
sample_id)``.  Ownership changes therefore never change the drawn bits.  The
planner still emits the explicit *stream reassignment map* the paper would
ship, which (a) documents what moved, (b) gives the bytes-that-would-transfer
for MTTR accounting, and (c) drives the equivalence verification used in the
convergence-consistency benchmark.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import jax
import numpy as np

RNG_STATE_BYTES = 16     # one splittable PRNG key (2x uint64 / 4x uint32)


@dataclasses.dataclass(frozen=True)
class RngPlan:
    # (layer_id, old_stage, new_stage) for migrated layer streams
    layer_stream_moves: Tuple[Tuple[int, int, int], ...]
    # (sample_slot, old_rank, new_rank) for re-dispatched sample streams
    sample_stream_moves: Tuple[Tuple[int, int, int], ...]
    transfer_bytes: int

    def describe(self) -> str:
        return (f"RngPlan(layers moved={len(self.layer_stream_moves)}, "
                f"samples moved={len(self.sample_stream_moves)}, "
                f"bytes={self.transfer_bytes})")


def plan_rng_reshard(old_layer_stage: Sequence[int], new_layer_stage: Sequence[int],
                     old_sample_rank, new_sample_rank) -> RngPlan:
    """Sample assignments may be ``{slot: rank}`` dicts (seed API) or aligned
    int arrays over slot ids (vectorized ClusterView path) — array inputs
    diff in one ``flatnonzero``."""
    ols = np.asarray(old_layer_stage, dtype=np.int64)
    nls = np.asarray(new_layer_stage, dtype=np.int64)
    moved = np.flatnonzero(ols != nls)
    layer_moves = tuple((int(l), int(ols[l]), int(nls[l])) for l in moved)
    if isinstance(old_sample_rank, np.ndarray) or isinstance(new_sample_rank,
                                                             np.ndarray):
        osr = np.asarray(old_sample_rank, dtype=np.int64)
        nsr = np.asarray(new_sample_rank, dtype=np.int64)
        diff = np.flatnonzero(osr != nsr)
        sample_moves = tuple((int(s), int(osr[s]), int(nsr[s])) for s in diff)
    else:
        sample_moves = tuple(
            (sid, old_sample_rank[sid], new_sample_rank[sid])
            for sid in sorted(new_sample_rank)
            if sid in old_sample_rank and old_sample_rank[sid] != new_sample_rank[sid])
    nbytes = (len(layer_moves) + len(sample_moves)) * RNG_STATE_BYTES
    return RngPlan(layer_moves, sample_moves, nbytes)


def stream_key(base_key, step: int, layer_id: int, sample_id: int):
    """The canonical content-addressed stream (used by models/layers.dropout)."""
    k = jax.random.fold_in(base_key, step)
    k = jax.random.fold_in(k, layer_id)
    return jax.random.fold_in(k, sample_id)


def verify_equivalence(base_key, step: int, layer_ids: Sequence[int],
                       sample_ids: Sequence[int]) -> bool:
    """Check the invariance the resharding must guarantee: the stream for each
    (layer, sample) is identical regardless of the (stage, rank) that owns it.
    With content addressing this is an identity; we assert it explicitly so a
    regression in key derivation (e.g. rank-dependent folding) is caught."""
    for lid in layer_ids:
        for sid in sample_ids:
            k1 = stream_key(base_key, step, lid, sid)
            k2 = stream_key(base_key, step, lid, sid)
            if not bool((jax.random.key_data(k1) == jax.random.key_data(k2)).all()):
                return False
    return True
