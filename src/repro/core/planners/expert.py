"""Expert-parallel (EP) elasticity planner — beyond-paper extension.

The paper's §7.7 MoE case study treats the MoE model through the generic
DP/PP machinery; §7.8 names adapting to expert-parallel systems as future
work.  This planner closes that gap for EP-sharded MoE layers:

* experts are state units (weights + optimizer shards) placed on the EP
  group's workers;
* on a failure, the dead worker's experts are recovered (ring snapshot /
  surviving replica) and re-placed across survivors to minimize the maximum
  *routed load* per worker (LPT greedy on observed router statistics — the
  same minimax shape as the Graph planner, over a different resource);
* on scale-out the placement rebalances back.

Transfer accounting mirrors core/zero.py: each move is (expert, src, dst,
bytes); disjoint pairs ship in parallel.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class ExpertMove:
    expert: int
    src: int                 # worker holding a live copy (or snapshot holder)
    dst: int
    nbytes: int
    from_snapshot: bool


@dataclasses.dataclass
class ExpertPlan:
    placement: Dict[int, int]          # expert -> worker
    moves: List[ExpertMove]
    max_load: float                    # minimax objective value
    est_seconds: float

    def loads(self, expert_load: Sequence[float], workers: Sequence[int]
              ) -> Dict[int, float]:
        out = {w: 0.0 for w in workers}
        for e, w in self.placement.items():
            out[w] += expert_load[e]
        return out


def lpt_placement(expert_load: Sequence[float], workers: Sequence[int],
                  pinned: Optional[Dict[int, int]] = None) -> Dict[int, int]:
    """Longest-processing-time greedy: heaviest expert to lightest worker.
    `pinned` experts keep their worker (avoid moving what survived)."""
    pinned = pinned or {}
    loads = {w: 0.0 for w in workers}
    placement: Dict[int, int] = {}
    for e, w in pinned.items():
        placement[e] = w
        loads[w] += expert_load[e]
    order = sorted((e for e in range(len(expert_load)) if e not in pinned),
                   key=lambda e: -expert_load[e])
    for e in order:
        w = min(loads, key=lambda k: (loads[k], k))
        placement[e] = w
        loads[w] += expert_load[e]
    return placement


def brute_force_placement(expert_load: Sequence[float],
                          workers: Sequence[int]) -> float:
    """Optimal minimax load (small instances; property-test oracle)."""
    best = float("inf")
    E = len(expert_load)
    for assign in itertools.product(workers, repeat=E):
        loads = {w: 0.0 for w in workers}
        for e, w in enumerate(assign):
            loads[w] += expert_load[e]
        best = min(best, max(loads.values()))
    return best


def plan_expert_reshard(expert_load: Sequence[float],
                        old_placement: Dict[int, int],
                        surviving: Sequence[int],
                        expert_bytes: int,
                        snapshot_holder: Optional[Dict[int, int]] = None,
                        link_bw: float = 25e9,
                        rebalance_survivors: bool = False) -> ExpertPlan:
    """Re-place experts after the EP group shrinks to `surviving`.

    Experts whose worker survived stay pinned (no gratuitous movement —
    ElasWave's minimal-perturbation principle) unless `rebalance_survivors`.
    Orphaned experts are fetched from their snapshot holder (ring scheme) or
    any survivor holding a replica, and placed by LPT.
    """
    surviving = list(surviving)
    snapshot_holder = snapshot_holder or {}
    pinned = {e: w for e, w in old_placement.items()
              if w in surviving and not rebalance_survivors}
    placement = lpt_placement(expert_load, surviving, pinned)
    moves: List[ExpertMove] = []
    for e, w in placement.items():
        old_w = old_placement.get(e)
        if old_w == w:
            continue
        if old_w in surviving:
            src, snap = old_w, False
        else:
            src = snapshot_holder.get(e, surviving[0])
            snap = True
        moves.append(ExpertMove(e, src, w, expert_bytes, snap))
    loads = {w: 0.0 for w in surviving}
    for e, w in placement.items():
        loads[w] += expert_load[e]
    # disjoint endpoint pairs in parallel -> max per-endpoint bytes
    ep_bytes: Dict[int, int] = {}
    for m in moves:
        ep_bytes[m.src] = ep_bytes.get(m.src, 0) + m.nbytes
        ep_bytes[m.dst] = ep_bytes.get(m.dst, 0) + m.nbytes
    est = max(ep_bytes.values()) / link_bw if ep_bytes else 0.0
    return ExpertPlan(placement, moves, max(loads.values()), est)
