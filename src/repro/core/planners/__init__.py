"""The four planning axes of the Schedule Engine (paper \u00a74): dataflow
resizing, minimax graph repartition, DVFS top-up, RNG resharding \u2014 plus the
MoE expert-placement extension."""
from .dataflow import DataflowPlan, plan_dataflow
from .graph import GraphPlan, minimax_layer_partition, brute_force_partition
from .dvfs import DvfsPlan, plan_dvfs, bisect_min_feasible
from .rng import RngPlan, plan_rng_reshard
from .expert import ExpertPlan, plan_expert_reshard, lpt_placement
