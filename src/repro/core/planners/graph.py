"""Graph planner (paper §4.2, Alg. 1): minimax layer partition via DP.

State f[p, l] = optimal worst-stage mini-step time partitioning layers [1..l]
over stages [1..p], subject to per-stage memory caps.  O(P L^2) with O(1)
segment cost queries (precomputed prefix sums in cost_model.SegmentCosts).

`brute_force_partition` is the oracle for property tests.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

INF = float("inf")


@dataclasses.dataclass(frozen=True)
class GraphPlan:
    boundaries: Tuple[int, ...]       # right boundaries b_1..b_{P-1} (1-based)
    stage_ranges: Tuple[Tuple[int, int], ...]  # 0-based inclusive [a, b] per stage
    worst_mini_step: float
    feasible: bool

    @property
    def layers_per_stage(self) -> Tuple[int, ...]:
        return tuple(b - a + 1 for a, b in self.stage_ranges)


def minimax_layer_partition(
        L: int, P: int,
        t: Callable[[int, int, int], float],     # t(stage, a, b) 0-based incl.
        mem: Callable[[int, int, int], float],   # mem(stage, a, b)
        caps: Sequence[float]) -> GraphPlan:
    """Alg. 1. Returns infeasible plan if no memory-feasible partition exists."""
    assert P >= 1 and L >= P
    f = np.full((P + 1, L + 1), INF)
    kstar = np.full((P + 1, L + 1), -1, dtype=np.int64)
    # base: stage 1 takes [1..l]
    for l in range(1, L + 1):
        if mem(0, 0, l - 1) <= caps[0]:
            f[1, l] = t(0, 0, l - 1)
    # transition
    for p in range(2, P + 1):
        for l in range(p, L + 1):
            best, bestk = INF, -1
            # prune: t_p([k+1..l]) decreases as k grows; f[p-1,k] increases.
            for k in range(p - 1, l):
                if f[p - 1, k] == INF:
                    continue
                if mem(p - 1, k, l - 1) > caps[p - 1]:
                    continue
                cand = max(f[p - 1, k], t(p - 1, k, l - 1))
                if cand < best:
                    best, bestk = cand, k
                elif f[p - 1, k] >= best:
                    # f is nondecreasing in k -> no better k beyond this point
                    break
            f[p, l], kstar[p, l] = best, bestk
    if f[P, L] == INF:
        return GraphPlan((), (), INF, feasible=False)
    # backtrack
    bounds = [0] * (P + 1)
    bounds[P] = L
    for p in range(P, 1, -1):
        bounds[p - 1] = int(kstar[p, bounds[p]])
    ranges = tuple((bounds[p - 1], bounds[p] - 1) for p in range(1, P + 1))
    return GraphPlan(tuple(bounds[1:P]), ranges, float(f[P, L]), feasible=True)


def plan_graph(seg, view, hw=None) -> GraphPlan:
    """View-level Alg. 1: derive per-stage widths / micro-batch sizes /
    straggler factors from a shared :class:`core.clusterview.ClusterView`
    (one array reduction each) and run the minimax DP.  Callers stop
    re-deriving rank membership per planner."""
    from ..cost_model import mini_step_time
    hw = hw or seg.hw
    width = view.stage_width()
    if int(width.min()) == 0:
        return GraphPlan((), (), INF, feasible=False)
    per_micro = view.global_batch // view.num_micro
    mbs_stage = np.ceil(per_micro / width).astype(np.int64)
    slow_stage = view.stage_slow()
    P = view.pp

    def t(p, a, b):
        return mini_step_time(seg, a, b, int(mbs_stage[p]), hw=hw) \
            * slow_stage[p]

    def mem(p, a, b):
        return seg.seg_mem(a, b, int(mbs_stage[p]),
                           inflight=min(P, view.num_micro),
                           dp_size=int(width[p]))

    return minimax_layer_partition(seg.cfg.num_layers, P, t, mem,
                                   [view.mem_cap] * P)


def brute_force_partition(L: int, P: int, t, mem, caps) -> GraphPlan:
    """Exhaustive oracle (small L, P only)."""
    best: Optional[GraphPlan] = None
    for cuts in itertools.combinations(range(1, L), P - 1):
        bounds = (0,) + cuts + (L,)
        ranges = tuple((bounds[i], bounds[i + 1] - 1) for i in range(P))
        if any(mem(i, a, b) > caps[i] for i, (a, b) in enumerate(ranges)):
            continue
        worst = max(t(i, a, b) for i, (a, b) in enumerate(ranges))
        if best is None or worst < best.worst_mini_step:
            best = GraphPlan(tuple(cuts), ranges, worst, feasible=True)
    return best or GraphPlan((), (), INF, feasible=False)


def mem_check_fails(L, P, t, mem, caps) -> bool:
    return not minimax_layer_partition(L, P, t, mem, caps).feasible
