"""Rank-vectorized ClusterView: the analytic plane's single currency.

The paper's premise is elasticity at 10^5–10^6 accelerators.  The seed
analytic path walked Python dicts and per-rank loops, which caps
``AnalyticScenarioRunner`` far below paper scale.  This module makes the
cluster state a first-class *array-of-ranks* object, mirroring the
``IntervalTable`` memoization idiom from the flat-state backbone
(``core.statespace``): precompute coordinate tables once, express every
state change and every reduction as a numpy array op.

* :class:`ClusterView` — one flat rank-major buffer per observable
  (``rank_alive``/``rank_freq``/``rank_slow``/``rank_domain``), with the
  classic ``[dp, pp]`` 2-D arrays exposed as **zero-copy reshape views** of
  the same buffers, so existing per-cell code (``view.alive[d, p] = False``)
  and vectorized code (``view.rank_alive[ranks] = False``) mutate identical
  state.  Stage/replica reductions (``stage_width``, ``stage_slow``, ...)
  are single masked-array reductions instead of Python ``for d in range(dp)``
  loops.  This is the single input/output type of the analytic stack:
  policies consume it, planners consume it, the scenario runner mutates it.
* :class:`FailureDomainMap` — correlated rack/pod failure domains: a block
  of ``domain_size`` consecutive ranks shares a domain id, so at-scale
  scenarios sample *whole domains*, not i.i.d. ranks.
* :class:`GroupDelta` — the declarative membership delta consumed by
  ``DynamicCommunicator.apply(delta, policy)``.

Rank convention (shared with ``scenarios.spec`` and the runner):
``rank = d * pp + p`` — DP-major, one rank per (replica, stage) worker cell
(a worker is a TP group; TP only materializes in the communicator's group
table).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


@functools.lru_cache(maxsize=128)
def rank_coords(dp: int, pp: int) -> Tuple[np.ndarray, np.ndarray]:
    """Memoized coordinate tables for the DP-major rank layout:
    ``rank_dp[r], rank_stage[r]`` with ``r = d * pp + p``."""
    r = np.arange(dp * pp, dtype=np.int64)
    out = (r // pp, r % pp)
    for a in out:
        a.setflags(write=False)
    return out


@dataclasses.dataclass(frozen=True)
class GroupDelta:
    """A communicator membership delta: ranks leaving every group they are
    in, plus explicit ``(group, rank)`` additions.  The single argument of
    ``DynamicCommunicator.apply``/``price``."""
    remove: Tuple[int, ...] = ()
    add: Tuple[Tuple[str, int], ...] = ()

    @staticmethod
    def shrink(ranks: Sequence[int]) -> "GroupDelta":
        return GroupDelta(remove=tuple(int(r) for r in ranks))

    @staticmethod
    def grow(adds: Sequence[Tuple[str, int]]) -> "GroupDelta":
        return GroupDelta(add=tuple((g, int(r)) for g, r in adds))


@dataclasses.dataclass(frozen=True)
class FailureDomainMap:
    """Correlated failure domains: ``domain_size`` consecutive ranks (a rack
    or pod) share one domain id; sampling failures per *domain* produces the
    correlated bursts that only exist at paper scale."""
    n_ranks: int
    domain_size: int

    def __post_init__(self):
        assert self.n_ranks >= 1 and self.domain_size >= 1

    @property
    def n_domains(self) -> int:
        return -(-self.n_ranks // self.domain_size)

    def domain_of(self, ranks) -> np.ndarray:
        return np.asarray(ranks, dtype=np.int64) // self.domain_size

    def ranks_of(self, domains) -> np.ndarray:
        """All ranks of the given domain ids (sorted, deduplicated,
        clipped to the cluster size) — one broadcasted arange, no loops."""
        d = np.unique(np.asarray(domains, dtype=np.int64))
        r = (d[:, None] * self.domain_size
             + np.arange(self.domain_size, dtype=np.int64)[None, :]).ravel()
        return r[r < self.n_ranks]

    def sample(self, n_domains: int, seed: int = 0) -> np.ndarray:
        """Deterministically sample ``n_domains`` distinct domain ids."""
        rng = np.random.default_rng(seed)
        n = min(n_domains, self.n_domains)
        return np.sort(rng.choice(self.n_domains, size=n, replace=False))


class ClusterView:
    """What the Agent reports to the Core, as arrays of ranks.

    Drop-in constructor-compatible with the legacy dataclass (2-D
    ``[dp, pp]`` ``alive``/``freq``/``slow`` arguments are accepted and
    raveled); ``view.alive`` etc. remain ``[dp, pp]`` arrays — now zero-copy
    views of the flat rank-major buffers ``view.rank_alive`` etc.
    """

    __slots__ = ("dp", "pp", "global_batch", "num_micro", "seq",
                 "layer_assignment", "mem_cap", "rank_alive", "rank_freq",
                 "rank_slow", "rank_domain", "alive", "freq", "slow",
                 "domains")

    def __init__(self, dp: int, pp: int, global_batch: int, num_micro: int,
                 seq: int, layer_assignment: Sequence[Tuple[int, int]],
                 alive: Optional[np.ndarray] = None,
                 freq: Optional[np.ndarray] = None,
                 slow: Optional[np.ndarray] = None,
                 mem_cap: float = float("inf"),
                 domain: Optional[np.ndarray] = None,
                 domains: Optional[FailureDomainMap] = None):
        self.dp, self.pp = int(dp), int(pp)
        self.global_batch = int(global_batch)
        self.num_micro = int(num_micro)
        self.seq = int(seq)
        self.layer_assignment = list(layer_assignment)
        self.mem_cap = mem_cap
        n = self.dp * self.pp
        self.rank_alive = self._buf(alive, n, np.bool_, True)
        self.rank_freq = self._buf(freq, n, np.float64, 1.0)
        self.rank_slow = self._buf(slow, n, np.float64, 1.0)
        self.domains = domains
        if domain is None and domains is not None:
            domain = domains.domain_of(np.arange(n))
        self.rank_domain = self._buf(domain, n, np.int64, -1)
        # zero-copy 2-D aliases of the flat buffers
        self.alive = self.rank_alive.reshape(self.dp, self.pp)
        self.freq = self.rank_freq.reshape(self.dp, self.pp)
        self.slow = self.rank_slow.reshape(self.dp, self.pp)

    @staticmethod
    def _buf(arr, n: int, dtype, fill) -> np.ndarray:
        if arr is None:
            return np.full(n, fill, dtype=dtype)
        # aliases the caller's buffer when it is already contiguous with the
        # right dtype (same semantics as the legacy dataclass, which stored
        # the caller's [dp, pp] arrays directly)
        return np.ascontiguousarray(arr, dtype=dtype).reshape(n)

    # -- identity -----------------------------------------------------------
    @property
    def n_ranks(self) -> int:
        return self.dp * self.pp

    def rank_of(self, d, p) -> np.ndarray:
        return np.asarray(d, dtype=np.int64) * self.pp + np.asarray(p)

    @property
    def rank_dp(self) -> np.ndarray:
        return rank_coords(self.dp, self.pp)[0]

    @property
    def rank_stage(self) -> np.ndarray:
        return rank_coords(self.dp, self.pp)[1]

    def copy(self) -> "ClusterView":
        return ClusterView(self.dp, self.pp, self.global_batch,
                           self.num_micro, self.seq,
                           list(self.layer_assignment),
                           alive=self.rank_alive.copy(),
                           freq=self.rank_freq.copy(),
                           slow=self.rank_slow.copy(),
                           mem_cap=self.mem_cap,
                           domain=self.rank_domain.copy(),
                           domains=self.domains)

    # -- vectorized reductions (replace per-rank Python loops) --------------
    def stage_width(self) -> np.ndarray:
        """Surviving DP width per stage: ``[pp]`` int64."""
        return self.alive.sum(axis=0, dtype=np.int64)

    def replica_width(self) -> np.ndarray:
        """Surviving stage count per DP replica: ``[dp]`` int64."""
        return self.alive.sum(axis=1, dtype=np.int64)

    def stage_slow(self) -> np.ndarray:
        """Worst straggler factor among alive ranks per stage (1.0 where the
        stage has no survivors)."""
        return np.where(self.alive, self.slow, 1.0).max(axis=0, initial=1.0)

    def stage_freq(self) -> np.ndarray:
        """Best frequency among alive ranks per stage (1.0 fallback)."""
        best = np.where(self.alive, self.freq, 0.0).max(axis=0, initial=0.0)
        return np.where(self.alive.any(axis=0), best, 1.0)

    def alive_count(self) -> int:
        return int(self.rank_alive.sum())

    def dead_ranks(self) -> np.ndarray:
        return np.flatnonzero(~self.rank_alive)

    # -- vectorized event application (whole bursts as one array op) --------
    def apply_elastic(self, ev) -> np.ndarray:
        """Mutate the view for one (possibly multi-rank burst) event; returns
        the affected rank array.  Replaces the runner's per-rank dict
        surgery."""
        from .events import EventKind          # local: avoid import cycle
        ranks = np.asarray(ev.ranks, dtype=np.int64)
        if ev.kind == EventKind.FAIL_SLOW:
            self.rank_slow[ranks] = np.maximum(self.rank_slow[ranks],
                                               ev.slow_factor)
        elif ev.kind == EventKind.DVFS_SET:
            self.rank_freq[ranks] = ev.freq
        elif ev.is_grow:
            self.rank_alive[ranks] = True
        elif ev.is_shrink:
            self.rank_alive[ranks] = False
        return ranks

    def describe(self) -> Dict:
        return {"dp": self.dp, "pp": self.pp, "n_ranks": self.n_ranks,
                "alive": int(self.rank_alive.sum()),
                "global_batch": self.global_batch,
                "num_micro": self.num_micro, "seq": self.seq,
                "n_domains": (self.domains.n_domains
                              if self.domains else None)}
