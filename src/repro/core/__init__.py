"""ElasWave core: multi-dimensional elastic scheduling + data plane."""
from .events import ElasticEvent, EventKind
from .cost_model import HardwareSpec, SegmentCosts, mini_step_time
from .engine import ScheduleEngine, RecoveryPlan
from .cluster import VirtualCluster
from .communicator import DynamicCommunicator, build_hybrid_groups
from . import zero, migration, pipeline, policies
