"""Seed dict/set DynamicCommunicator, preserved as the equivalence oracle.

``core.communicator.DynamicCommunicator`` is now rank-vectorized (int64
link-code arrays + memoized group index tables).  This module keeps the seed
implementation — Python dicts of member lists, a ``set`` of ``frozenset``
links — so property tests can enforce, at dp×pp×tp ≤ 64 ranks, that the
vectorized ``apply(delta, policy)`` produces byte-identical ``OpStats``,
group tables, link sets, ``affected_groups`` ordering and MTTR accounting
(mirroring the PR 2 fast-path/``core.legacy`` pattern).

One intentional delta from the seed: affected groups are processed in
``sorted(...)`` name order instead of Python ``set`` iteration order, in both
implementations, so the per-group accumulation order is well defined.  For
ring groups that share at most one rank (every hybrid dp/pp/tp layout) the
order never changes any count; making it deterministic lets the oracle
compare accumulators exactly.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Set, Tuple

from .clusterview import GroupDelta
from .communicator import (BOOTSTRAP_PER_RANK_S, EDIT_CONST_S, LINK_SETUP_S,
                           PARTIAL_PER_RANK_S, Link, OpStats, ring_links)


class LegacyDynamicCommunicator:
    """The seed implementation, verbatim modulo sorted affected-group order,
    with the new ``apply``/``price`` entrypoints layered on top."""

    def __init__(self, groups: Dict[str, List[int]]):
        self.groups: Dict[str, List[int]] = {k: list(v) for k, v in groups.items()}
        self.links: Set[Link] = set()
        for g in self.groups.values():
            self.links |= ring_links(g)
        self.history: List[OpStats] = []

    # ---- helpers ----
    def clone(self) -> "LegacyDynamicCommunicator":
        c = LegacyDynamicCommunicator(self.groups)
        c.links = set(self.links)
        return c

    def _group_links(self) -> Set[Link]:
        s: Set[Link] = set()
        for g in self.groups.values():
            s |= ring_links(g)
        return s

    def affected_groups(self, ranks: Sequence[int]) -> List[str]:
        rs = set(ranks)
        return [k for k, g in self.groups.items() if rs & set(g)]

    def all_ranks(self) -> Set[int]:
        out: Set[int] = set()
        for g in self.groups.values():
            out |= set(g)
        return out

    # ---- unified entrypoints (delegating to the seed recovery modes) ----
    def apply(self, delta: GroupDelta, policy: str = "edit") -> OpStats:
        if policy == "edit":
            return self.edit(remove=delta.remove, add=delta.add)
        if policy == "partial_rebuild":
            return self.partial_rebuild(remove=delta.remove, add=delta.add)
        if policy == "full_rebuild":
            rem = set(delta.remove)
            new_groups = {k: [r for r in v if r not in rem]
                          for k, v in self.groups.items()}
            for g, r in delta.add:
                new_groups.setdefault(g, []).append(r)
            return self.full_rebuild(new_groups)
        raise ValueError(f"unknown recovery policy {policy!r}")

    def price(self, delta: GroupDelta, policy: str = "edit") -> OpStats:
        """Price without committing (the clone-based seed idiom)."""
        return self.clone().apply(delta, policy)

    # ---- recovery modes (seed implementations) ----
    def full_rebuild(self, new_groups: Dict[str, List[int]]) -> OpStats:
        old_links = set(self.links)
        self.groups = {k: list(v) for k, v in new_groups.items()}
        new_links = self._group_links()
        n_ranks = len(self.all_ranks())
        secs = (BOOTSTRAP_PER_RANK_S * n_ranks + LINK_SETUP_S * len(new_links))
        self.links = new_links
        st = OpStats("full_rebuild", len(new_links), 0, len(old_links), n_ranks, secs)
        self.history.append(st)
        return st

    def partial_rebuild(self, remove: Sequence[int] = (),
                        add: Sequence[Tuple[str, int]] = ()) -> OpStats:
        affected = set(self.affected_groups(remove)) | {g for g, _ in add}
        created = destroyed = 0
        touched: Set[int] = set()
        for name in sorted(affected):
            old = ring_links(self.groups[name])
            self.groups[name] = [r for r in self.groups[name] if r not in set(remove)]
            for g, r in add:
                if g == name:
                    self.groups[name].append(r)
            new = ring_links(self.groups[name])
            # partial rebuild: tears down & re-creates ALL links of the group
            destroyed += len(old)
            created += len(new)
            touched |= set(self.groups[name])
            self.links -= old
            self.links |= new
        secs = PARTIAL_PER_RANK_S * len(touched) + LINK_SETUP_S * created
        st = OpStats("partial_rebuild", created, 0, destroyed, len(touched), secs)
        self.history.append(st)
        return st

    def edit(self, remove: Sequence[int] = (),
             add: Sequence[Tuple[str, int]] = ()) -> OpStats:
        """ElasWave in-place edit: reuse intact links, create only missing."""
        affected = set(self.affected_groups(remove)) | {g for g, _ in add}
        created = destroyed = reused = 0
        touched: Set[int] = set()
        for name in sorted(affected):
            old = ring_links(self.groups[name])
            self.groups[name] = [r for r in self.groups[name] if r not in set(remove)]
            for g, r in add:
                if g == name:
                    self.groups[name].append(r)
            new = ring_links(self.groups[name])
            newly = new - self.links          # only links not yet established
            dead = old - new
            created += len(newly)
            reused += len(new & self.links)
            destroyed += len(dead)
            touched |= set(self.groups[name])
            self.links -= dead
            self.links |= newly
        secs = EDIT_CONST_S + LINK_SETUP_S * created
        st = OpStats("edit", created, reused, destroyed, len(touched), secs)
        self.history.append(st)
        return st
