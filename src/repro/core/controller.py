"""Proactive elasticity controller (ROADMAP "predictive capacity controller").

Wraps the detection :class:`~repro.core.agent.Agent` with the cluster-level
policy decisions the agent itself is too local to make — CLUES-style
lifecycle management (cf. ``lifecycle()`` / pending-task / stuck-node
recovery in the indigo orchestrator):

* **Resurrection**: a heartbeat from a rank the controller itself evicted
  (false positive — the "dead" worker was merely partitioned) turns into a
  ``SCALE_OUT`` rejoin event, so the executor re-admits it through the
  normal grow path and parameter/RNG/dataflow consistency is preserved by
  construction.
* **Stage-width veto**: the controller refuses to confirm-evict the last
  registered rank of a pipeline stage — losing it would make the model
  un-runnable, so the rank stays suspect until a replacement exists.  The
  veto is a backstop against detection false positives, not a liveness fix:
  a genuinely dead last-rank still stalls the stage.
* **Grant tracking**: ``grant()`` records a scheduler-promised scale-out;
  if the rank never joins within ``grant_timeout`` observation rounds it is
  moved to the stuck list (``stuck_grants()``) instead of being waited on
  forever — granted-but-never-joined capacity is recovered, not leaked.

The controller is deterministic and clockless: "time" is the count of
``observe()`` calls, so replays are exact.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Set

from .agent import Agent, HealthState, Probe
from .events import ElasticEvent, EventKind


@dataclasses.dataclass
class Grant:
    """A scheduler-promised rank that has not joined yet."""
    rank: int
    granted_at: int          # observe-round when the grant was recorded
    detail: str = ""


class ElasticController:
    def __init__(self, agent: Agent, grant_timeout: int = 8,
                 resurrection_window: int = 32):
        self.agent = agent
        self.grant_timeout = grant_timeout
        self.resurrection_window = resurrection_window
        self.rounds = 0                      # observe-call clock
        self._evicted_at: Dict[int, int] = {}   # rank -> round we evicted it
        self._pending_grants: Dict[int, Grant] = {}
        self._stuck: List[Grant] = []

    # -- lifecycle ---------------------------------------------------------

    def grant(self, rank: int, detail: str = ""):
        """Record a scheduler grant: ``rank`` is expected to join soon."""
        self._pending_grants[rank] = Grant(rank, self.rounds, detail)

    def note_join(self, rank: int):
        """The granted rank actually joined (executor applied SCALE_OUT)."""
        self._pending_grants.pop(rank, None)
        self._evicted_at.pop(rank, None)

    def stuck_grants(self) -> List[Grant]:
        """Grants that timed out without the rank ever joining."""
        return list(self._stuck)

    def pending_grants(self) -> List[Grant]:
        return list(self._pending_grants.values())

    # -- observation -------------------------------------------------------

    def observe(self, probes: List[Probe]) -> List[ElasticEvent]:
        """Agent detection + controller policy.

        Returns the agent's events with the stage-width veto applied, plus
        resurrection ``SCALE_OUT`` events for falsely-evicted ranks that
        are heartbeating again.
        """
        self.rounds += 1
        step = probes[0].step if probes else 0

        raw = self.agent.observe(probes)
        events: List[ElasticEvent] = []
        for ev in raw:
            if ev.kind == EventKind.FAIL_STOP and self._veto_eviction(ev):
                continue
            events.append(ev)
            if ev.kind == EventKind.FAIL_STOP:
                for r in ev.ranks:
                    self._evicted_at[r] = self.rounds

        events.extend(self._detect_resurrections(probes, step))
        self._expire_grants()
        return events

    def _veto_eviction(self, ev: ElasticEvent) -> bool:
        """Refuse to evict the last registered rank of any stage.  The agent
        keeps the rank CONFIRMED internally but we do not forward the event;
        the rank is rolled back to SUSPECT so a later heartbeat can clear it
        and a later miss (once the stage has peers again) re-confirms."""
        for r in ev.ranks:
            stage = self.agent.stage_of.get(r, 0)
            peers = [q for q in self.agent.ranks
                     if q != r and self.agent.stage_of.get(q, 0) == stage]
            if not peers:
                h = self.agent.health.get(r)
                if h is not None:
                    h.state = HealthState.SUSPECT
                self.agent.reported_dead.discard(r)
                return True
        return False

    def _detect_resurrections(self, probes: List[Probe],
                              step: int) -> List[ElasticEvent]:
        """A heartbeat from a rank we evicted recently (and that has not
        been re-registered) is a detection false positive: the worker is
        alive behind a healed partition.  Emit a SCALE_OUT rejoin so the
        executor re-admits it through the normal grow path."""
        events: List[ElasticEvent] = []
        beating: Set[int] = {p.rank for p in probes if p.heartbeat}
        for r in sorted(beating & set(self._evicted_at)):
            if r in self.agent.times:        # already re-registered
                self._evicted_at.pop(r, None)
                continue
            if self.rounds - self._evicted_at[r] > self.resurrection_window:
                self._evicted_at.pop(r, None)
                continue
            self._evicted_at.pop(r, None)
            events.append(ElasticEvent(
                EventKind.SCALE_OUT, step, (r,),
                detail="resurrection: heartbeat after false-positive eviction"))
        return events

    def _expire_grants(self):
        expired = [g for g in self._pending_grants.values()
                   if self.rounds - g.granted_at >= self.grant_timeout]
        for g in expired:
            del self._pending_grants[g.rank]
            self._stuck.append(g)

    # -- passthroughs used by executors ------------------------------------

    def max_confirm_misses(self) -> int:
        return self.agent.max_confirm_misses()
