"""1F1B pipeline discrete-event simulator.

Drives all throughput benchmarks (Figs. 11, 12a, 14, 15a): given per-stage
per-micro-batch forward/backward times (from the Eq. 1 cost model x device
frequency x straggler factor), simulate the 1F1B schedule and report step
time, per-stage bubble, and peak in-flight activation counts (for the
ReCycle-OOM analysis).

Supports per-rank *extra* micro-batches (ReCycle rerouting: surviving ranks
of the failed stage absorb the failed rank's micro-batches) and per-rank
micro-batch-size multipliers (ElasWave dataflow resizing).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass
class StageTiming:
    fwd: float                      # per-micro-batch forward seconds
    bwd: float                      # per-micro-batch backward seconds
    num_micro: int                  # micro-batches this stage processes


@dataclasses.dataclass
class SimResult:
    step_time: float
    stage_busy: List[float]
    stage_bubble: List[float]
    peak_inflight: List[int]        # max concurrent stored activations / stage

    @property
    def pipeline_efficiency(self) -> float:
        total = self.step_time * len(self.stage_busy)
        return sum(self.stage_busy) / total if total else 0.0


def simulate_1f1b(stages: Sequence[StageTiming],
                  p2p: float = 0.0) -> SimResult:
    """Event-driven 1F1B.  All stages must process the same number of
    micro-batches (standard PP); per-rank load differences enter through
    fwd/bwd times (micro-batch resizing) — see simulate_dp_pp for the DP
    dimension."""
    P = len(stages)
    M = stages[0].num_micro
    assert all(s.num_micro == M for s in stages)
    warmup = [min(P - i, M) for i in range(P)]   # in-flight fwd before 1F1B

    # event-driven: track per-stage ready times and dependency times
    fwd_done = [[0.0] * M for _ in range(P)]
    bwd_done = [[0.0] * M for _ in range(P)]
    stage_free = [0.0] * P
    # schedule order per stage: warmup fwds, then alternate (1F1B), then cooldown
    order: List[List[Tuple[str, int]]] = []
    for i in range(P):
        w = warmup[i]
        seq: List[Tuple[str, int]] = [("f", m) for m in range(w)]
        nf, nb = w, 0
        while nb < M:
            if nb < M:
                seq.append(("b", nb)); nb += 1
            if nf < M:
                seq.append(("f", nf)); nf += 1
        order.append(seq)

    inflight = [0] * P
    peak = [0] * P
    ptr = [0] * P
    done = [False] * P
    # iterate until all stages drained; simple fixed-point loop over ready ops
    progressed = True
    while any(not d for d in done):
        progressed = False
        for i in range(P):
            while ptr[i] < len(order[i]):
                kind, m = order[i][ptr[i]]
                if kind == "f":
                    if i > 0 and fwd_done[i - 1][m] == 0.0:
                        break   # upstream forward not yet scheduled
                    dep = fwd_done[i - 1][m] + p2p if i > 0 else 0.0
                    start = max(stage_free[i], dep)
                    end = start + stages[i].fwd
                    fwd_done[i][m] = end
                    inflight[i] += 1
                    peak[i] = max(peak[i], inflight[i])
                else:
                    dep_self = fwd_done[i][m]
                    dep_next = bwd_done[i + 1][m] + p2p if i < P - 1 else fwd_done[i][m]
                    if i < P - 1 and bwd_done[i + 1][m] == 0.0:
                        break   # dependency not yet scheduled
                    start = max(stage_free[i], dep_self, dep_next)
                    end = start + stages[i].bwd
                    bwd_done[i][m] = end
                    inflight[i] -= 1
                stage_free[i] = end
                ptr[i] += 1
                progressed = True
            if ptr[i] == len(order[i]):
                done[i] = True
        if not progressed and not all(done):
            # shouldn't happen with a valid 1F1B order; avoid infinite loop
            raise RuntimeError("pipeline deadlock in simulation")

    step_time = max(max(r) for r in bwd_done)
    busy = [stages[i].num_micro * (stages[i].fwd + stages[i].bwd) for i in range(P)]
    bubble = [step_time - b for b in busy]
    return SimResult(step_time, busy, bubble, peak)


def simulate_interleaved_1f1b(stages: Sequence[StageTiming], v: int = 2,
                              p2p: float = 0.0) -> SimResult:
    """Interleaved 1F1B with `v` virtual stages per physical stage
    (Megatron-LM interleaving; the schedule family AdaPipe starts from).

    Each physical stage p hosts v model chunks; chunk j of stage p is virtual
    stage j*P + p.  We simulate the virtual pipeline of depth v*P where each
    virtual stage costs 1/v of the physical stage's per-micro time, then fold
    the per-virtual-stage busy/bubble back onto physical stages.  Warmup
    bubble shrinks by ~1/v at the cost of more P2P messages (modeled via the
    deeper virtual chain)."""
    P = len(stages)
    virt = []
    for j in range(v):
        for p in range(P):
            s = stages[p]
            virt.append(StageTiming(s.fwd / v, s.bwd / v, s.num_micro))
    r = simulate_1f1b(virt, p2p=p2p)
    busy = [0.0] * P
    peak = [0] * P
    for idx in range(v * P):
        p = idx % P
        busy[p] += r.stage_busy[idx]
        peak[p] += r.peak_inflight[idx]
    # Device-sharing bound: the virtual pipeline above lets chunks of the
    # same physical device overlap; a device must serialize its v chunks, so
    # step >= busy_p + fill/drain residual (P-1)(f_p + b_p)/v — for balanced
    # stages this recovers the Megatron interleaved bubble (P-1)/(vM).
    dev_bound = max(busy[p] + (P - 1) * (stages[p].fwd + stages[p].bwd) / v
                    + 2 * (P - 1) * p2p
                    for p in range(P))
    step = max(r.step_time, dev_bound)
    bubble = [step - b for b in busy]
    return SimResult(step, busy, bubble, peak)


def simulate_dp_pp(fwd: Sequence[Sequence[float]], bwd: Sequence[Sequence[float]],
                   num_micro: int, p2p: float = 0.0,
                   extra_micro: Optional[Dict[Tuple[int, int], int]] = None,
                   ) -> Tuple[float, List[SimResult]]:
    """fwd[d][p], bwd[d][p]: per-micro times for DP replica d, stage p.
    extra_micro[(d, p)]: additional micro-batches rerouted to that rank
    (ReCycle).  DP replicas run the same schedule; the step ends at the
    slowest replica (gradient all-reduce joins them), and within a replica a
    rank with extra micro-batches stretches its stage.
    Returns (step_time, per-replica SimResult)."""
    extra_micro = extra_micro or {}
    results = []
    for d in range(len(fwd)):
        stages = []
        for p in range(len(fwd[d])):
            extra = extra_micro.get((d, p), 0)
            scale = (num_micro + extra) / num_micro
            stages.append(StageTiming(fwd[d][p] * scale, bwd[d][p] * scale,
                                      num_micro))
        results.append(simulate_1f1b(stages, p2p=p2p))
    return max(r.step_time for r in results), results
