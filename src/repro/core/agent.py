"""ElasWave Agent (paper §3.2): per-worker health monitoring.

Co-located with each (virtual) worker; hooks heartbeat/step-time probes and
relays elastic events to the Core.  Fail-stop: missed heartbeats.  Fail-slow:
step-time z-score over a rolling window against the stage's peer median.
Scheduler signals (scale in/out) are injected directly.

Rank membership is DYNAMIC: the monitored set changes with the cluster.
``add_rank`` registers a worker granted by SCALE_OUT (or a rejoin — stale
dead/slow verdicts are cleared so a later failure of the same rank is
re-detected), ``remove_rank`` retires one that left.  Both the training
``VirtualCluster`` and the serving engine wire these from their apply paths;
probes for unregistered ranks are ignored.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from .events import ElasticEvent, EventKind


@dataclasses.dataclass
class Probe:
    step: int
    rank: int
    heartbeat: bool
    step_seconds: float
    mem_used: float = 0.0


class Agent:
    def __init__(self, num_ranks: int, window: int = 8,
                 slow_threshold: float = 1.3, miss_limit: int = 2):
        self.window = window
        self.slow_threshold = slow_threshold
        self.miss_limit = miss_limit
        self.misses: Dict[int, int] = {}
        self.times: Dict[int, Deque[float]] = {}
        self.reported_slow: set = set()
        self.reported_dead: set = set()
        for r in range(num_ranks):
            self.add_rank(r)

    @property
    def ranks(self) -> List[int]:
        """Currently monitored ranks (sorted)."""
        return sorted(self.times)

    @property
    def num_ranks(self) -> int:
        return len(self.times)

    def add_rank(self, rank: int):
        """Register a rank (SCALE_OUT / rejoin).  Health history restarts
        fresh and stale verdicts are cleared, so a rank that rejoins and
        later fails again is re-detected."""
        self.misses[rank] = 0
        self.times[rank] = deque(maxlen=self.window)
        self.reported_dead.discard(rank)
        self.reported_slow.discard(rank)

    def remove_rank(self, rank: int):
        """Retire a rank that left (recovered fail-stop / scale-in): it no
        longer accrues misses or participates in the fleet median."""
        self.misses.pop(rank, None)
        self.times.pop(rank, None)
        self.reported_dead.discard(rank)
        self.reported_slow.discard(rank)

    def observe(self, probes: List[Probe]) -> List[ElasticEvent]:
        events: List[ElasticEvent] = []
        step = probes[0].step if probes else 0
        seen = set()
        for p in probes:
            if p.rank not in self.times:      # unregistered: ignore
                continue
            seen.add(p.rank)
            if not p.heartbeat:
                self.misses[p.rank] += 1
            else:
                self.misses[p.rank] = 0
                self.times[p.rank].append(p.step_seconds)
        for r in self.ranks:
            if r not in seen:
                self.misses[r] += 1
            if self.misses[r] >= self.miss_limit and r not in self.reported_dead:
                self.reported_dead.add(r)
                events.append(ElasticEvent(EventKind.FAIL_STOP, step, (r,),
                                           detail=f"{self.misses[r]} missed heartbeats"))
        # fail-slow: compare each rank's median to the global median
        med_all = np.median([t for d in self.times.values() for t in d]) \
            if any(self.times.values()) else 0.0
        for r, d in self.times.items():
            if len(d) < self.window // 2 or r in self.reported_dead:
                continue
            m = np.median(d)
            if med_all > 0 and m > self.slow_threshold * med_all \
                    and r not in self.reported_slow:
                self.reported_slow.add(r)
                events.append(ElasticEvent(
                    EventKind.FAIL_SLOW, step, (r,), slow_factor=float(m / med_all),
                    detail=f"median {m:.3f}s vs fleet {med_all:.3f}s"))
        return events

    def clear_slow(self, rank: int):
        self.reported_slow.discard(rank)
