"""ElasWave Agent (paper §3.2): per-worker health monitoring.

Co-located with each (virtual) worker; hooks heartbeat/step-time probes and
relays elastic events to the Core.  Detection is *hardened* against the
imperfect-probe regimes the detection-chaos fuzzer injects:

* **Fail-stop** is a healthy → suspect → confirmed state machine, not a raw
  miss counter.  The first missed heartbeat only raises *suspicion*; the
  rank is confirmed dead (FAIL_STOP emitted) after ``confirm_needed``
  consecutive misses.  A heartbeat received while suspect is a **flap**: the
  rank returns to healthy, and its confirmation threshold doubles
  (``miss_limit * 2**min(flaps, backoff_cap)``) — exponential-backoff
  re-probing, so a link that blips repeatedly has to stay silent for longer
  and longer before it is evicted.  A fresh (never-flapped) rank confirms at
  exactly ``miss_limit`` misses, matching the reactive baseline.
* **Fail-slow** compares a rank's rolling step-time median against the
  median of its *stage peers* (other ranks in the same pipeline stage), not
  the global fleet — heterogeneous stages have legitimately different step
  times.  Stage topology is passed in by the executor (``stage_of``);
  without one, all ranks form a single peer group.
* **OOM early warning**: per-rank ``Probe.mem_used`` history is fitted with
  a linear trend; when the extrapolated usage crosses
  ``mem_threshold * mem_cap`` within ``mem_horizon`` observations, an
  advisory ``OOM_RISK`` event is emitted (once, re-armed when pressure
  recedes).

Probes within one ``observe`` call are aggregated per rank, which makes
detection *order-independent*: duplicated, reordered, or delayed copies of
the same heartbeat cannot change the verdict — any surviving heartbeat
counts as life.

Rank membership is DYNAMIC: the monitored set changes with the cluster.
``add_rank`` registers a worker granted by SCALE_OUT (or a rejoin — stale
dead/slow verdicts and flap history are cleared so a later failure of the
same rank is re-detected), ``remove_rank`` retires one that left.  Both the
training ``VirtualCluster`` and the serving engine wire these from their
apply paths; probes for unregistered ranks are ignored.
"""
from __future__ import annotations

import dataclasses
import enum
from collections import deque
from typing import Deque, Dict, List, Optional

import numpy as np

from .events import ElasticEvent, EventKind


@dataclasses.dataclass
class Probe:
    step: int
    rank: int
    heartbeat: bool
    step_seconds: float
    mem_used: float = 0.0


class HealthState(enum.Enum):
    HEALTHY = "healthy"
    SUSPECT = "suspect"      # ≥1 consecutive miss, below confirmation bar
    CONFIRMED = "confirmed"  # FAIL_STOP emitted


@dataclasses.dataclass
class RankHealth:
    state: HealthState = HealthState.HEALTHY
    consecutive_misses: int = 0
    flaps: int = 0           # heartbeats received while SUSPECT (lifetime)


class Agent:
    def __init__(self, num_ranks: int, window: int = 8,
                 slow_threshold: float = 1.3, miss_limit: int = 2,
                 backoff_cap: int = 3,
                 stage_of: Optional[Dict[int, int]] = None,
                 mem_cap: float = 1.0, mem_threshold: float = 0.9,
                 mem_horizon: int = 3):
        self.window = window
        self.slow_threshold = slow_threshold
        self.miss_limit = miss_limit
        self.backoff_cap = backoff_cap
        self.stage_of: Dict[int, int] = dict(stage_of) if stage_of else {}
        self.mem_cap = mem_cap
        self.mem_threshold = mem_threshold
        self.mem_horizon = mem_horizon
        self.health: Dict[int, RankHealth] = {}
        self.times: Dict[int, Deque[float]] = {}
        self.mem: Dict[int, Deque[float]] = {}
        self.reported_slow: set = set()
        self.reported_dead: set = set()
        self.reported_oom: set = set()
        for r in range(num_ranks):
            self.add_rank(r)

    @property
    def ranks(self) -> List[int]:
        """Currently monitored ranks (sorted)."""
        return sorted(self.times)

    @property
    def num_ranks(self) -> int:
        return len(self.times)

    def add_rank(self, rank: int, stage: Optional[int] = None):
        """Register a rank (SCALE_OUT / rejoin).  Health history restarts
        fresh and stale verdicts are cleared, so a rank that rejoins and
        later fails again is re-detected."""
        self.health[rank] = RankHealth()
        self.times[rank] = deque(maxlen=self.window)
        self.mem[rank] = deque(maxlen=self.window)
        if stage is not None:
            self.stage_of[rank] = stage
        self.reported_dead.discard(rank)
        self.reported_slow.discard(rank)
        self.reported_oom.discard(rank)

    def remove_rank(self, rank: int):
        """Retire a rank that left (recovered fail-stop / scale-in): it no
        longer accrues misses or participates in the stage-peer median."""
        self.health.pop(rank, None)
        self.times.pop(rank, None)
        self.mem.pop(rank, None)
        self.reported_dead.discard(rank)
        self.reported_slow.discard(rank)
        self.reported_oom.discard(rank)

    # -- state machine -----------------------------------------------------

    def confirm_needed(self, rank: int) -> int:
        """Consecutive misses required to confirm this rank dead.  Doubles
        with each recorded flap (bounded by ``backoff_cap``)."""
        h = self.health.get(rank)
        flaps = h.flaps if h is not None else 0
        return self.miss_limit * (2 ** min(flaps, self.backoff_cap))

    def max_confirm_misses(self) -> int:
        """Upper bound on observe() rounds needed to confirm any currently
        registered rank — executors use this as their detection-loop bound."""
        if not self.health:
            return self.miss_limit
        return max(self.confirm_needed(r) for r in self.health)

    def state_of(self, rank: int) -> Optional[HealthState]:
        h = self.health.get(rank)
        return h.state if h is not None else None

    # -- observation -------------------------------------------------------

    def observe(self, probes: List[Probe]) -> List[ElasticEvent]:
        events: List[ElasticEvent] = []
        step = probes[0].step if probes else 0
        # Aggregate probes per rank: order-independent, duplicate-proof.
        # Any heartbeat among a rank's probes counts as life; step-time and
        # memory samples are the medians/max over the heartbeat copies.
        beats: Dict[int, List[Probe]] = {}
        seen: set = set()
        for p in probes:
            if p.rank not in self.times:      # unregistered: ignore
                continue
            seen.add(p.rank)
            if p.heartbeat:
                beats.setdefault(p.rank, []).append(p)

        for r in self.ranks:
            h = self.health[r]
            alive = r in beats
            if alive:
                ps = beats[r]
                self.times[r].append(float(np.median([p.step_seconds for p in ps])))
                m = max(p.mem_used for p in ps)
                if m > 0:
                    self.mem[r].append(float(m))
                if h.state is HealthState.SUSPECT:
                    h.flaps += 1              # blip, not death: back off
                if h.state is not HealthState.CONFIRMED:
                    h.state = HealthState.HEALTHY
                h.consecutive_misses = 0
            elif r in seen or probes:
                # missed: either an explicit dead probe or absent from a
                # round that did carry probes
                h.consecutive_misses += 1
                if h.state is HealthState.HEALTHY:
                    h.state = HealthState.SUSPECT
                if (h.consecutive_misses >= self.confirm_needed(r)
                        and h.state is not HealthState.CONFIRMED):
                    h.state = HealthState.CONFIRMED
                    self.reported_dead.add(r)
                    events.append(ElasticEvent(
                        EventKind.FAIL_STOP, step, (r,),
                        detail=(f"{h.consecutive_misses} consecutive misses"
                                f" (needed {self.confirm_needed(r)},"
                                f" flaps={h.flaps})")))

        events.extend(self._detect_slow(step))
        events.extend(self._detect_oom(step))
        return events

    def _detect_slow(self, step: int) -> List[ElasticEvent]:
        """Fail-slow: each rank's rolling median vs the median of its stage
        peers' medians.  Ranks without enough history — or without any peer
        that has enough history — are skipped."""
        events: List[ElasticEvent] = []
        med: Dict[int, float] = {
            r: float(np.median(d)) for r, d in self.times.items()
            if len(d) >= self.window // 2}
        for r, m in med.items():
            if r in self.reported_dead or r in self.reported_slow:
                continue
            stage = self.stage_of.get(r, 0)
            peers = [med[q] for q in med
                     if q != r and self.stage_of.get(q, 0) == stage]
            if not peers:
                continue
            ref = float(np.median(peers))
            if ref > 0 and m > self.slow_threshold * ref:
                self.reported_slow.add(r)
                events.append(ElasticEvent(
                    EventKind.FAIL_SLOW, step, (r,), slow_factor=float(m / ref),
                    detail=f"median {m:.3f}s vs stage peers {ref:.3f}s"))
        return events

    def _detect_oom(self, step: int) -> List[ElasticEvent]:
        """OOM early warning: linear-trend extrapolation of per-rank memory
        usage.  Advisory — emitted once per rank, re-armed when the
        projection drops back below the threshold."""
        events: List[ElasticEvent] = []
        limit = self.mem_threshold * self.mem_cap
        for r, d in self.mem.items():
            if r in self.reported_dead or len(d) < 2:
                continue
            xs = np.arange(len(d), dtype=np.float64)
            slope = float(np.polyfit(xs, np.asarray(d, dtype=np.float64), 1)[0])
            projected = d[-1] + max(slope, 0.0) * self.mem_horizon
            if projected >= limit:
                if r not in self.reported_oom:
                    self.reported_oom.add(r)
                    events.append(ElasticEvent(
                        EventKind.OOM_RISK, step, (r,),
                        detail=(f"mem {d[-1]:.3f} slope {slope:+.3f}/obs →"
                                f" {projected:.3f} ≥ {limit:.3f}"
                                f" within {self.mem_horizon} obs")))
            else:
                self.reported_oom.discard(r)
        return events

    def clear_slow(self, rank: int):
        self.reported_slow.discard(rank)
