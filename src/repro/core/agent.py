"""ElasWave Agent (paper §3.2): per-worker health monitoring.

Co-located with each (virtual) worker; hooks heartbeat/step-time probes and
relays elastic events to the Core.  Fail-stop: missed heartbeats.  Fail-slow:
step-time z-score over a rolling window against the stage's peer median.
Scheduler signals (scale in/out) are injected directly.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from .events import ElasticEvent, EventKind


@dataclasses.dataclass
class Probe:
    step: int
    rank: int
    heartbeat: bool
    step_seconds: float
    mem_used: float = 0.0


class Agent:
    def __init__(self, num_ranks: int, window: int = 8,
                 slow_threshold: float = 1.3, miss_limit: int = 2):
        self.num_ranks = num_ranks
        self.window = window
        self.slow_threshold = slow_threshold
        self.miss_limit = miss_limit
        self.misses: Dict[int, int] = {r: 0 for r in range(num_ranks)}
        self.times: Dict[int, Deque[float]] = {
            r: deque(maxlen=window) for r in range(num_ranks)}
        self.reported_slow: set = set()
        self.reported_dead: set = set()

    def observe(self, probes: List[Probe]) -> List[ElasticEvent]:
        events: List[ElasticEvent] = []
        step = probes[0].step if probes else 0
        seen = set()
        for p in probes:
            seen.add(p.rank)
            if not p.heartbeat:
                self.misses[p.rank] += 1
            else:
                self.misses[p.rank] = 0
                self.times[p.rank].append(p.step_seconds)
        for r in range(self.num_ranks):
            if r not in seen:
                self.misses[r] = self.misses.get(r, 0) + 1
            if self.misses[r] >= self.miss_limit and r not in self.reported_dead:
                self.reported_dead.add(r)
                events.append(ElasticEvent(EventKind.FAIL_STOP, step, (r,),
                                           detail=f"{self.misses[r]} missed heartbeats"))
        # fail-slow: compare each rank's median to the global median
        med_all = np.median([t for d in self.times.values() for t in d]) \
            if any(self.times.values()) else 0.0
        for r, d in self.times.items():
            if len(d) < self.window // 2 or r in self.reported_dead:
                continue
            m = np.median(d)
            if med_all > 0 and m > self.slow_threshold * med_all \
                    and r not in self.reported_slow:
                self.reported_slow.add(r)
                events.append(ElasticEvent(
                    EventKind.FAIL_SLOW, step, (r,), slow_factor=float(m / med_all),
                    detail=f"median {m:.3f}s vs fleet {med_all:.3f}s"))
        return events

    def clear_slow(self, rank: int):
        self.reported_slow.discard(rank)
