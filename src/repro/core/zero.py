"""ZeRO optimizer-state layouts (paper §6.3): Contiguous vs Interleaved.

State space: per *stage*, the concatenation of its layers' flattened optimizer
vectors.  Ownership within the stage's DP group:

* **Contiguous**: one global byte array per DP group; rank j owns one
  contiguous block of ~equal size.  Migrating layer i's state across stages
  shifts every cut point by ~|O_i|/D -> many-to-many intra-stage resharding;
  total bytes ~= (D+1)/2 * |O_i|.
* **Interleaved**: each layer's vector is split into D equal shards; rank j
  owns shard j of *every* layer.  Migration = D disjoint rank-to-rank sends;
  total bytes = |O_i| and no intra-stage resharding.

`migration_plan` returns the exact transfer list (src_rank, dst_rank, nbytes,
intra_stage) for either layout — executed for real by core/migration.py and
measured by benchmarks/migration_mttr.py.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

Interval = Tuple[int, int]   # [start, end) byte offsets


@dataclasses.dataclass(frozen=True)
class Layout:
    kind: str                           # "contiguous" | "interleaved"
    layer_sizes: Tuple[int, ...]        # bytes per layer in this stage
    dp: int

    @property
    def total(self) -> int:
        return sum(self.layer_sizes)

    def owner_intervals(self, rank: int) -> List[Interval]:
        """Intervals of the stage state space owned by `rank`."""
        if self.kind == "contiguous":
            per = self.total // self.dp
            start = rank * per
            end = self.total if rank == self.dp - 1 else start + per
            return [(start, end)]
        out: List[Interval] = []
        off = 0
        for sz in self.layer_sizes:
            per = sz // self.dp
            s = off + rank * per
            e = off + sz if rank == self.dp - 1 else s + per
            out.append((s, e))
            off += sz
        return out

    def layer_interval(self, layer_pos: int) -> Interval:
        off = sum(self.layer_sizes[:layer_pos])
        return (off, off + self.layer_sizes[layer_pos])

    def table(self):
        """The memoized, vectorized equivalent of this layout
        (``core.statespace.IntervalTable``) — what hot paths should use
        instead of calling :meth:`owner_intervals` per rank per step.  This
        pure-Python implementation stays as the reference; equivalence is
        enforced by ``tests/test_statespace.py``."""
        from .statespace import get_table
        return get_table(self.kind, self.layer_sizes, self.dp)


def _overlap(a: Interval, b: Interval) -> int:
    return max(0, min(a[1], b[1]) - max(a[0], b[0]))


@dataclasses.dataclass(frozen=True)
class Transfer:
    src_rank: int
    dst_rank: int
    nbytes: int
    intra_stage: bool     # True: resharding within a stage's DP group
    src_stage: int = 0
    dst_stage: int = 0


def migration_plan(kind: str, layer_sizes: Sequence[int], layer_pos: int,
                   dp: int, src_stage: int, dst_stage: int,
                   dst_layer_sizes: Sequence[int]) -> List[Transfer]:
    """Plan for migrating layer `layer_pos`'s optimizer state from src_stage
    (layout over `layer_sizes`) to dst_stage (receiving it appended)."""
    sizes = tuple(layer_sizes)
    size_i = sizes[layer_pos]
    transfers: List[Transfer] = []

    if kind == "interleaved":
        # D disjoint rank-to-rank sends: rank j -> rank j.
        per = size_i // dp
        for j in range(dp):
            n = size_i - per * (dp - 1) if j == dp - 1 else per
            transfers.append(Transfer(j, j, n, intra_stage=False,
                                      src_stage=src_stage, dst_stage=dst_stage))
        return transfers

    assert kind == "contiguous"
    old = Layout("contiguous", sizes, dp)
    new_sizes = tuple(s for i, s in enumerate(sizes) if i != layer_pos)
    new = Layout("contiguous", new_sizes, dp)
    li = old.layer_interval(layer_pos)

    # map old offsets -> new offsets (remove the layer's interval)
    def to_new(off: int) -> int:
        return off if off <= li[0] else off - (li[1] - li[0])

    # 1) cross-stage: the migrating layer's bytes leave, from whoever owns them
    for j in range(dp):
        for iv in old.owner_intervals(j):
            n = _overlap(iv, li)
            if n:
                transfers.append(Transfer(j, j, n, intra_stage=False,
                                          src_stage=src_stage, dst_stage=dst_stage))
    # 2) intra-stage resharding: remaining bytes move to restore contiguity
    for j_old in range(dp):
        for iv in old.owner_intervals(j_old):
            # subtract the migrated interval, remap to new space
            pieces = []
            if iv[0] < li[0]:
                pieces.append((iv[0], min(iv[1], li[0])))
            if iv[1] > li[1]:
                pieces.append((max(iv[0], li[1]), iv[1]))
            for (s, e) in pieces:
                ns, ne = to_new(s), to_new(e)
                for j_new in range(dp):
                    for tv in new.owner_intervals(j_new):
                        n = _overlap((ns, ne), tv)
                        if n and j_new != j_old:
                            transfers.append(Transfer(
                                j_old, j_new, n, intra_stage=True,
                                src_stage=src_stage, dst_stage=src_stage))
    return transfers


def plan_bytes(transfers: Sequence[Transfer]) -> Dict[str, int]:
    cross = sum(t.nbytes for t in transfers if not t.intra_stage)
    intra = sum(t.nbytes for t in transfers if t.intra_stage)
    return {"cross_stage": cross, "intra_stage": intra, "total": cross + intra}


def theoretical_bytes(kind: str, size_i: int, dp: int) -> float:
    """Paper §6.3 closed forms: contiguous ~ (D+1)/2 |O_i|; interleaved |O_i|."""
    if kind == "interleaved":
        return float(size_i)
    return (dp + 1) / 2 * size_i
