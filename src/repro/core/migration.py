"""Layer migration (paper §6.2): blocking vs non-blocking with gradient
precomputation, plus the optimizer-state movement from core/zero.py.

Blocking: pause -> copy params+opt -> resume.  Stall = bytes/bw + fixed
orchestration.

Non-blocking (ElasWave, Fig. 9): the parameter copy streams while training
proceeds.  For early micro-batches mb[0..k] the *target* stage has no L_i
parameters yet, so the *source* runs a shadow instance of L_i, accumulates
the missing gradients, and asynchronously ships one "payback" gradient that
the target merges — gradient accumulation stays complete, and the only
non-overlapped cost is orchestration + whatever copy time exceeds the step's
compute window.

The VirtualCluster executes the numerics (shadow grads merged exactly); this
module provides the planning + MTTR accounting used by both the cluster and
benchmarks/migration_mttr.py.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

from . import zero

ORCH_OVERHEAD_S = 0.3           # pause/handshake/bookkeeping per layer move


@dataclasses.dataclass(frozen=True)
class MigrationSpec:
    layer_ids: Tuple[int, ...]      # global layer indices to move
    src_stage: int
    dst_stage: int
    param_bytes: int                # total parameter payload
    opt_bytes: int                  # total optimizer-state payload
    dp: int
    zero_layout: str                # "contiguous" | "interleaved"
    blocking: bool


@dataclasses.dataclass
class MigrationTiming:
    param_seconds: float
    opt_seconds: float
    overlapped_seconds: float       # hidden under compute
    stall_seconds: float            # MTTR contribution
    payback_grad_bytes: int
    opt_transfer_bytes: int


def plan_opt_transfers(spec: MigrationSpec, layer_sizes: Sequence[int],
                       layer_pos: int, dst_layer_sizes: Sequence[int],
                       ) -> List[zero.Transfer]:
    return zero.migration_plan(spec.zero_layout, layer_sizes, layer_pos,
                               spec.dp, spec.src_stage, spec.dst_stage,
                               dst_layer_sizes)


def migration_timing(spec: MigrationSpec, link_bw: float,
                     step_compute_window: float) -> MigrationTiming:
    """MTTR model.  `step_compute_window`: compute time available to hide the
    copy under (non-blocking overlaps with ongoing training steps)."""
    if spec.zero_layout == "interleaved":
        opt_bytes = float(spec.opt_bytes)
        # D disjoint p2p sends proceed in parallel across ranks
        opt_secs = spec.opt_bytes / spec.dp / link_bw
    else:
        opt_bytes = zero.theoretical_bytes("contiguous", spec.opt_bytes, spec.dp)
        # cross-stage |O_i| + (D-1)/2 |O_i| intra-stage neighbor rounds,
        # serialized through the group (paper §6.3)
        opt_secs = (spec.opt_bytes / spec.dp / link_bw
                    + (spec.dp - 1) / 2 * spec.opt_bytes / spec.dp / link_bw * 2)
    param_secs = spec.param_bytes / link_bw
    payback = spec.param_bytes * 2 if not spec.blocking else 0   # fp32 grads of bf16 params

    orch = ORCH_OVERHEAD_S * max(len(spec.layer_ids), 1)
    if spec.blocking:
        stall = orch + param_secs + opt_secs
        overlapped = 0.0
    else:
        # The copy overlaps with ongoing compute, but not perfectly: the
        # shadow-instance bookkeeping, the payback-gradient merge, and the
        # final parameter swap stay on the critical path.  Empirically (paper
        # Fig. 13) the hidden fraction saturates around ~55% of the payload
        # for large models — orchestration dominates for small ones.
        copy = param_secs + opt_secs
        payback_secs = payback / link_bw * 0.2   # low-priority, mostly hidden
        overlapped = min(0.55 * copy, step_compute_window)
        stall = orch + (copy - overlapped) + payback_secs
    return MigrationTiming(param_secs, opt_secs, overlapped, stall,
                           payback, int(opt_bytes))
