"""Seed-faithful reference implementations of the VirtualCluster hot paths.

These are the *old* (pre flat-state fast path) step and recovery
implementations, preserved verbatim modulo the StageState storage change
(per-rank shards are now zero-copy views into per-stage flat buffers, and
interval lookups go through the memoized ``statespace`` tables).  The Python
per-item / per-rank / per-interval loop *structure* of the seed — one jitted
grad call and one host sync per micro-batch, one eager Adam per ZeRO shard,
one re-unravel per entry, full-stage rebuilds on migration — is exactly what
the fast path in ``cluster.py`` optimizes away, so it is what this module
preserves.

Two consumers:

* the numerics oracle — ``tests/test_fast_path_numerics.py`` asserts the fast
  path's loss trajectory and post-recovery shard contents are bit-identical
  to this path;
* the benchmark baseline — ``benchmarks/train_step_perf.py`` times this path
  against the fast path and emits ``BENCH_train_step.json``.

Selected with ``VirtualCluster(..., fast_path=False)``.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.flatten_util import ravel_pytree

from repro.data.pipeline import make_batch
from repro.optim.adam import adam_update_flat

from .fabric.snapshot import SnapshotPool
from .migration import MigrationSpec, migration_timing
from .statespace import COMPONENTS, HEAD, STEM, StageState, get_table


# ------------------------------------------------------------------ step --
def micro_grads(cl, step: int) -> Tuple[float, tuple]:
    """Seed micro-batch loop: one jitted grad call and one ``float(loss)``
    host sync per (micro, rank) slice; per-leaf eager accumulation."""
    ids_by_rank = cl.sampler.partition(step, cl.per_rank_mbs, cl.num_micro)
    step_key = jax.random.fold_in(cl.base_key, step)
    total_loss = 0.0
    acc = None
    for m in range(cl.num_micro):
        for r, rank_ids in enumerate(ids_by_rank):
            ids = rank_ids[m]
            if len(ids) == 0:
                continue
            batch = make_batch(ids, cl.seq, cl.cfg.vocab_size)
            if cl.rng_mode == "reshard":
                sids = batch["sample_ids"]
            else:   # naive: rank-addressed streams (the paper's "w/o")
                sids = jnp.arange(len(ids)) + r * 100003
            loss, grads = cl._grad_fn(len(ids))(
                cl.stem, cl.layer_params, cl.head,
                batch["tokens"], batch["labels"], step_key, sids)
            w = cl.grad_weights[r] / cl.num_micro
            total_loss += float(loss) * w
            gs = jax.tree.map(lambda g: g * w, grads)
            acc = gs if acc is None else jax.tree.map(jnp.add, acc, gs)
    return total_loss, acc


def train_step(cl) -> float:
    """Seed train step: per-entry gradient re-ravel, per-(stage, rank) eager
    Adam over interval-concatenated shards, per-entry parameter re-unravel."""
    step = cl.step_count
    loss, (g_stem, g_layers, g_head) = micro_grads(cl, step)
    cl.opt_step += 1
    grad_shard_by_stage: List[List[np.ndarray]] = []
    for p, st in enumerate(cl.stages):
        # assemble this stage's full gradient vector
        parts = []
        for e in st.entries:
            if e == STEM:
                parts.append(np.asarray(ravel_pytree(g_stem)[0], np.float32))
            elif e == HEAD:
                parts.append(np.asarray(ravel_pytree(g_head)[0], np.float32))
            else:
                parts.append(np.asarray(ravel_pytree(g_layers[e])[0], np.float32))
        gfull = np.concatenate(parts) if parts else np.zeros(0, np.float32)
        tbl = st.table
        shards = []
        for j, r in enumerate(st.dp_ranks):
            gs = np.concatenate([gfull[s:e] for s, e in tbl.owner_intervals(j)]) \
                if st.total else np.zeros(0, np.float32)
            _, newst = adam_update_flat(
                jnp.asarray(gs),
                {k: jnp.asarray(v) for k, v in st.shard(r).items()},
                cl.opt_step, cl.adam)
            st.write_shard(r, {k: np.asarray(v) for k, v in newst.items()})
            shards.append(gs)
        grad_shard_by_stage.append(shards)
    write_params_from_masters(cl)
    if cl.snapshot_enabled:
        for p, st in enumerate(cl.stages):
            cl.snapshots[p].snapshot_step(step, grad_shard_by_stage[p],
                                          cl.opt_step)
    cl.step_count += 1
    cl.losses.append(loss)
    return loss


def stage_full_vec(st: StageState, comp: str = "master") -> np.ndarray:
    """Seed all-gather equivalent: per-rank, per-interval Python copy loop."""
    full = np.zeros(st.total, dtype=np.float32)
    tbl = st.table
    shards = st.shards
    for j, r in enumerate(st.dp_ranks):
        off = 0
        src = shards[r][comp]
        for s, e in tbl.owner_intervals(j):
            n = e - s
            full[s:e] = src[off:off + n]
            off += n
    return full


def write_params_from_masters(cl) -> None:
    """Seed write-back: one re-unravel and one host->device transfer per
    entry."""
    for p, st in enumerate(cl.stages):
        full = stage_full_vec(st)
        off = 0
        for e, sz in zip(st.entries, st.sizes):
            vec = jnp.asarray(full[off:off + sz])
            tree = cl.flattener.unflatten_entry(e, vec)
            if e == STEM:
                cl.stem = tree
            elif e == HEAD:
                cl.head = tree
            else:
                cl.layer_params[e] = tree
            off += sz


# -------------------------------------------------------------- recovery --
def stage_full_vec_with_snapshots(cl, p: int, comp: str,
                                  failed: List[int]) -> np.ndarray:
    """Pre-failure ground truth: survivors' device state + failed ranks'
    snapshot state (seed per-interval loop)."""
    st = cl.stages[p]
    pool = cl.snapshots[p]
    full = np.zeros(st.total, dtype=np.float32)
    tbl = st.table
    shards = st.shards
    for j, r in enumerate(st.dp_ranks):
        src = shards[r][comp] if r not in failed else None
        if src is None:
            snap = pool.host[pool.holder_of(j)]
            src = snap[comp] if snap is not None else None
        if src is None:
            continue
        off = 0
        for s, e in tbl.owner_intervals(j):
            full[s:e] = src[off:off + (e - s)]
            off += e - s
    return full


def live_remap_stage(cl, p: int, failed: List[int]):
    """Seed shrink remap: per-component, per-rank segment dicts rebuilt in
    Python; full-vector verification via the seed gather loop."""
    st = cl.stages[p]
    pool = cl.snapshots[p]
    tbl = st.table
    old_ranks = list(st.dp_ranks)
    # record pre-failure full vectors for verification
    pre = {c: stage_full_vec_with_snapshots(cl, p, c, failed)
           for c in COMPONENTS}

    surviving = [r for r in old_ranks if r not in failed]
    device_parts = {r: tbl.owner_intervals(old_ranks.index(r))
                    for r in surviving}
    host_parts = {}
    for f in failed:
        holder = pool.holder_of(old_ranks.index(f))
        holder_rank = old_ranks[holder]
        if holder_rank in surviving and pool.host[holder] is not None:
            host_parts[f] = tbl.owner_intervals(old_ranks.index(f))
    new_tbl = get_table(st.layout_kind, st.sizes, len(surviving))
    target_parts = {r: new_tbl.owner_intervals(j)
                    for j, r in enumerate(surviving)}

    plan = cl.remapper.compute_plan(st.total, device_parts, host_parts,
                                    target_parts)
    # execute with real arrays, per component
    shards = st.shards
    new_shards: Dict[int, Dict[str, np.ndarray]] = {r: {} for r in surviving}
    for comp in COMPONENTS:
        device_data = {}
        for r in surviving:
            ivs = tbl.owner_intervals(old_ranks.index(r))
            segs, off = {}, 0
            for s, e in ivs:
                segs[(s, e)] = shards[r][comp][off:off + (e - s)]
                off += e - s
            device_data[r] = segs
        host_data = {}
        for f in failed:
            holder = pool.holder_of(old_ranks.index(f))
            snap = pool.host[holder]
            if snap is None:
                continue
            ivs = tbl.owner_intervals(old_ranks.index(f))
            segs, off = {}, 0
            for s, e in ivs:
                segs[(s, e)] = snap[comp][off:off + (e - s)]
                off += e - s
            host_data[f] = segs
        assembled = cl.remapper.execute(plan, st.total, device_data, host_data)
        for r in surviving:
            new_shards[r][comp] = assembled.get(r, np.zeros(0, np.float32))
    st.replace_shards(surviving, new_shards)
    # verification (paper: online verification before resume)
    for comp in COMPONENTS:
        post = stage_full_vec(st, comp)
        assert np.array_equal(post, pre[comp]), f"remap corrupted {comp}"
    # rebuild ring snapshot pool for the shrunken group
    cl.snapshots[p] = SnapshotPool(len(surviving), cl.adam, batched=False)
    if cl.snapshot_enabled:
        cl.snapshots[p].bootstrap(cl.step_count,
                                  [st.shard(r) for r in surviving])
    return plan.est_seconds, plan


def widen_stage(cl, p: int, joining: List[int]) -> float:
    """Seed reverse remap: redistribute the stage state over a WIDER group."""
    st = cl.stages[p]
    old_ranks = list(st.dp_ranks)
    tbl = st.table
    new_ranks = old_ranks + [j for j in joining if j not in old_ranks]
    pre = {c: stage_full_vec(st, c) for c in COMPONENTS}
    device_parts = {r: tbl.owner_intervals(old_ranks.index(r))
                    for r in old_ranks}
    new_tbl = get_table(st.layout_kind, st.sizes, len(new_ranks))
    target_parts = {r: new_tbl.owner_intervals(j)
                    for j, r in enumerate(new_ranks)}
    plan = cl.remapper.compute_plan(st.total, device_parts, {}, target_parts)
    shards = st.shards
    new_shards: Dict[int, Dict[str, np.ndarray]] = {r: {} for r in new_ranks}
    for comp in COMPONENTS:
        device_data = {}
        for r in old_ranks:
            ivs = tbl.owner_intervals(old_ranks.index(r))
            segs, off = {}, 0
            for s, e in ivs:
                segs[(s, e)] = shards[r][comp][off:off + (e - s)]
                off += e - s
            device_data[r] = segs
        assembled = cl.remapper.execute(plan, st.total, device_data, {})
        for r in new_ranks:
            new_shards[r][comp] = assembled.get(r, np.zeros(0, np.float32))
    st.replace_shards(new_ranks, new_shards)
    for comp in COMPONENTS:
        post = stage_full_vec(st, comp)
        assert np.array_equal(post, pre[comp]), f"widen corrupted {comp}"
    cl.snapshots[p] = SnapshotPool(len(new_ranks), cl.adam, batched=False)
    if cl.snapshot_enabled:
        cl.snapshots[p].bootstrap(cl.step_count,
                                  [st.shard(r) for r in new_ranks])
    return plan.est_seconds


def entry_from_stage(cl, e: int) -> Dict[str, np.ndarray]:
    """Seed entry extraction: three full-stage gathers per entry."""
    for st in cl.stages:
        if e in st.entries:
            pos = st.entries.index(e)
            iv = st.table.layer_interval(pos)
            out = {}
            for comp in COMPONENTS:
                full = stage_full_vec(st, comp)
                out[comp] = full[iv[0]:iv[1]]
            return out
    raise KeyError(e)


def apply_migrations(cl, moves: List[Tuple[int, int, int]],
                     new_ranges: List[Tuple[int, int]]) -> float:
    """Seed migration executor: rebuilds EVERY stage's state (and snapshot
    pool) from per-entry slices, affected or not."""
    total_stall = 0.0
    # compute per-move timing with the migration model
    step_window = cl.simulate_step_time()
    for (lid, src, dst) in moves:
        st_src = cl.stages[src]
        pbytes = int(cl.seg.param_bytes[lid])
        obytes = int(cl.seg.opt_bytes[lid])
        spec = MigrationSpec((lid,), src, dst, pbytes, obytes,
                             dp=len(st_src.dp_ranks),
                             zero_layout=cl.zero_layout,
                             blocking=not cl.non_blocking_migration)
        timing = migration_timing(spec, cl.hw.link_bw, step_window)
        total_stall += timing.stall_seconds
    # state movement: rebuild both stage states from the new assignment
    # (real arrays; correctness asserted by reconstructing masters)
    pre_state = {e: entry_from_stage(cl, e) for st in cl.stages
                 for e in st.entries}
    cl.layer_assignment = list(new_ranges)
    for p in range(cl.pp):
        st_old = cl.stages[p]
        survivors = list(st_old.dp_ranks)
        entries = cl._stage_entries(p)
        vec_parts = [pre_state[e] for e in entries]
        sizes = [v["master"].size for v in vec_parts]
        full_by_comp = {
            c: (np.concatenate([v[c] for v in vec_parts]) if vec_parts
                else np.zeros(0, np.float32))
            for c in COMPONENTS}
        new_st = StageState.from_full(entries, sizes, cl.zero_layout,
                                      survivors, full_by_comp)
        cl.stages[p] = new_st
        cl.snapshots[p] = SnapshotPool(len(survivors), cl.adam, batched=False)
        if cl.snapshot_enabled:
            cl.snapshots[p].bootstrap(cl.step_count,
                                      [new_st.shard(r) for r in survivors])
    return total_stall
