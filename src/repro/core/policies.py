"""Recovery policies: ElasWave (ours) + the paper's two baselines.

All three consume the same ClusterView and produce a ThroughputDecision the
pipeline simulator can evaluate, so Fig. 11/12a/14 comparisons are
apples-to-apples.

* **TorchFTPolicy** — DP-replica granularity: a failure drops the entire DP
  replica (pipeline) containing the failed rank; remaining replicas re-split
  the global batch.  Wastes the failed replica's surviving ranks.
* **ReCyclePolicy** — keep the layout; reroute the failed rank's micro-batches
  to same-stage peers in other DP replicas (decoupled-backward bubbles absorb
  some of it).  Creates stage stragglers when the bubble budget is exhausted
  and extends activation lifetimes (OOM risk), per paper Fig. 1.
* **ElasWavePolicy** — multi-dimensional: dataflow resize (DP domain) +
  minimax layer re-partition (PP domain) + DVFS top-up.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .cost_model import HardwareSpec, SegmentCosts, mini_step_time
from .pipeline import StageTiming, simulate_1f1b, simulate_dp_pp
from .planners.dataflow import plan_dataflow
from .planners.graph import minimax_layer_partition
from .planners.dvfs import plan_dvfs, ACHIEVABLE


@dataclasses.dataclass
class ClusterView:
    """What the Agent reports to the Core."""
    dp: int                          # replicas
    pp: int                          # stages
    global_batch: int
    num_micro: int
    seq: int
    layer_assignment: List[Tuple[int, int]]   # per stage [a, b] inclusive
    alive: np.ndarray                # [dp, pp] bool
    freq: np.ndarray                 # [dp, pp] normalized frequency
    slow: np.ndarray                 # [dp, pp] straggler multiplier (>=1)
    mem_cap: float                   # bytes per device


@dataclasses.dataclass
class Decision:
    name: str
    step_time: float
    feasible: bool
    detail: Dict


def _stage_times(seg: SegmentCosts, view: ClusterView, assignment,
                 mbs_by_stage: Sequence[int], freq: np.ndarray,
                 slow: np.ndarray, d: int) -> List[StageTiming]:
    stages = []
    for p, (a, b) in enumerate(assignment):
        eff = seg.hw.peak_flops * seg.hw.mfu * freq[d, p] / slow[d, p]
        fl = seg.seg_fwd_flops(a, b, mbs_by_stage[p])
        stages.append(StageTiming(fl / eff, 2 * fl / eff, view.num_micro))
    return stages


class TorchFTPolicy:
    name = "torchft"

    def decide(self, seg: SegmentCosts, view: ClusterView) -> Decision:
        # replicas with any dead rank are dropped entirely
        alive_reps = [d for d in range(view.dp) if view.alive[d].all()]
        n = len(alive_reps)
        if n == 0:
            return Decision(self.name, float("inf"), False, {"alive_reps": 0})
        # global batch is re-split over the surviving replicas: same
        # micro-batch size, proportionally more micro-batches per replica.
        mbs = max(1, view.global_batch // (view.num_micro * view.dp))
        num_micro_n = -(-view.global_batch // (mbs * n))
        times = []
        for d in alive_reps:
            st = _stage_times(seg, view, view.layer_assignment,
                              [mbs] * view.pp, view.freq, view.slow, d)
            st = [StageTiming(s.fwd, s.bwd, num_micro_n) for s in st]
            times.append(simulate_1f1b(st).step_time)
        # replicas synchronized by grad all-reduce
        return Decision(self.name, max(times), True,
                        {"alive_reps": n, "mbs": mbs, "num_micro": num_micro_n,
                         "wasted_ranks": int((view.alive.sum()
                                              - n * view.pp))})


class ReCyclePolicy:
    name = "recycle"

    def __init__(self, oom_pressure_limit: float = 2.5):
        # memory-pressure model: rerouting extends activation lifetimes and
        # defers weight-gradients on every affected stage.  pressure =
        # sum over affected stages of (extra / num_micro).  Calibrated so the
        # paper's observation holds: Llama2-34B (DP=3) OOMs at 3-node loss
        # (6 affected stages x 0.5 = 3.0 > limit) but not at 1-2 nodes.
        self.oom_pressure_limit = oom_pressure_limit

    def decide(self, seg: SegmentCosts, view: ClusterView) -> Decision:
        mbs = max(1, view.global_batch // (view.num_micro * view.dp))
        extra: Dict[Tuple[int, int], int] = {}
        for p in range(view.pp):
            dead = [d for d in range(view.dp) if not view.alive[d, p]]
            live = [d for d in range(view.dp) if view.alive[d, p]]
            if dead and not live:
                return Decision(self.name, float("inf"), False, {"stage_lost": p})
            for i, d in enumerate(dead):
                # reroute the failed rank's micro-batches round-robin to peers
                share = view.num_micro // max(len(live), 1)
                for j, ld in enumerate(live):
                    add = share + (1 if j < view.num_micro % max(len(live), 1) else 0)
                    extra[(ld, p)] = extra.get((ld, p), 0) + add
        # OOM check: deferred weight-grad + extended activation pressure
        pressure = sum(e / view.num_micro for e in extra.values())
        oom = pressure > self.oom_pressure_limit
        fwd = [[0.0] * view.pp for _ in range(view.dp)]
        bwd = [[0.0] * view.pp for _ in range(view.dp)]
        for d in range(view.dp):
            st = _stage_times(seg, view, view.layer_assignment,
                              [mbs] * view.pp, view.freq, view.slow, d)
            for p, s in enumerate(st):
                fwd[d][p], bwd[d][p] = s.fwd, s.bwd
        # replicas with dead ranks still run (peers cover), but dead rank rows
        # excluded from timing by copying a live replica's times (uniform
        # hardware -> any live row; if none is fully live, rows are already
        # per-stage correct since peers cover the dead cells)
        live_rows = [d for d in range(view.dp) if view.alive[d].all()]
        if live_rows:
            for d in range(view.dp):
                if not view.alive[d].all():
                    fwd[d] = list(fwd[live_rows[0]])
                    bwd[d] = list(bwd[live_rows[0]])
        step, _ = simulate_dp_pp(fwd, bwd, view.num_micro,
                                 extra_micro=extra)
        return Decision(self.name, step, not oom,
                        {"extra_micro": dict(extra), "oom": oom, "mbs": mbs})


class ElasWavePolicy:
    name = "elaswave"

    def __init__(self, hw: Optional[HardwareSpec] = None, use_dvfs: bool = True,
                 use_migration: bool = True, pipeline_v: int = 1):
        self.hw = hw or HardwareSpec()
        self.use_dvfs = use_dvfs
        self.use_migration = use_migration
        self.pipeline_v = pipeline_v     # >1: interleaved-1F1B virtual stages

    def decide(self, seg: SegmentCosts, view: ClusterView) -> Decision:
        L = seg.cfg.num_layers
        P = view.pp
        # per-stage surviving DP width
        width = [int(view.alive[:, p].sum()) for p in range(P)]
        if min(width) == 0:
            return Decision(self.name, float("inf"), False, {"stage_lost": True})
        # 1) dataflow: per-stage micro-batch sizes (failed rank's share spread)
        per_micro = view.global_batch // view.num_micro
        mbs_stage = [int(np.ceil(per_micro / w)) for w in width]

        # 2) graph: minimax layer re-partition under memory caps.
        # Per-stage straggler factors enter the cost (a slow stage should
        # receive FEWER layers — fail-slow mitigation via migration).
        slow_stage = [max((view.slow[d, p] for d in range(view.dp)
                           if view.alive[d, p]), default=1.0)
                      for p in range(P)]

        def t(p, a, b):
            return mini_step_time(seg, a, b, mbs_stage[p], hw=self.hw) \
                * slow_stage[p]

        def mem(p, a, b):
            return seg.seg_mem(a, b, mbs_stage[p], inflight=min(P, view.num_micro),
                               dp_size=width[p])

        if self.use_migration:
            plan = minimax_layer_partition(L, P, t, mem,
                                           [view.mem_cap] * P)
            if not plan.feasible:
                return Decision(self.name, float("inf"), False, {"mem_infeasible": True})
            assignment = list(plan.stage_ranges)
        else:
            assignment = list(view.layer_assignment)

        # 3) DVFS: up-clock residual stragglers to match the best stage time
        freq = view.freq.copy()
        base_times = []
        for p, (a, b) in enumerate(assignment):
            worst_slow = max(view.slow[d, p] for d in range(view.dp)
                             if view.alive[d, p])
            eff = self.hw.peak_flops * self.hw.mfu / worst_slow
            fl = seg.seg_fwd_flops(a, b, mbs_stage[p])
            base_times.append(3 * fl / eff)
        target = min(base_times)
        dvfs_detail = []
        if self.use_dvfs:
            for p in range(P):
                if base_times[p] <= target * 1.001:
                    continue

                def obs(f, p=p):
                    return base_times[p] / f

                dplan = plan_dvfs(obs, 1.0, self.hw.max_freq, target,
                                  eps=0.02 * target, df_min=0.01, rank=p)
                for d in range(view.dp):
                    freq[d, p] = max(freq[d, p], dplan.freq)
                base_times[p] = base_times[p] / dplan.freq
                dvfs_detail.append((p, round(dplan.freq, 3), dplan.status))

        # evaluate: stage p runs with its own width/mbs; replicas sync on DP
        # all-reduce — simulate one "effective" pipeline with per-stage times
        stages = []
        for p, (a, b) in enumerate(assignment):
            worst_slow = max(view.slow[d, p] for d in range(view.dp)
                             if view.alive[d, p])
            f = max(freq[d, p] for d in range(view.dp) if view.alive[d, p])
            eff = self.hw.peak_flops * self.hw.mfu * f / worst_slow
            fl = seg.seg_fwd_flops(a, b, mbs_stage[p])
            stages.append(StageTiming(fl / eff, 2 * fl / eff, view.num_micro))
        if self.pipeline_v > 1:
            from .pipeline import simulate_interleaved_1f1b
            step = simulate_interleaved_1f1b(stages, v=self.pipeline_v).step_time
        else:
            step = simulate_1f1b(stages).step_time
        return Decision(self.name, step, True,
                        {"assignment": assignment, "mbs_stage": mbs_stage,
                         "dvfs": dvfs_detail, "width": width})
