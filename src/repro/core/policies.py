"""Recovery policies: ElasWave (ours) + three baselines.

All policies consume the same rank-vectorized :class:`ClusterView`
(``core.clusterview`` — re-exported here for compatibility) and produce a
Decision the pipeline simulator can evaluate, so Fig. 11/12a/14 comparisons
are apples-to-apples.  Per-rank Python loops are replaced by stage/replica
array reductions, so ``decide`` stays sub-second at 10^5 ranks: the only
remaining loops run over pipeline stages (pp) or unique (freq, slow)
configurations, never over dp.

* **TorchFTPolicy** — DP-replica granularity: a failure drops the entire DP
  replica (pipeline) containing the failed rank; remaining replicas re-split
  the global batch.  Wastes the failed replica's surviving ranks.
* **ReCyclePolicy** — keep the layout; reroute the failed rank's micro-batches
  to same-stage peers in other DP replicas (decoupled-backward bubbles absorb
  some of it).  Creates stage stragglers when the bubble budget is exhausted
  and extends activation lifetimes (OOM risk), per paper Fig. 1.
* **OobleckPolicy** — pipeline-template fallback (PAPERS.md): precomputed
  minimax partitions per surviving-stage count; a damaged replica is
  re-instantiated on its k surviving workers from template[k] instead of
  being dropped.
* **ElasWavePolicy** — multi-dimensional: dataflow resize (DP domain) +
  minimax layer re-partition (PP domain) + DVFS top-up.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .clusterview import ClusterView, FailureDomainMap, GroupDelta  # noqa: F401  (re-export)
from .cost_model import HardwareSpec, SegmentCosts, mini_step_time
from .pipeline import StageTiming, simulate_1f1b, simulate_dp_pp
from .planners.dataflow import plan_dataflow
from .planners.graph import minimax_layer_partition
from .planners.dvfs import plan_dvfs, ACHIEVABLE


@dataclasses.dataclass
class Decision:
    name: str
    step_time: float
    feasible: bool
    detail: Dict


def _stage_times(seg: SegmentCosts, view: ClusterView, assignment,
                 mbs_by_stage: Sequence[int], freq: np.ndarray,
                 slow: np.ndarray, d: int) -> List[StageTiming]:
    stages = []
    for p, (a, b) in enumerate(assignment):
        eff = seg.hw.peak_flops * seg.hw.mfu * freq[d, p] / slow[d, p]
        fl = seg.seg_fwd_flops(a, b, mbs_by_stage[p])
        stages.append(StageTiming(fl / eff, 2 * fl / eff, view.num_micro))
    return stages


class TorchFTPolicy:
    name = "torchft"

    def decide(self, seg: SegmentCosts, view: ClusterView) -> Decision:
        # replicas with any dead rank are dropped entirely
        alive_rows = view.alive.all(axis=1)                     # [dp]
        n = int(alive_rows.sum())
        if n == 0:
            return Decision(self.name, float("inf"), False, {"alive_reps": 0})
        # global batch is re-split over the surviving replicas: same
        # micro-batch size, proportionally more micro-batches per replica.
        mbs = max(1, view.global_batch // (view.num_micro * view.dp))
        num_micro_n = -(-view.global_batch // (mbs * n))
        fl = [seg.seg_fwd_flops(a, b, mbs) for a, b in view.layer_assignment]
        # replicas synchronized by grad all-reduce -> step = max over
        # replicas; identical (freq, slow) rows give identical times, so
        # simulate each distinct configuration once (at scale: one row).
        rows = np.concatenate([view.freq[alive_rows], view.slow[alive_rows]],
                              axis=1)
        times = []
        for row in np.unique(rows, axis=0):
            f, s = row[:view.pp], row[view.pp:]
            st = [StageTiming(
                fl[p] / (seg.hw.peak_flops * seg.hw.mfu * f[p] / s[p]),
                2 * fl[p] / (seg.hw.peak_flops * seg.hw.mfu * f[p] / s[p]),
                num_micro_n) for p in range(view.pp)]
            times.append(simulate_1f1b(st).step_time)
        return Decision(self.name, max(times), True,
                        {"alive_reps": n, "mbs": mbs, "num_micro": num_micro_n,
                         "wasted_ranks": int((view.alive.sum()
                                              - n * view.pp))})


class ReCyclePolicy:
    name = "recycle"

    def __init__(self, oom_pressure_limit: float = 2.5):
        # memory-pressure model: rerouting extends activation lifetimes and
        # defers weight-gradients on every affected stage.  pressure =
        # sum over affected stages of (extra / num_micro).  Calibrated so the
        # paper's observation holds: Llama2-34B (DP=3) OOMs at 3-node loss
        # (6 affected stages x 0.5 = 3.0 > limit) but not at 1-2 nodes.
        self.oom_pressure_limit = oom_pressure_limit

    def decide(self, seg: SegmentCosts, view: ClusterView) -> Decision:
        mbs = max(1, view.global_batch // (view.num_micro * view.dp))
        extra: Dict[Tuple[int, int], int] = {}
        for p in range(view.pp):
            dead = [d for d in range(view.dp) if not view.alive[d, p]]
            live = [d for d in range(view.dp) if view.alive[d, p]]
            if dead and not live:
                return Decision(self.name, float("inf"), False, {"stage_lost": p})
            for i, d in enumerate(dead):
                # reroute the failed rank's micro-batches round-robin to peers
                share = view.num_micro // max(len(live), 1)
                for j, ld in enumerate(live):
                    add = share + (1 if j < view.num_micro % max(len(live), 1) else 0)
                    extra[(ld, p)] = extra.get((ld, p), 0) + add
        # OOM check: deferred weight-grad + extended activation pressure
        pressure = sum(e / view.num_micro for e in extra.values())
        oom = pressure > self.oom_pressure_limit
        fwd = [[0.0] * view.pp for _ in range(view.dp)]
        bwd = [[0.0] * view.pp for _ in range(view.dp)]
        for d in range(view.dp):
            st = _stage_times(seg, view, view.layer_assignment,
                              [mbs] * view.pp, view.freq, view.slow, d)
            for p, s in enumerate(st):
                fwd[d][p], bwd[d][p] = s.fwd, s.bwd
        # replicas with dead ranks still run (peers cover), but dead rank rows
        # excluded from timing by copying a live replica's times (uniform
        # hardware -> any live row; if none is fully live, rows are already
        # per-stage correct since peers cover the dead cells)
        live_rows = [d for d in range(view.dp) if view.alive[d].all()]
        if live_rows:
            for d in range(view.dp):
                if not view.alive[d].all():
                    fwd[d] = list(fwd[live_rows[0]])
                    bwd[d] = list(bwd[live_rows[0]])
        step, _ = simulate_dp_pp(fwd, bwd, view.num_micro,
                                 extra_micro=extra)
        return Decision(self.name, step, not oom,
                        {"extra_micro": dict(extra), "oom": oom, "mbs": mbs})


class OobleckPolicy:
    """Oobleck-style pipeline-template fallback (PAPERS.md).

    For each surviving-stage count k the policy precomputes (and caches) a
    minimax layer partition of all L layers over k stages — the "pipeline
    template".  A replica that lost ranks is re-instantiated on its k
    surviving workers from template[k], so its capacity is kept (unlike
    TorchFT, which drops the replica) at the price of a deeper-stage,
    higher-latency pipeline.  Replicas whose template is memory-infeasible
    are dropped; survivors re-split the global batch TorchFT-style.
    """
    name = "oobleck"

    def __init__(self, hw: Optional[HardwareSpec] = None):
        self.hw = hw or HardwareSpec()
        self._templates: Dict[Tuple, object] = {}

    def _template(self, seg: SegmentCosts, view: ClusterView, k: int, mbs: int):
        key = (id(seg.cfg), view.seq, k, mbs, view.mem_cap,
               min(k, view.num_micro))
        plan = self._templates.get(key)
        if plan is None:
            L = seg.cfg.num_layers

            def t(p, a, b):
                return mini_step_time(seg, a, b, mbs, hw=self.hw)

            def mem(p, a, b):
                return seg.seg_mem(a, b, mbs,
                                   inflight=min(k, view.num_micro), dp_size=1)

            plan = minimax_layer_partition(L, k, t, mem, [view.mem_cap] * k)
            self._templates[key] = plan
        return plan

    def decide(self, seg: SegmentCosts, view: ClusterView) -> Decision:
        k_rep = view.replica_width()                            # [dp]
        mbs = max(1, view.global_batch // (view.num_micro * view.dp))
        ks = [int(k) for k in np.unique(k_rep[k_rep > 0])]
        tmpl = {k: self._template(seg, view, k, mbs) for k in ks}
        feasible_ks = [k for k in ks if tmpl[k].feasible]
        live = (k_rep > 0) & np.isin(k_rep, feasible_ks)
        n = int(live.sum())
        if n == 0:
            return Decision(self.name, float("inf"), False, {"alive_reps": 0})
        num_micro_n = -(-view.global_batch // (mbs * n))
        # each live replica runs template[k] on its survivors, slowed by its
        # worst straggler / slowest clock; distinct (k, slow, freq) configs
        # are simulated once (at scale: a handful).
        rep_slow = np.where(view.alive, view.slow, 1.0).max(axis=1, initial=1.0)
        rep_freq = np.where(view.alive, view.freq, np.inf).min(axis=1,
                                                               initial=np.inf)
        triples = np.stack([k_rep.astype(np.float64), rep_slow, rep_freq],
                           axis=1)[live]
        times = []
        for k, s, f in np.unique(triples, axis=0):
            ranges = tmpl[int(k)].stage_ranges
            eff = self.hw.peak_flops * self.hw.mfu * f / s
            st = [StageTiming(seg.seg_fwd_flops(a, b, mbs) / eff,
                              2 * seg.seg_fwd_flops(a, b, mbs) / eff,
                              num_micro_n) for a, b in ranges]
            times.append(simulate_1f1b(st).step_time)
        return Decision(self.name, max(times), True,
                        {"alive_reps": n, "mbs": mbs, "num_micro": num_micro_n,
                         "templates": {k: tmpl[k].layers_per_stage
                                       for k in feasible_ks},
                         "dropped_reps": int((k_rep > 0).sum()) - n,
                         "wasted_ranks": int(view.alive.sum()
                                             - k_rep[live].sum())})


class ElasWavePolicy:
    name = "elaswave"

    def __init__(self, hw: Optional[HardwareSpec] = None, use_dvfs: bool = True,
                 use_migration: bool = True, pipeline_v: int = 1):
        self.hw = hw or HardwareSpec()
        self.use_dvfs = use_dvfs
        self.use_migration = use_migration
        self.pipeline_v = pipeline_v     # >1: interleaved-1F1B virtual stages

    def decide(self, seg: SegmentCosts, view: ClusterView) -> Decision:
        L = seg.cfg.num_layers
        P = view.pp
        # per-stage surviving DP width (one reduction, not a dp loop)
        width_v = view.stage_width()
        width = [int(w) for w in width_v]
        if min(width) == 0:
            return Decision(self.name, float("inf"), False, {"stage_lost": True})
        # 1) dataflow: per-stage micro-batch sizes (failed rank's share spread)
        per_micro = view.global_batch // view.num_micro
        mbs_stage = [int(m) for m in np.ceil(per_micro / width_v)]

        # 2) graph: minimax layer re-partition under memory caps.
        # Per-stage straggler factors enter the cost (a slow stage should
        # receive FEWER layers — fail-slow mitigation via migration).
        slow_stage = view.stage_slow()

        def t(p, a, b):
            return mini_step_time(seg, a, b, mbs_stage[p], hw=self.hw) \
                * slow_stage[p]

        def mem(p, a, b):
            return seg.seg_mem(a, b, mbs_stage[p], inflight=min(P, view.num_micro),
                               dp_size=width[p])

        if self.use_migration:
            plan = minimax_layer_partition(L, P, t, mem,
                                           [view.mem_cap] * P)
            if not plan.feasible:
                return Decision(self.name, float("inf"), False, {"mem_infeasible": True})
            assignment = list(plan.stage_ranges)
        else:
            assignment = list(view.layer_assignment)

        # 3) DVFS: up-clock residual stragglers to match the best stage time
        freq = view.freq.copy()
        base_times = []
        for p, (a, b) in enumerate(assignment):
            eff = self.hw.peak_flops * self.hw.mfu / slow_stage[p]
            fl = seg.seg_fwd_flops(a, b, mbs_stage[p])
            base_times.append(3 * fl / eff)
        target = min(base_times)
        dvfs_detail = []
        if self.use_dvfs:
            for p in range(P):
                if base_times[p] <= target * 1.001:
                    continue

                def obs(f, p=p):
                    return base_times[p] / f

                dplan = plan_dvfs(obs, 1.0, self.hw.max_freq, target,
                                  eps=0.02 * target, df_min=0.01, rank=p)
                freq[:, p] = np.maximum(freq[:, p], dplan.freq)
                base_times[p] = base_times[p] / dplan.freq
                dvfs_detail.append((p, round(dplan.freq, 3), dplan.status))

        # evaluate: stage p runs with its own width/mbs; replicas sync on DP
        # all-reduce — simulate one "effective" pipeline with per-stage times
        stage_freq = np.where(view.alive, freq, 0.0).max(axis=0)
        stages = []
        for p, (a, b) in enumerate(assignment):
            eff = (self.hw.peak_flops * self.hw.mfu * stage_freq[p]
                   / slow_stage[p])
            fl = seg.seg_fwd_flops(a, b, mbs_stage[p])
            stages.append(StageTiming(fl / eff, 2 * fl / eff, view.num_micro))
        if self.pipeline_v > 1:
            from .pipeline import simulate_interleaved_1f1b
            step = simulate_interleaved_1f1b(stages, v=self.pipeline_v).step_time
        else:
            step = simulate_1f1b(stages).step_time
        return Decision(self.name, step, True,
                        {"assignment": assignment, "mbs_stage": mbs_stage,
                         "dvfs": dvfs_detail, "width": width})
