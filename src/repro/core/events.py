"""Elastic events (paper §3.1): fail-stop, fail-slow, scheduler scale signals.

This is the single event vocabulary shared by the Agent (which *detects*
events), the ScheduleEngine (which *plans* around them), the VirtualCluster
(which *executes* the plans) and the scenario engine in
``repro.scenarios`` (which *injects* them from declarative traces).

Beyond the paper's four first-class kinds, four further kinds exist:

* ``DVFS_SET``  — an external frequency setpoint (e.g. power capping or a
                  scenario absorbing a straggler by up-clocking peers);
* ``MIGRATE``   — a scheduler-directed layer migration between two stages,
                  used by MTTR micro-benchmarks to meter the migration path
                  in isolation;
* ``PREEMPT_NOTICE`` — a scheduler *advance warning* (spot two-minute
                  notice): the named ranks WILL be preempted ``deadline``
                  seconds after the event step.  Liveness-wise it is a
                  shrink (the rank is lost either way); the proactive
                  executor drains the rank — snapshot flush + verified
                  remap + layer migration — inside the notice window, so
                  most of the recovery overlaps with ongoing training
                  instead of stalling it after the fail-stop lands;
* ``OOM_RISK``  — an Agent-emitted early warning that a rank's memory
                  trend will cross its capacity soon.  Advisory: it alters
                  no liveness and executors treat it as a zero-cost record.

An event may name *several* ranks (``ranks`` tuple): the scenario engine
uses this to express concurrent failure bursts, which executors apply as a
deterministic rank-ordered sequence of single-rank recoveries.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional, Tuple


class EventKind(enum.Enum):
    FAIL_STOP = "fail_stop"
    FAIL_SLOW = "fail_slow"
    SCALE_IN = "scale_in"       # scheduler-driven preemption
    SCALE_OUT = "scale_out"     # new resources granted
    DVFS_SET = "dvfs_set"       # injected frequency setpoint (perturbation)
    MIGRATE = "migrate"         # directed layer migration (perturbation)
    PREEMPT_NOTICE = "preempt_notice"   # advance warning of a preemption
    OOM_RISK = "oom_risk"       # agent-emitted pre-OOM early warning


@dataclasses.dataclass(frozen=True)
class ElasticEvent:
    kind: EventKind
    step: int
    ranks: Tuple[int, ...]                 # affected global ranks
    slow_factor: float = 1.0               # >1 for FAIL_SLOW (time multiplier)
    detail: str = ""
    freq: float = 1.0                      # DVFS_SET target frequency
    layers: Tuple[int, ...] = ()           # MIGRATE: layer ids to move
    src_stage: int = 0                     # MIGRATE: source stage
    dst_stage: int = 1                     # MIGRATE: destination stage
    deadline: float = 120.0                # PREEMPT_NOTICE: seconds of warning

    @property
    def is_shrink(self) -> bool:
        return self.kind in (EventKind.FAIL_STOP, EventKind.SCALE_IN,
                             EventKind.PREEMPT_NOTICE)

    @property
    def is_grow(self) -> bool:
        return self.kind == EventKind.SCALE_OUT

    def describe(self) -> str:
        base = f"{self.kind.value}@{self.step} ranks={list(self.ranks)}"
        if self.kind == EventKind.FAIL_SLOW:
            base += f" x{self.slow_factor:g}"
        if self.kind == EventKind.DVFS_SET:
            base += f" f={self.freq:g}"
        if self.kind == EventKind.MIGRATE:
            base += (f" layers={list(self.layers)} "
                     f"{self.src_stage}->{self.dst_stage}")
        if self.kind == EventKind.PREEMPT_NOTICE:
            base += f" deadline={self.deadline:g}s"
        return base


def burst(kind: EventKind, step: int, ranks: Tuple[int, ...], **kw) -> ElasticEvent:
    """A concurrent multi-rank event (e.g. a whole node or switch domain
    failing at once).  Executors apply the ranks in ascending order so burst
    recovery is deterministic."""
    return ElasticEvent(kind, step, tuple(sorted(ranks)), **kw)
