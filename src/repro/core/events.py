"""Elastic events (paper §3.1): fail-stop, fail-slow, scheduler scale signals."""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional, Tuple


class EventKind(enum.Enum):
    FAIL_STOP = "fail_stop"
    FAIL_SLOW = "fail_slow"
    SCALE_IN = "scale_in"       # scheduler-driven preemption
    SCALE_OUT = "scale_out"     # new resources granted


@dataclasses.dataclass(frozen=True)
class ElasticEvent:
    kind: EventKind
    step: int
    ranks: Tuple[int, ...]                 # affected global ranks
    slow_factor: float = 1.0               # >1 for FAIL_SLOW (time multiplier)
    detail: str = ""

    @property
    def is_shrink(self) -> bool:
        return self.kind in (EventKind.FAIL_STOP, EventKind.SCALE_IN)
