"""Mini-step cost model — paper Eq. (1) — and the stage memory model.

    T_i = T^Cf + T^Cb + [T^P2Pf - sigma_f T^Cf]_+ + [T^P2Pb - sigma_b T^Cb]_+

Compute terms come from analytic per-layer FLOPs (profiled offline in the
paper; analytic here — same role), scaled by device frequency.  P2P terms are
activation/grad bytes over link bandwidth, parameterized by neighbor ranks
(fan-in/out contention).  Segment costs t_p([a..b]) and Mem[a..b] are
precomputed prefix sums so the Alg.1 DP solver queries them in O(1).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.models.config import ATTN, ATTN_MOE, MAMBA, MAMBA_MOE, ModelConfig


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    peak_flops: float = 197e12          # bf16 FLOP/s per chip (TPU v5e)
    hbm_bw: float = 819e9               # bytes/s
    link_bw: float = 50e9               # bytes/s per ICI link
    hbm_bytes: float = 16e9             # per-chip HBM capacity
    mfu: float = 0.45                   # achievable fraction of peak (profiled)
    base_freq: float = 1.0              # normalized frequency
    max_freq: float = 1.178             # 1650/1400 MHz, paper's testbed ratio


def layer_flops(cfg: ModelConfig, layer_idx: int, tokens: int) -> float:
    """Forward FLOPs of one layer for `tokens` tokens (bwd ~ 2x fwd)."""
    from repro.models.registry import flat_layer_types
    blk = flat_layer_types(cfg)[layer_idx]
    d = cfg.d_model
    f = 0.0
    if blk in (ATTN, ATTN_MOE):
        if cfg.use_mla:
            qdim = cfg.num_heads * (cfg.qk_nope_dim + cfg.qk_rope_dim)
            f += 2 * tokens * d * (cfg.q_lora_rank or qdim)
            if cfg.q_lora_rank:
                f += 2 * tokens * cfg.q_lora_rank * qdim
            f += 2 * tokens * d * (cfg.kv_lora_rank + cfg.qk_rope_dim)
            f += 2 * tokens * cfg.kv_lora_rank * cfg.num_heads * (cfg.qk_nope_dim + cfg.v_head_dim)
            f += 2 * tokens * cfg.num_heads * cfg.v_head_dim * d
        else:
            hd = cfg.head_dim
            f += 2 * tokens * d * (cfg.num_heads + 2 * cfg.num_kv_heads) * hd
            f += 2 * tokens * cfg.num_heads * hd * d
        # attention scores+values: 2 * 2 * tokens * seq * H * hd  (causal ~ /2)
        # tokens here = mbs*seq so use seq from cfg context: approximate with
        # quadratic term folded via avg seq — callers pass tokens=mbs*seq and
        # we add attn quadratic separately in segment_costs.
    else:
        di, ds, ng = cfg.d_inner, cfg.ssm_state, cfg.ssm_ngroups
        f += 2 * tokens * d * (2 * di + 2 * ng * ds + cfg.ssm_heads)
        f += 2 * tokens * di * d
        f += 2 * tokens * di * ds * 2      # SSD state update + output (linear)
    if blk in (ATTN_MOE, MAMBA_MOE):
        act = cfg.top_k + cfg.num_shared_experts
        mats = 2 if cfg.activation == "relu2" else 3
        f += 2 * tokens * act * mats * d * cfg.moe_d_ff
        f += 2 * tokens * d * cfg.num_experts  # router
    elif cfg.d_ff > 0:
        mats = 2 if cfg.activation == "relu2" else 3
        f += 2 * tokens * mats * d * cfg.d_ff
    return f


def attn_quadratic_flops(cfg: ModelConfig, layer_idx: int, mbs: int, seq: int) -> float:
    from repro.models.registry import flat_layer_types
    blk = flat_layer_types(cfg)[layer_idx]
    if blk in (ATTN, ATTN_MOE):
        hd = cfg.v_head_dim if cfg.use_mla else cfg.head_dim
        qk = (cfg.qk_nope_dim + cfg.qk_rope_dim) if cfg.use_mla else cfg.head_dim
        return 2 * mbs * cfg.num_heads * seq * seq * (qk + hd) / 2  # causal
    return 0.0


def layer_param_bytes(cfg: ModelConfig, layer_idx: int, dtype_bytes: int = 2) -> float:
    from repro.models.registry import flat_layer_types
    blk = flat_layer_types(cfg)[layer_idx]
    return cfg._block_params(blk) * dtype_bytes


def layer_opt_bytes(cfg: ModelConfig, layer_idx: int) -> float:
    """Mixed-precision Adam: fp32 master + mu + nu = 12 B/param."""
    from repro.models.registry import flat_layer_types
    blk = flat_layer_types(cfg)[layer_idx]
    return cfg._block_params(blk) * 12


def activation_bytes(cfg: ModelConfig, mbs: int, seq: int, dtype_bytes: int = 2) -> float:
    """Boundary activation (what P2P ships between stages)."""
    return mbs * seq * cfg.d_model * dtype_bytes


def layer_act_footprint(cfg: ModelConfig, layer_idx: int, mbs: int, seq: int,
                        dtype_bytes: int = 2) -> float:
    """Stored activation per layer per in-flight micro-batch (w/ recompute of
    attention internals — store ~4 d_model-wide tensors per layer)."""
    return 4 * mbs * seq * cfg.d_model * dtype_bytes


@dataclasses.dataclass
class SegmentCosts:
    """Precomputed prefix sums for Alg.1 O(1) segment queries.

    Prefix sums are memoized (computed once, reused by every scalar *and*
    vectorized query), and the ``*_vec`` methods accept layer-index arrays so
    the planners and policies price all P stages in one array op — the
    ``IntervalTable`` idiom from ``core.statespace`` applied to the cost
    model.  Scalar queries keep the seed's exact arithmetic; the memoized
    cumsum is the same computation the seed re-ran per call, so results are
    bit-identical.
    """
    cfg: ModelConfig
    seq: int
    hw: HardwareSpec
    fwd_flops: np.ndarray           # [L] per-layer fwd FLOPs for 1 sample
    param_bytes: np.ndarray         # [L]
    opt_bytes: np.ndarray           # [L]

    @classmethod
    def build(cls, cfg: ModelConfig, seq: int, hw: HardwareSpec) -> "SegmentCosts":
        L = cfg.num_layers
        fwd = np.array([layer_flops(cfg, i, seq) +
                        attn_quadratic_flops(cfg, i, 1, seq) for i in range(L)])
        pb = np.array([layer_param_bytes(cfg, i) for i in range(L)])
        ob = np.array([layer_opt_bytes(cfg, i) for i in range(L)])
        return cls(cfg, seq, hw, fwd, pb, ob)

    def _pre(self, arr):
        key = id(arr)
        cache = getattr(self, "_pre_cache", None)
        if cache is None:
            cache = {}
            object.__setattr__(self, "_pre_cache", cache)
        out = cache.get(key)
        if out is None:
            out = np.concatenate([[0.0], np.cumsum(arr)])
            out.setflags(write=False)
            cache[key] = out
        return out

    def seg_fwd_flops(self, a: int, b: int, mbs: int) -> float:
        """Layers [a..b] inclusive, 0-indexed."""
        c = self._pre(self.fwd_flops)
        return mbs * (c[b + 1] - c[a])

    def seg_fwd_flops_vec(self, a: np.ndarray, b: np.ndarray, mbs) -> np.ndarray:
        """Vector form of :meth:`seg_fwd_flops` — ``a``/``b``/``mbs`` broadcast;
        per-element arithmetic identical to the scalar path."""
        c = self._pre(self.fwd_flops)
        a = np.asarray(a, dtype=np.int64)
        b = np.asarray(b, dtype=np.int64)
        return np.asarray(mbs) * (c[b + 1] - c[a])

    def seg_mem(self, a: int, b: int, mbs: int, inflight: int,
                dp_size: int = 1) -> float:
        """params + ZeRO-sharded optimizer + activations for layers [a..b]."""
        pb = self._pre(self.param_bytes)
        ob = self._pre(self.opt_bytes)
        acts = sum(layer_act_footprint(self.cfg, i, mbs, self.seq)
                   for i in range(a, b + 1)) * inflight
        return (pb[b + 1] - pb[a]) + (ob[b + 1] - ob[a]) / max(dp_size, 1) + acts

    def seg_mem_vec(self, a: np.ndarray, b: np.ndarray, mbs, inflight,
                    dp_size=1) -> np.ndarray:
        """Vector form of :meth:`seg_mem`.  The activation term uses
        ``count * footprint`` instead of the scalar path's repeated addition
        (can differ in the last ULP); use only in vectorized contexts — the
        scalar path stays the comparison oracle."""
        pb = self._pre(self.param_bytes)
        ob = self._pre(self.opt_bytes)
        a = np.asarray(a, dtype=np.int64)
        b = np.asarray(b, dtype=np.int64)
        foot = layer_act_footprint(self.cfg, 0, 1, self.seq)  # layer-uniform
        acts = (b - a + 1) * (foot * np.asarray(mbs)) * np.asarray(inflight)
        return ((pb[b + 1] - pb[a])
                + (ob[b + 1] - ob[a]) / np.maximum(np.asarray(dp_size), 1)
                + acts)


def mini_step_time(seg: SegmentCosts, a: int, b: int, mbs: int,
                   freq: float = 1.0, sigma_f: float = 0.7, sigma_b: float = 0.7,
                   neighbor_ranks: int = 1, hw: Optional[HardwareSpec] = None) -> float:
    """Paper Eq.(1) for one stage holding layers [a..b] with micro-batch mbs."""
    hw = hw or seg.hw
    eff = hw.peak_flops * hw.mfu * freq
    t_cf = seg.seg_fwd_flops(a, b, mbs) / eff
    t_cb = 2.0 * t_cf
    p2p = activation_bytes(seg.cfg, mbs, seg.seq) / (hw.link_bw / max(neighbor_ranks, 1))
    t_f = t_cf + max(0.0, p2p - sigma_f * t_cf)
    t_b = t_cb + max(0.0, p2p - sigma_b * t_cb)
    return t_f + t_b


def mini_step_time_vec(seg: SegmentCosts, a, b, mbs, freq=1.0,
                       sigma_f: float = 0.7, sigma_b: float = 0.7,
                       neighbor_ranks=1,
                       hw: Optional[HardwareSpec] = None) -> np.ndarray:
    """Eq.(1) over stage vectors: ``a``/``b``/``mbs``/``freq``/
    ``neighbor_ranks`` broadcast (typically ``[P]`` arrays), one array op for
    the whole pipeline.  Per-element arithmetic matches the scalar
    :func:`mini_step_time` exactly (same operation order), so vectorized
    policies reproduce the per-stage loop bit-for-bit."""
    hw = hw or seg.hw
    eff = hw.peak_flops * hw.mfu * np.asarray(freq, dtype=np.float64)
    t_cf = seg.seg_fwd_flops_vec(a, b, np.asarray(mbs)) / eff
    t_cb = 2.0 * t_cf
    p2p = ((np.asarray(mbs) * seg.seq * seg.cfg.d_model * 2)
           / (hw.link_bw / np.maximum(np.asarray(neighbor_ranks), 1)))
    t_f = t_cf + np.maximum(0.0, p2p - sigma_f * t_cf)
    t_b = t_cb + np.maximum(0.0, p2p - sigma_b * t_cb)
    return t_f + t_b
