"""Schedule Engine (paper §4): elastic event -> executable RecoveryPlan.

Jointly decides the four axes — Dataflow, Graph, DVFS, RNG — under per-stage
memory-capacity checks, and attaches the data-plane actions (communicator
edits, live-remap transfer plan, migration specs) so the Recovery Executor
(``VirtualCluster.apply_plan``) can run it without further decisions.  The
scenario engine (``repro.scenarios``) drives this plan/apply pair from
declarative event traces; see docs/ARCHITECTURE.md for the full path.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .cost_model import HardwareSpec, SegmentCosts, mini_step_time
from .events import ElasticEvent, EventKind
from .planners.dataflow import DataflowPlan, plan_dataflow
from .planners.graph import GraphPlan, minimax_layer_partition
from .planners.dvfs import DvfsPlan, plan_dvfs, plan_dvfs_stages
from .planners.rng import RngPlan, plan_rng_reshard


@dataclasses.dataclass
class RecoveryPlan:
    event: ElasticEvent
    dataflow: DataflowPlan
    graph: GraphPlan
    dvfs: List[DvfsPlan]
    rng: RngPlan
    new_dp: int
    migrations: List[Tuple[int, int, int]]   # (layer_id, src_stage, dst_stage)
    capacity_ok: bool
    plan_seconds: float = 0.0                # planner wall time (MTTR itemization)


class ScheduleEngine:
    def __init__(self, cfg, seq: int, hw: Optional[HardwareSpec] = None,
                 mem_cap: Optional[float] = None):
        self.cfg = cfg
        self.hw = hw or HardwareSpec()
        self.seg = SegmentCosts.build(cfg, seq, self.hw)
        self.mem_cap = mem_cap if mem_cap is not None else self.hw.hbm_bytes

    def plan_view(self, event: ElasticEvent, view, *,
                  failed_dp_ranks: Sequence[int],
                  old_sample_rank: Optional[Dict[int, int]] = None,
                  dp: Optional[int] = None,
                  use_slow: bool = False) -> RecoveryPlan:
        """Plan directly from a shared ``core.clusterview.ClusterView``:
        per-stage widths and straggler factors come from the view's array
        reductions, so callers stop re-deriving rank membership per planner.
        ``dp`` overrides the pre-event replica count when the view's static
        ``dp`` no longer reflects it (ranks already removed earlier)."""
        return self.plan(
            event, dp=dp if dp is not None else view.dp, pp=view.pp,
            global_batch=view.global_batch, num_micro=view.num_micro,
            layer_assignment=view.layer_assignment,
            failed_dp_ranks=list(failed_dp_ranks),
            old_sample_rank=old_sample_rank or {},
            stage_widths=[int(w) for w in view.stage_width()],
            slow=view.stage_slow() if use_slow else None)

    def plan(self, event: ElasticEvent, *, dp: int, pp: int,
             global_batch: int, num_micro: int,
             layer_assignment: Sequence[Tuple[int, int]],
             failed_dp_ranks: Sequence[int],
             old_sample_rank: Dict[int, int],
             stage_widths: Optional[Sequence[int]] = None,
             freqs: Optional[Sequence[float]] = None,
             slow: Optional[Sequence[float]] = None) -> RecoveryPlan:
        import time as _time
        t0 = _time.perf_counter()
        L = self.cfg.num_layers
        new_dp = dp - len(failed_dp_ranks) if event.is_shrink else \
            dp + len(event.ranks)
        assert new_dp >= 1

        # --- Dataflow ---
        df = plan_dataflow(global_batch, num_micro, new_dp)
        per_micro = global_batch // num_micro
        if stage_widths is None:
            stage_widths = [new_dp] * pp
        # per-stage micro-batch size after resizing on that stage's DP width
        mbs_stage = [-(-per_micro // max(w, 1)) for w in stage_widths]
        mbs = max(df.micro_batch_sizes)

        # --- Graph (minimax repartition under memory caps) ---
        def t(p, a, b):
            return mini_step_time(self.seg, a, b, mbs_stage[p], hw=self.hw)

        def mem(p, a, b):
            return self.seg.seg_mem(a, b, mbs_stage[p],
                                    inflight=min(pp, num_micro),
                                    dp_size=max(stage_widths[p], 1))

        graph = minimax_layer_partition(L, pp, t, mem, [self.mem_cap] * pp)
        capacity_ok = graph.feasible
        if not graph.feasible:
            graph = GraphPlan((), tuple(layer_assignment), float("inf"), False)

        # --- migrations: diff old vs new assignment ---
        old_stage = _stage_of(layer_assignment, L)
        new_stage = _stage_of(graph.stage_ranges, L) if graph.feasible else old_stage
        migrations = [(lid, old_stage[lid], new_stage[lid])
                      for lid in range(L) if old_stage[lid] != new_stage[lid]]

        # --- DVFS: align residual stragglers to fastest stage ---
        dvfs_plans: List[DvfsPlan] = []
        if graph.feasible:
            times = []
            for p, (a, b) in enumerate(graph.stage_ranges):
                s = (slow[p] if slow is not None and len(slow) else 1.0)
                times.append(t(p, a, b) * s)
            dvfs_plans = list(plan_dvfs_stages(times, self.hw.max_freq))

        # --- RNG resharding ---
        new_sample_rank = _sample_assignment(df, old_sample_rank)
        rng = plan_rng_reshard(old_stage, new_stage, old_sample_rank,
                               new_sample_rank)

        return RecoveryPlan(event, df, graph, dvfs_plans, rng, new_dp,
                            migrations, capacity_ok,
                            plan_seconds=_time.perf_counter() - t0)


def _stage_of(ranges: Sequence[Tuple[int, int]], L: int) -> List[int]:
    out = [0] * L
    for p, (a, b) in enumerate(ranges):
        for l in range(a, b + 1):
            out[l] = p
    return out


def _sample_assignment(df: DataflowPlan, old: Dict[int, int]) -> Dict[int, int]:
    """Re-slice sample slots [0, per_micro) among new ranks, contiguous."""
    new: Dict[int, int] = {}
    cursor = 0
    for r, sz in enumerate(df.micro_batch_sizes):
        for _ in range(sz):
            if cursor in old or not old:
                new[cursor] = r
            cursor += 1
    # keep keys aligned with old when old provided
    if old:
        new = {sid: new.get(sid, old[sid]) for sid in old}
    return new
