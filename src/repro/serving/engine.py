"""Continuous-batching serving engine on the elastic recovery fabric.

One :class:`ServingEngine` = an admission queue + a set of serving replicas,
each holding a slot-indexed KV pool (``serving/kvcache.py``).  Scheduling is
iteration-level continuous batching: every tick admits queued requests into
free slots (SLO-aware — reject when the projected TTFT is already blown,
defer when the marginal per-token latency would blow the budget), runs the
admitted requests' prefills, and runs ONE batched decode step over every
other in-flight slot.  The simulated clock advances by a deterministic cost
model, so latency metrics are replayable; token *values* come from real
model numerics (``mode="numeric"``) or a deterministic stub
(``mode="synthetic"`` — trace-scale scheduling runs).

Elastic events from ``core/events.py`` hit :meth:`apply_event`: replica
SCALE_IN / FAIL_STOP triggers KV-cache migration or prefix rebuild instead of
request loss (policy-controlled, ``serving/policies.py``), SCALE_OUT adds a
replica, FAIL_SLOW / DVFS_SET retime one.  Replica health is tracked by the
same ``core.agent.Agent`` the training plane uses, exercising its dynamic
``add_rank``/``remove_rank`` registration.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Deque, Dict, List, Optional, Sequence

import numpy as np

from repro.core.agent import Agent, Probe
from repro.core.events import ElasticEvent, EventKind

from .kvcache import KVPool, migrate_slot, slot_kv_bytes
from .policies import (DROP, MIGRATE, REBUILD, ElasWaveServePolicy,
                       ServeRecoveryPolicy)
from .request import Request, RequestState, SLO
from .sampling import SamplerConfig, sample_tokens


@dataclasses.dataclass(frozen=True)
class ServeCostModel:
    """Deterministic iteration timing (simulated seconds)."""
    decode_base: float = 0.015        # fixed cost of a decode iteration
    decode_per_slot: float = 0.004    # marginal cost per batched slot
    prefill_per_token: float = 0.0015
    kv_bw_bytes: float = 2e9          # migration bandwidth (bytes/s)
    detect_seconds: float = 0.5       # fail-stop detection bound
    idle_quantum: float = 0.05

    def decode_seconds(self, n_slots: int) -> float:
        return self.decode_base + self.decode_per_slot * n_slots if n_slots \
            else 0.0

    def prefill_seconds(self, n_tokens: int) -> float:
        return self.prefill_per_token * n_tokens

    def migration_seconds(self, nbytes: int) -> float:
        return nbytes / self.kv_bw_bytes


@dataclasses.dataclass
class Replica:
    rid: int
    pool: KVPool
    slow: float = 1.0     # fail-slow multiplier (>= 1)
    freq: float = 1.0     # DVFS setpoint

    @property
    def time_factor(self) -> float:
        return self.slow / max(self.freq, 1e-6)


def _fake_token(rid: int, pos: int, vocab: int) -> int:
    """Synthetic-mode token stream: deterministic in (rid, pos) only, so it
    is invariant under migration by construction."""
    return (rid * 7919 + pos * 104729 + 17) % vocab


class ServingEngine:
    def __init__(self, cfg, *, n_replicas: int = 2, slots_per_replica: int = 4,
                 max_len: int = 64, mode: str = "numeric", params=None,
                 seed: int = 0, sampler: Optional[SamplerConfig] = None,
                 slo: Optional[SLO] = None,
                 cost: Optional[ServeCostModel] = None,
                 policy: Optional[ServeRecoveryPolicy] = None,
                 ranks_per_replica: int = 1):
        assert mode in ("numeric", "synthetic"), mode
        self.cfg = cfg
        self.mode = mode
        self.max_len = max_len
        self.slots_per_replica = slots_per_replica
        self.sampler = sampler or SamplerConfig()
        self.slo = slo or SLO()
        self.cost = cost or ServeCostModel()
        self.policy = policy or ElasWaveServePolicy()
        self.ranks_per_replica = max(int(ranks_per_replica), 1)
        self.seed = seed

        self.hooks = None
        self.params = None
        if mode == "numeric":
            import jax
            from repro.models import registry as R
            self.hooks = R.serving_hooks(cfg)
            self.params = params if params is not None else R.init_model(
                jax.random.key(seed), cfg)
            self._slot_bytes = slot_kv_bytes(cfg, max_len,
                                             self.hooks.init_caches)
        else:
            from repro.models import registry as R
            self._slot_bytes = slot_kv_bytes(cfg, max_len,
                                             R.serving_hooks(cfg).init_caches)

        self.replicas: Dict[int, Replica] = {}
        for rid in range(n_replicas):
            self.replicas[rid] = self._make_replica(rid)
        self.agent = Agent(num_ranks=n_replicas)

        self.clock = 0.0
        self.queue: Deque[Request] = deque()
        self.requests: Dict[int, Request] = {}
        self.event_log: List[Dict] = []
        self.detected: List[ElasticEvent] = []   # agent-raised (fail-slow)
        self.deferrals = 0
        self.tokens_decoded = 0
        self.ticks = 0

    # ------------------------------------------------------------------
    # replicas
    # ------------------------------------------------------------------
    def _make_replica(self, rid: int) -> Replica:
        caches = (self.hooks.init_caches(self.slots_per_replica, self.max_len)
                  if self.mode == "numeric" else None)
        pool = KVPool(self.slots_per_replica, caches,
                      slot_bytes=self._slot_bytes)
        return Replica(rid=rid, pool=pool)

    def alive_replicas(self) -> List[Replica]:
        return [self.replicas[r] for r in sorted(self.replicas)]

    @property
    def n_active(self) -> int:
        return sum(r.pool.n_active for r in self.replicas.values())

    @property
    def n_queued(self) -> int:
        return len(self.queue)

    # ------------------------------------------------------------------
    # submission / admission
    # ------------------------------------------------------------------
    def submit(self, req: Request):
        assert len(req.prompt) + req.max_new_tokens <= self.max_len, \
            "request does not fit the KV slot"
        self.requests[req.rid] = req
        self.queue.append(req)

    def _pick_replica(self) -> Optional[Replica]:
        """Most free slots, respecting the per-token SLO projection; ties go
        to the lowest replica id (determinism)."""
        best = None
        for rep in self.alive_replicas():
            if rep.pool.n_free == 0:
                continue
            proj = self.cost.decode_seconds(rep.pool.n_active + 1) \
                * rep.time_factor
            if proj > self.slo.per_token:
                continue
            if best is None or rep.pool.n_free > best.pool.n_free:
                best = rep
        return best

    def _admit(self) -> List[Request]:
        admitted: List[Request] = []
        while self.queue:
            req = self.queue[0]
            if req.arrival > self.clock:
                break
            prefix_len = len(req.prefix)
            # SLO admission: a request whose projected TTFT is already blown
            # can only get worse — reject it now (first admission only;
            # requeued in-flight requests are never rejected, that would be
            # a drop by another name).
            projected_ttft = (self.clock - req.arrival
                              + self.cost.prefill_seconds(prefix_len)
                              + self.cost.decode_seconds(self.n_active + 1))
            if req.prefills == 0 and projected_ttft > self.slo.ttft:
                self.queue.popleft()
                req.state = RequestState.REJECTED
                req.finish_time = self.clock
                continue
            rep = self._pick_replica()
            if rep is None:
                # defer: either no free slot anywhere, or admitting would
                # blow the per-token budget for in-flight requests
                self.deferrals += 1
                break
            self.queue.popleft()
            slots = rep.pool.free_slots()
            slot = slots[0]
            rep.pool.assign(slot, req.rid, length=0)   # length set at prefill
            req.state = RequestState.ACTIVE
            req.replica, req.slot = rep.rid, slot
            if req.admit_time is None:
                req.admit_time = self.clock
            req.prefills += 1
            admitted.append(req)
        return admitted

    # ------------------------------------------------------------------
    # numerics
    # ------------------------------------------------------------------
    def _prefill_one(self, req: Request) -> int:
        """Prefill the request's full prefix into its slot and sample the
        next token.  Returns the number of tokens prefilled."""
        rep = self.replicas[req.replica]
        prefix = req.prefix
        pos = len(prefix)                      # position of the sampled token
        if self.mode == "numeric":
            import jax.numpy as jnp
            caches1 = self.hooks.init_caches(1, self.max_len)
            extras1 = self.hooks.prepare_extras(self.params, req)
            logits, caches1 = self.hooks.prefill(
                self.params, jnp.asarray(prefix[None, :]), caches1, extras1)
            tok = int(sample_tokens(np.asarray(logits), [req.rid], [pos],
                                    self.sampler)[0])
            rep.pool.write(req.slot, caches1, extras1)
        else:
            tok = _fake_token(req.rid, pos, self.cfg.vocab_size)
        rep.pool.lengths[req.slot] = pos
        req.generated.append(tok)
        self.tokens_decoded += 1
        return len(prefix)

    def _decode_replica(self, rep: Replica, skip_rids: set) -> int:
        """One batched decode step over the replica's in-flight slots
        (excluding this tick's fresh prefills).  Returns slots decoded."""
        ids = [s for s in rep.pool.active_slots()
               if rep.pool.slot_req[s] not in skip_rids]
        ids = [s for s in ids
               if not self.requests[int(rep.pool.slot_req[s])].done]
        if not ids:
            return 0
        reqs = [self.requests[int(rep.pool.slot_req[s])] for s in ids]
        positions = rep.pool.lengths[ids]            # write index per slot
        sample_pos = [int(p) + 1 for p in positions]  # token being sampled
        if self.mode == "numeric":
            import jax.numpy as jnp
            from .kvcache import EXTRAS_AXIS, gather_slots, scatter_slots
            toks = jnp.asarray([[r.generated[-1]] for r in reqs],
                               dtype=jnp.int32)
            caches = gather_slots(rep.pool.caches, ids)
            extras = (gather_slots(rep.pool.extras, ids, axis=EXTRAS_AXIS)
                      if rep.pool.extras is not None else None)
            logits, caches = self.hooks.decode_step(
                self.params, toks, caches, jnp.asarray(positions,
                                                       dtype=jnp.int32),
                extras)
            rep.pool.caches = scatter_slots(rep.pool.caches, caches, ids)
            nxt = sample_tokens(np.asarray(logits), [r.rid for r in reqs],
                                sample_pos, self.sampler)
        else:
            nxt = [_fake_token(r.rid, p, self.cfg.vocab_size)
                   for r, p in zip(reqs, sample_pos)]
        for s, r, t in zip(ids, reqs, nxt):
            rep.pool.lengths[s] += 1
            r.generated.append(int(t))
            self.tokens_decoded += 1
        return len(ids)

    # ------------------------------------------------------------------
    # the tick
    # ------------------------------------------------------------------
    def tick(self) -> float:
        """One continuous-batching iteration; returns simulated seconds."""
        self.ticks += 1
        if not self.replicas:
            dt = self._idle_dt()
            self.clock += dt
            return dt
        admitted = self._admit()
        fresh = {r.rid for r in admitted}
        prefill_tokens: Dict[int, int] = {}
        for req in admitted:
            prefill_tokens[req.replica] = (prefill_tokens.get(req.replica, 0)
                                           + self._prefill_one(req))
        dt = 0.0
        for rep in self.alive_replicas():
            n = self._decode_replica(rep, fresh)
            pf = prefill_tokens.get(rep.rid, 0)
            if n or pf:
                rep_dt = (self.cost.decode_seconds(n)
                          + self.cost.prefill_seconds(pf)) * rep.time_factor
                dt = max(dt, rep_dt)
        if dt == 0.0:
            dt = self._idle_dt()
        self.clock += dt
        self._timestamp_and_retire(fresh)
        self._observe_health(dt)
        return dt

    def _idle_dt(self) -> float:
        """Nothing to compute: jump to the next arrival if one is pending."""
        future = [r.arrival for r in self.queue if r.arrival > self.clock]
        if future:
            return min(future) - self.clock
        return self.cost.idle_quantum

    def _timestamp_and_retire(self, fresh: set):
        del fresh
        for rep in self.alive_replicas():
            for s in rep.pool.active_slots():
                req = self.requests[int(rep.pool.slot_req[s])]
                if req.first_token_time is None and req.generated:
                    req.first_token_time = self.clock
                if req.done:
                    req.finish_time = self.clock
                    req.state = RequestState.DONE
                    rep.pool.release(s)
                    req.replica = req.slot = -1

    def _observe_health(self, dt: float):
        """Feed the training-plane Agent the serving replicas' heartbeats —
        the same probe protocol, replicas as ranks."""
        probes = [Probe(step=self.ticks, rank=rep.rid, heartbeat=True,
                        step_seconds=dt * rep.time_factor)
                  for rep in self.alive_replicas()]
        self.detected.extend(self.agent.observe(probes))

    def run_until(self, t_end: float, max_ticks: int = 2_000_000):
        """Advance the simulated clock to ``t_end``; idle spans (no active
        slots, no due arrivals) fast-forward instead of ticking, clamped to
        ``t_end`` so elastic events are applied at their trace time."""
        while self.clock < t_end and max_ticks:
            if self.n_active == 0 and \
                    not any(r.arrival <= self.clock for r in self.queue):
                future = [r.arrival for r in self.queue]
                self.clock = min(min(future) if future else t_end, t_end)
                if self.clock >= t_end:
                    break
                continue
            self.tick()
            max_ticks -= 1

    def drain(self, max_ticks: int = 100_000):
        """Run until every submitted request has left the system."""
        while max_ticks and (self.queue or self.n_active):
            self.tick()
            max_ticks -= 1
        assert not (self.queue or self.n_active), "drain did not converge"

    # ------------------------------------------------------------------
    # elastic events
    # ------------------------------------------------------------------
    def _event_replicas(self, ev: ElasticEvent) -> List[int]:
        return sorted({r // self.ranks_per_replica for r in ev.ranks})

    def apply_event(self, ev: ElasticEvent) -> Dict[str, Any]:
        """event -> plan (policy disposition) -> apply: the serving side of
        the paper's recovery path.  Returns the per-event stats record."""
        stats = {"t": self.clock, "kind": ev.kind.value,
                 "replicas": self._event_replicas(ev),
                 "policy": self.policy.name, "migrated": 0, "rebuilt": 0,
                 "dropped": 0, "kv_bytes_moved": 0, "stall_seconds": 0.0}
        if ev.kind == EventKind.SCALE_OUT:
            for rid in stats["replicas"]:
                if rid not in self.replicas:
                    self.replicas[rid] = self._make_replica(rid)
                    self.agent.add_rank(rid)
        elif ev.kind in (EventKind.SCALE_IN, EventKind.FAIL_STOP):
            for rid in stats["replicas"]:
                if rid in self.replicas:
                    self._remove_replica(rid, ev, stats)
            if ev.kind == EventKind.FAIL_STOP:
                stats["stall_seconds"] += self.cost.detect_seconds
        elif ev.kind == EventKind.FAIL_SLOW:
            for rid in stats["replicas"]:
                if rid in self.replicas:
                    self.replicas[rid].slow = max(
                        self.replicas[rid].slow, ev.slow_factor)
        elif ev.kind == EventKind.DVFS_SET:
            for rid in stats["replicas"]:
                if rid in self.replicas:
                    self.replicas[rid].freq = ev.freq
        else:
            raise ValueError(f"unsupported serving event kind: {ev.kind}")
        self.clock += stats["stall_seconds"]
        self.event_log.append(stats)
        return stats

    def _remove_replica(self, rid: int, ev: ElasticEvent, stats: Dict):
        rep = self.replicas.pop(rid)
        self.agent.remove_rank(rid)
        disposition = self.policy.disposition(ev)
        requeue: List[Request] = []
        for s in rep.pool.active_slots():
            req = self.requests[int(rep.pool.slot_req[s])]
            action = disposition
            if action == MIGRATE:
                dst = self._pick_migration_target()
                if dst is None:
                    action = REBUILD       # no survivor capacity: rebuild
                else:
                    dslot = dst.pool.free_slots()[0]
                    stats["kv_bytes_moved"] += migrate_slot(
                        rep.pool, s, dst.pool, dslot, req.rid)
                    req.replica, req.slot = dst.rid, dslot
                    req.migrations += 1
                    stats["migrated"] += 1
                    continue
            if action == REBUILD:
                rep.pool.release(s)
                req.state = RequestState.QUEUED
                req.replica = req.slot = -1
                req.migrations += 1
                requeue.append(req)
                stats["rebuilt"] += 1
            elif action == DROP:
                rep.pool.release(s)
                req.state = RequestState.DROPPED
                req.finish_time = self.clock
                req.replica = req.slot = -1
                stats["dropped"] += 1
        # requeued in-flight requests go to the FRONT (oldest first) so the
        # rebuild is not starved by fresh arrivals
        for req in reversed(requeue):
            self.queue.appendleft(req)
        stats["stall_seconds"] += self.cost.migration_seconds(
            stats["kv_bytes_moved"])

    def _pick_migration_target(self) -> Optional[Replica]:
        best = None
        for rep in self.alive_replicas():
            if rep.pool.n_free == 0:
                continue
            if best is None or rep.pool.n_free > best.pool.n_free:
                best = rep
        return best

    # ------------------------------------------------------------------
    # metrics
    # ------------------------------------------------------------------
    def summary(self) -> Dict[str, Any]:
        reqs = list(self.requests.values())
        done = [r for r in reqs if r.state == RequestState.DONE]
        ttfts = np.array([r.ttft for r in done if r.ttft is not None])
        ptls = np.array([r.per_token_latency for r in done
                         if r.per_token_latency is not None])
        slo_ok = [r for r in done if r.meets(self.slo)]
        horizon = max(self.clock, 1e-9)

        def pct(a, q):
            return float(np.percentile(a, q)) if len(a) else None

        return {
            "policy": self.policy.name,
            "sampler": self.sampler.describe(),
            "n_requests": len(reqs),
            "completed": len(done),
            "dropped": sum(r.state == RequestState.DROPPED for r in reqs),
            "rejected": sum(r.state == RequestState.REJECTED for r in reqs),
            "in_flight_at_end": self.n_active + self.n_queued,
            "deferrals": self.deferrals,
            "migrations": sum(r.migrations for r in reqs),
            "re_prefills": sum(max(r.prefills - 1, 0) for r in reqs),
            "tokens_decoded": self.tokens_decoded,
            "ttft_p50": pct(ttfts, 50), "ttft_p99": pct(ttfts, 99),
            "per_token_p50": pct(ptls, 50), "per_token_p99": pct(ptls, 99),
            "slo_attainment": len(slo_ok) / len(done) if done else None,
            "goodput_tokens_per_s":
                sum(len(r.generated) for r in slo_ok) / horizon,
            "kv_bytes_moved": sum(e["kv_bytes_moved"] for e in self.event_log),
            "drops_per_capacity_change": [
                {"t": e["t"], "kind": e["kind"], "replicas": e["replicas"],
                 "dropped": e["dropped"], "migrated": e["migrated"],
                 "rebuilt": e["rebuilt"],
                 "stall_seconds": e["stall_seconds"]}
                for e in self.event_log
                if e["kind"] in ("scale_in", "scale_out", "fail_stop")],
        }


# ---------------------------------------------------------------------------
# offline convenience (launch/serve.py and examples/serve.py wrappers)
# ---------------------------------------------------------------------------
def offline_generate(cfg, *, batch: int = 4, prompt_len: int = 32,
                     max_new_tokens: int = 16, seed: int = 0,
                     sampler: Optional[SamplerConfig] = None, params=None,
                     frames_len: int = 16) -> Dict[str, Any]:
    """Batch-generate through the serving engine (single replica, offline
    SLO): the shared implementation behind ``launch/serve.py --smoke`` and
    ``examples/serve.py``.  Enc-dec archs get seeded random frames."""
    rng = np.random.default_rng(seed)
    engine = ServingEngine(
        cfg, n_replicas=1, slots_per_replica=batch,
        max_len=prompt_len + max_new_tokens + 1, mode="numeric",
        params=params, seed=seed, sampler=sampler or SamplerConfig(),
        slo=SLO(ttft=1e9, per_token=1e9))
    t0 = time.perf_counter()
    for b in range(batch):
        prompt = rng.integers(0, cfg.vocab_size,
                              size=prompt_len).astype(np.int32)
        frames = (rng.standard_normal((frames_len, cfg.d_model))
                  .astype(np.float32) if cfg.is_encdec else None)
        engine.submit(Request(rid=b, arrival=0.0, prompt=prompt,
                              max_new_tokens=max_new_tokens,
                              encoder_frames=frames))
    engine.drain()
    wall = time.perf_counter() - t0
    seqs = np.stack([np.asarray(engine.requests[b].generated)
                     for b in range(batch)])
    return {"sequences": seqs, "wall_seconds": wall,
            "summary": engine.summary(), "engine": engine}
