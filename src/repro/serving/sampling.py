"""Seeded token sampling, content-addressed through the RNG planner.

The paper's computation-consistency invariant (§4.4) extends to serving: a
sampled token must not depend on *which replica or slot* computed it.  The
key for the token at absolute position ``pos`` of request ``rid`` is

    stream_key(base_key, step=pos, layer_id=SAMPLE_STREAM_ID, sample_id=rid)

— the same content-addressed derivation ``core/planners/rng.py`` uses for
dropout streams, with a reserved pseudo-layer id for the sampling head.  KV
migration, requeue-with-prefix rebuilds and replica changes therefore leave
sampled streams bit-identical (tested in ``tests/test_serving.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.planners.rng import stream_key

# reserved pseudo layer id for the sampling head — disjoint from any real
# model layer id so sampling never collides with a dropout stream
SAMPLE_STREAM_ID = 1 << 20


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    method: str = "greedy"        # "greedy" | "topk"
    temperature: float = 1.0
    top_k: int = 0                # 0 = full vocab
    seed: int = 0

    def describe(self) -> dict:
        return dataclasses.asdict(self)


def sample_tokens(logits, rids: Sequence[int], positions: Sequence[int],
                  sc: SamplerConfig) -> np.ndarray:
    """logits: [B, V] -> token ids [B].  ``positions[b]`` is the absolute
    position of the token being sampled for request ``rids[b]``."""
    logits = np.asarray(logits, dtype=np.float32)
    if sc.method == "greedy":
        return np.argmax(logits, axis=-1).astype(np.int64)
    if sc.method != "topk":
        raise ValueError(f"unknown sampling method {sc.method!r}")
    import jax
    import jax.numpy as jnp
    base = jax.random.key(sc.seed)
    out = np.zeros(len(rids), dtype=np.int64)
    for b, (rid, pos) in enumerate(zip(rids, positions)):
        key = stream_key(base, int(pos), SAMPLE_STREAM_ID, int(rid))
        row = jnp.asarray(logits[b])
        if sc.top_k and sc.top_k < row.shape[-1]:
            vals, idx = jax.lax.top_k(row, sc.top_k)
        else:
            vals, idx = row, jnp.arange(row.shape[-1])
        choice = jax.random.categorical(key, vals / max(sc.temperature, 1e-6))
        out[b] = int(idx[choice])
    return out
