"""Elastic serving plane: continuous batching + KV-cache migration on the
recovery fabric (see docs/ARCHITECTURE.md).

The inference-side counterpart of the training ``VirtualCluster``: the same
``core.events`` vocabulary, the same event -> plan -> apply recovery path,
the same content-addressed RNG invariant — applied to an admission queue,
slot-indexed KV pools, and replica-level capacity changes.

Quick use::

    from repro.serving import ServingEngine, Request, SamplerConfig
    eng = ServingEngine(cfg, n_replicas=2, slots_per_replica=4, max_len=64)
    eng.submit(Request(rid=0, arrival=0.0, prompt=prompt, max_new_tokens=16))
    eng.drain()
    print(eng.summary())
"""
from .engine import Replica, ServeCostModel, ServingEngine, offline_generate
from .kvcache import (KVPool, gather_slots, migrate_slot, scatter_slots,
                      slot_kv_bytes)
from .policies import (SERVE_POLICIES, ChameleonServePolicy, DropPolicy,
                       ElasWaveServePolicy, RebuildServePolicy,
                       ServeRecoveryPolicy)
from .request import SLO, Request, RequestState, poisson_arrivals
from .sampling import SAMPLE_STREAM_ID, SamplerConfig, sample_tokens

__all__ = [
    "ChameleonServePolicy", "DropPolicy", "ElasWaveServePolicy", "KVPool",
    "RebuildServePolicy", "Replica", "Request", "RequestState",
    "SAMPLE_STREAM_ID", "SERVE_POLICIES", "SLO", "SamplerConfig",
    "ServeCostModel", "ServeRecoveryPolicy", "ServingEngine", "gather_slots",
    "migrate_slot", "offline_generate", "poisson_arrivals", "sample_tokens",
    "scatter_slots", "slot_kv_bytes",
]
