"""Slot-indexed KV-cache pools: explicit pytrees with gather/scatter moves.

Each serving replica owns one :class:`KVPool` — the stacked-cache pytree of
``models.registry.serving_hooks(cfg).init_caches(n_slots, max_len)`` plus
per-slot occupancy metadata.  Every cache leaf carries the slot dimension on
axis 1 (axis 0 is the layer/repeats stacking axis), and per-request extras
(e.g. an enc-dec encoder output) carry it on axis 0.

Gather/scatter follow the flat-state backbone's idiom
(``core/statespace.py``): one fancy-index per leaf instead of per-slot Python
loops.  A migration between replicas is ``gather_slots`` on the source pool +
``scatter_slots`` into the destination pool — a pure array copy, so migrated
decode streams are bit-identical to undisturbed ones (the serving analogue of
the training fast path's zero-copy shard views being bit-exact).
"""
from __future__ import annotations

from typing import Any, List, Optional, Sequence, Tuple

import numpy as np

SLOT_AXIS = 1        # stacked caches: [repeats/layers, slot, ...]
EXTRAS_AXIS = 0      # per-slot extras:  [slot, ...]


def _ix(ids: Sequence[int], axis: int) -> Tuple:
    """A single fancy-index selecting ``ids`` along ``axis``."""
    return tuple([slice(None)] * axis + [np.asarray(ids, dtype=np.int32)])


def gather_slots(tree, ids: Sequence[int], axis: int = SLOT_AXIS):
    """Slice ``ids`` out of every leaf along the slot axis (one fancy-index
    per leaf, mirroring ``IntervalTable.gather``)."""
    import jax
    idx = _ix(ids, axis)
    return jax.tree.map(lambda a: a[idx], tree)


def scatter_slots(dst, src, ids: Sequence[int], axis: int = SLOT_AXIS):
    """Write ``src`` (a gathered slice) into ``dst`` at ``ids``."""
    import jax
    idx = _ix(ids, axis)
    return jax.tree.map(
        lambda d, s: d.at[idx].set(s.astype(d.dtype)), dst, src)


def tree_nbytes(tree) -> int:
    import jax
    return int(sum(np.prod(l.shape) * np.dtype(l.dtype).itemsize
                   for l in jax.tree.leaves(tree)))


def slot_kv_bytes(cfg, max_len: int, init_caches=None) -> int:
    """Per-slot KV bytes for migration accounting, from cache *shapes* only
    (``jax.eval_shape`` — nothing is allocated)."""
    import jax
    if init_caches is None:
        from repro.models import registry as R
        init_caches = R.serving_hooks(cfg).init_caches
    shapes = jax.eval_shape(lambda: init_caches(1, max_len))
    return tree_nbytes(shapes)


class KVPool:
    """Per-replica slot bookkeeping over one stacked cache pytree.

    ``caches=None`` puts the pool in synthetic mode (scheduler/latency runs
    at trace scale): occupancy and byte accounting behave identically but no
    arrays are moved.
    """

    def __init__(self, n_slots: int, caches=None, *, slot_bytes: int = 0):
        self.n_slots = int(n_slots)
        self.caches = caches
        self.extras = None                 # lazily shaped from first template
        self.slot_req = np.full(self.n_slots, -1, dtype=np.int64)
        self.lengths = np.zeros(self.n_slots, dtype=np.int64)
        self._slot_bytes = int(slot_bytes) if slot_bytes else (
            tree_nbytes(caches) // max(self.n_slots, 1) if caches is not None
            else 0)

    # -- occupancy ---------------------------------------------------------
    def free_slots(self) -> List[int]:
        return [int(s) for s in np.flatnonzero(self.slot_req < 0)]

    def active_slots(self) -> List[int]:
        return [int(s) for s in np.flatnonzero(self.slot_req >= 0)]

    @property
    def n_free(self) -> int:
        return int((self.slot_req < 0).sum())

    @property
    def n_active(self) -> int:
        return self.n_slots - self.n_free

    def assign(self, slot: int, rid: int, length: int = 0):
        assert self.slot_req[slot] < 0, f"slot {slot} occupied"
        self.slot_req[slot] = rid
        self.lengths[slot] = length

    def release(self, slot: int):
        self.slot_req[slot] = -1
        self.lengths[slot] = 0

    def slot_bytes(self, slot: int) -> int:
        del slot  # uniform slots (max_len-sized); kept for API symmetry
        return self._slot_bytes

    # -- array movement ----------------------------------------------------
    def ensure_extras(self, template_slice):
        """Allocate the per-slot extras pytree from a [1, ...] template."""
        import jax
        import jax.numpy as jnp
        if self.extras is None and template_slice is not None:
            self.extras = jax.tree.map(
                lambda a: jnp.zeros((self.n_slots,) + tuple(a.shape[1:]),
                                    a.dtype), template_slice)

    def write(self, slot: int, cache_slice, extra_slice=None):
        """Scatter a single gathered slice ([.., 1, ..]) into ``slot``."""
        if self.caches is not None and cache_slice is not None:
            self.caches = scatter_slots(self.caches, cache_slice, [slot])
        if extra_slice is not None:
            self.ensure_extras(extra_slice)
            self.extras = scatter_slots(self.extras, extra_slice, [slot],
                                        axis=EXTRAS_AXIS)

    def read(self, slot: int):
        """Gather one slot's (cache, extras) slices (shapes keep the slot
        dim, so they scatter straight into another pool)."""
        c = (gather_slots(self.caches, [slot]) if self.caches is not None
             else None)
        e = (gather_slots(self.extras, [slot], axis=EXTRAS_AXIS)
             if self.extras is not None else None)
        return c, e


def migrate_slot(src: KVPool, src_slot: int, dst: KVPool, dst_slot: int,
                 rid: int) -> int:
    """Move one in-flight slot between replicas; returns bytes moved.
    Pure gather+scatter — the migrated stream's continuation is bit-identical
    (tested by ``tests/test_serving.py``)."""
    c, e = src.read(src_slot)
    length = int(src.lengths[src_slot])
    dst.assign(dst_slot, rid, length)
    dst.write(dst_slot, c, e)
    src.release(src_slot)
    return src.slot_bytes(src_slot)
