"""Serving recovery policies: what happens to in-flight requests when a
replica leaves.

A policy maps an elastic event to a *disposition* for each in-flight request
on the departing replica:

* ``"migrate"`` — gather the slot's KV pytree and scatter it into a free
  slot on a survivor (graceful capacity changes: the KV still exists);
* ``"rebuild"`` — requeue with the full token prefix and re-prefill on a
  survivor (the KV is gone — fail-stop — but the control plane's prefix
  record reconstructs it; recompute cost, zero request loss);
* ``"drop"``   — fail the request (the restart-the-world baseline).

``ElasWaveServePolicy`` is the paper-native choice (never drop),
``DropPolicy`` the TorchFT-style baseline, and ``ChameleonServePolicy`` the
per-event selector from PAPERS.md's Chameleon: it picks a disposition per
event kind/state instead of fixing one per run.
"""
from __future__ import annotations

from typing import Dict

from repro.core.events import ElasticEvent, EventKind

MIGRATE, REBUILD, DROP = "migrate", "rebuild", "drop"


class ServeRecoveryPolicy:
    name = "base"

    def disposition(self, ev: ElasticEvent) -> str:
        raise NotImplementedError

    def describe(self) -> Dict:
        return {"name": self.name}


class ElasWaveServePolicy(ServeRecoveryPolicy):
    """Zero-loss: migrate KV on graceful scale-in; rebuild from the prefix
    record on fail-stop (KV on the failed replica is unrecoverable)."""
    name = "elaswave_migrate"

    def disposition(self, ev: ElasticEvent) -> str:
        return REBUILD if ev.kind == EventKind.FAIL_STOP else MIGRATE


class RebuildServePolicy(ServeRecoveryPolicy):
    """Always requeue-with-prefix (no KV movement): simpler data plane,
    pays re-prefill recompute on every capacity change."""
    name = "rebuild"

    def disposition(self, ev: ElasticEvent) -> str:
        return REBUILD


class DropPolicy(ServeRecoveryPolicy):
    """TorchFT-style: in-flight work on a departing replica is lost."""
    name = "drop"

    def disposition(self, ev: ElasticEvent) -> str:
        return DROP


class ChameleonServePolicy(ServeRecoveryPolicy):
    """Per-event policy selection (Chameleon, PAPERS.md): graceful events
    migrate; fail-stops rebuild; an explicit override map can pin choices."""
    name = "chameleon"

    def __init__(self, overrides: Dict[EventKind, str] = None):
        self.overrides = dict(overrides or {})

    def disposition(self, ev: ElasticEvent) -> str:
        if ev.kind in self.overrides:
            return self.overrides[ev.kind]
        return REBUILD if ev.kind == EventKind.FAIL_STOP else MIGRATE

    def describe(self) -> Dict:
        return {"name": self.name,
                "overrides": {k.value: v for k, v in self.overrides.items()}}


SERVE_POLICIES = {p.name: p for p in
                  (ElasWaveServePolicy(), RebuildServePolicy(), DropPolicy(),
                   ChameleonServePolicy())}
