"""Serving requests, SLOs and deterministic arrival workloads.

A :class:`Request` is the serving plane's unit of work: a timestamped prompt
plus a generation budget.  The control plane keeps the full token prefix
(prompt + generated) for every in-flight request, which is what makes the
recovery fabric's zero-loss guarantee possible: KV state lost to a fail-stop
can always be rebuilt by re-prefilling the prefix, and KV state threatened by
a graceful scale-in can be migrated outright (see ``serving/kvcache.py``).

Arrivals are generated deterministically (seeded exponential gaps), so
scenario replays are reproducible — the serving analogue of the training
side's seeded ``GlobalBatchSampler``.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Dict, List, Optional

import numpy as np


class RequestState(enum.Enum):
    QUEUED = "queued"        # waiting for admission (includes requeued)
    ACTIVE = "active"        # holds a slot, decoding
    DONE = "done"
    REJECTED = "rejected"    # SLO-aware admission turned it away
    DROPPED = "dropped"      # lost in-flight to a capacity change


@dataclasses.dataclass(frozen=True)
class SLO:
    """Latency budgets driving admission (reject/defer) and goodput."""
    ttft: float = 3.0         # seconds to first token
    per_token: float = 0.25   # seconds per decode token (steady state)


@dataclasses.dataclass
class Request:
    rid: int
    arrival: float
    prompt: np.ndarray                       # [P] int32 token ids
    max_new_tokens: int
    encoder_frames: Optional[np.ndarray] = None   # enc-dec: [T, d] frames

    state: RequestState = RequestState.QUEUED
    generated: List[int] = dataclasses.field(default_factory=list)
    admit_time: Optional[float] = None       # first admission
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    replica: int = -1
    slot: int = -1
    migrations: int = 0                      # KV gather/scatter moves
    prefills: int = 0                        # admissions (1 + requeues)

    @property
    def prefix(self) -> np.ndarray:
        """prompt + generated-so-far: what a re-prefill must replay."""
        gen = np.asarray(self.generated, dtype=self.prompt.dtype)
        return np.concatenate([self.prompt, gen]) if len(gen) else self.prompt

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens

    @property
    def ttft(self) -> Optional[float]:
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival

    @property
    def per_token_latency(self) -> Optional[float]:
        """Mean decode latency after the first token."""
        if self.finish_time is None or self.first_token_time is None:
            return None
        n = len(self.generated) - 1
        if n <= 0:
            return 0.0
        return (self.finish_time - self.first_token_time) / n

    def meets(self, slo: SLO) -> bool:
        t, p = self.ttft, self.per_token_latency
        return (t is not None and p is not None
                and t <= slo.ttft and p <= slo.per_token)

    def record(self) -> Dict:
        return {
            "rid": self.rid, "arrival": self.arrival,
            "state": self.state.value, "prompt_len": int(len(self.prompt)),
            "generated": len(self.generated), "ttft": self.ttft,
            "per_token": self.per_token_latency,
            "migrations": self.migrations, "prefills": self.prefills,
        }


def poisson_arrivals(rate: float, horizon: float, *, prompt_len: int,
                     max_new_tokens: int, vocab_size: int, seed: int = 0,
                     frames_shape: Optional[tuple] = None) -> List[Request]:
    """Deterministic request stream: seeded exponential inter-arrival gaps,
    seeded random prompts.  ``frames_shape=(T, d)`` additionally attaches
    encoder frames (enc-dec serving)."""
    rng = np.random.default_rng(seed)
    out: List[Request] = []
    t, rid = 0.0, 0
    while True:
        t += float(rng.exponential(1.0 / rate))
        if t >= horizon:
            break
        prompt = rng.integers(0, vocab_size, size=prompt_len).astype(np.int32)
        frames = (rng.standard_normal(frames_shape).astype(np.float32)
                  if frames_shape is not None else None)
        out.append(Request(rid=rid, arrival=t, prompt=prompt,
                           max_new_tokens=max_new_tokens,
                           encoder_frames=frames))
        rid += 1
    return out
