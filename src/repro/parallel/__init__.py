from . import sharding
