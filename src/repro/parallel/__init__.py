"""Sharding specs and mesh lowering for the hybrid DP\u00d7PP\u00d7TP layouts."""
from . import sharding
