"""Sharding rules for the production pjit path.

Mesh axes:
  single-pod: ("data", "model") = (16, 16)
  multi-pod : ("pod", "data", "model") = (2, 16, 16)

Policy (MaxText-style FSDP + TP, adapted per family):
  * batch                -> ("pod","data")          [DP]
  * weight in-dim  (d)   -> ("pod","data")          [ZeRO-3 / FSDP shard]
  * weight out-dim (ff/heads/vocab) -> "model"      [TP]
  * MoE expert dim       -> "model"                 [EP]
  * KV cache: batch -> DP axes; heads -> "model" if divisible, else seq -> "model"
  * every rule degrades to None if the dim is not divisible by the axis group
    (e.g. vocab 50280 or 51865 cannot shard over 16).

All functions are divisibility-safe so every (arch x shape x mesh) cell lowers.
"""
from __future__ import annotations

import math
from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig


def mesh_axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return math.prod(mesh.shape[a] for a in axes)


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _fit(mesh: Mesh, size: int, axes) -> Optional[Any]:
    """Return `axes` if `size` divides evenly over them, trying suffixes of
    the axis tuple before giving up (e.g. ("pod","data") -> ("data",))."""
    if axes is None:
        return None
    if isinstance(axes, str):
        axes = (axes,)
    for start in range(len(axes)):
        cand = axes[start:]
        if size % mesh_axis_size(mesh, cand) == 0:
            return cand if len(cand) > 1 else cand[0]
    return None


def _spec(mesh: Mesh, shape, *dim_axes) -> P:
    """Build a PartitionSpec fitting each dim; drop axes that don't divide."""
    assert len(shape) == len(dim_axes), (shape, dim_axes)
    used = set()
    entries = []
    for size, axes in zip(shape, dim_axes):
        fitted = _fit(mesh, size, axes)
        # an axis name may appear at most once in a PartitionSpec
        if fitted is not None:
            names = (fitted,) if isinstance(fitted, str) else tuple(fitted)
            if any(n in used for n in names):
                fitted = None
            else:
                used.update(names)
        entries.append(fitted)
    return P(*entries)


# --------------------------------------------------------------------------
# Parameter shardings
# --------------------------------------------------------------------------
def param_pspecs(cfg: ModelConfig, mesh: Mesh, params_shapes) -> Any:
    """Map a params shape-pytree -> PartitionSpec pytree by path rules."""
    DP = dp_axes(mesh)

    def rule(path, leaf):
        shape = tuple(leaf.shape)
        keys = [getattr(p, "key", getattr(p, "name", str(p))) for p in path]
        name = keys[-1] if keys else ""
        joined = "/".join(str(k) for k in keys)
        # strip leading stacked-repeats axis for block params under segments/
        stacked = ("segments" in joined) or ("encoder/" in joined and len(shape) >= 2) \
            or ("decoder/" in joined)
        core = shape[1:] if stacked and len(shape) >= 2 else shape
        lead = (None,) if stacked and len(shape) >= 2 else ()

        def out(*axes):
            sp = _spec(mesh, core, *axes)
            return P(*(lead + tuple(sp)))

        if name == "embedding":
            return out("model", DP)
        if name in ("wq", "wk", "wv", "wq_a", "wq_b", "wkv_a", "wkv_b",
                    "wg", "wu", "wi", "in_proj"):
            if len(core) == 3:           # MoE expert weights [E, d, ff]
                return out("model", DP, None)
            return out(DP, "model")
        if name in ("wo", "out_proj"):
            if len(core) == 3:           # MoE [E, ff, d]
                return out("model", None, DP)
            return out("model", DP)
        if name == "w":                  # lm head [d, V]
            return out(DP, "model")
        if name == "router":
            return out(DP, None)
        if name == "conv_w":
            return out(None, "model")
        if name == "enc_pos":
            return out(None, DP)
        # scale / A_log / D / dt_bias / other small vectors: replicate
        return P(*(lead + (None,) * len(core)))

    return jax.tree_util.tree_map_with_path(rule, params_shapes)


def batch_pspecs(cfg: ModelConfig, mesh: Mesh, batch_shapes) -> Any:
    DP = dp_axes(mesh)

    def rule(path, leaf):
        shape = tuple(leaf.shape)
        sp = [None] * len(shape)
        fitted = _fit(mesh, shape[0], DP)
        sp[0] = fitted
        return P(*sp)

    return jax.tree_util.tree_map_with_path(rule, batch_shapes)


def cache_pspecs(cfg: ModelConfig, mesh: Mesh, cache_shapes) -> Any:
    """KV caches [rep, B, T, Hkv, hd] / MLA [rep, B, T, r] /
    mamba ssm [rep, B, h, p, n], conv [rep, B, k-1, c]."""
    DP = dp_axes(mesh)

    def rule(path, leaf):
        shape = tuple(leaf.shape)
        keys = "/".join(str(getattr(p, "key", getattr(p, "name", p))) for p in path)
        # leading repeats axis
        if len(shape) == 5 and "ssm" not in keys:      # [rep,B,T,H,hd]
            rep, B, T, H, hd = shape
            h_fit = _fit(mesh, H, "model")
            if h_fit is not None:
                return _spec(mesh, shape, None, DP, None, "model", None)
            return _spec(mesh, shape, None, DP, "model", None, None)
        if len(shape) == 5:                            # mamba ssm [rep,B,h,p,n]
            return _spec(mesh, shape, None, DP, "model", None, None)
        if len(shape) == 4:                            # MLA latent / conv state
            # [rep,B,T,r] -> shard T over model when batch tiny
            return _spec(mesh, shape, None, DP, "model", None)
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(rule, cache_shapes)


def to_shardings(mesh: Mesh, pspecs) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                        is_leaf=lambda x: isinstance(x, P))


def opt_pspecs(param_specs) -> Any:
    """Adam mu/nu/master share the param sharding; scalars replicated."""
    return param_specs
