"""Sharded mixed-precision AdamW.

State per-leaf: {master fp32, mu fp32, nu fp32}; params stay in model dtype.
The state pytree mirrors the param pytree, so the FSDP/ZeRO sharding rules in
parallel/sharding.py apply verbatim (this is ZeRO-3 semantics under pjit: XLA
all-gathers weights for compute, reduce-scatters grads back to the shards).

The ElasWave VirtualCluster uses the same math through `adam_update_flat` on
flattened per-layer vectors (its ZeRO-1 shards).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    master_weights: bool = True


def init_opt_state(params, cfg: AdamConfig):
    def leaf(p):
        st = {"mu": jnp.zeros(p.shape, jnp.float32),
              "nu": jnp.zeros(p.shape, jnp.float32)}
        if cfg.master_weights:
            st["master"] = p.astype(jnp.float32)
        return st
    return {"leaves": jax.tree.map(leaf, params), "step": jnp.zeros((), jnp.int32)}


def opt_state_shapes(params_shapes, cfg: AdamConfig):
    return jax.eval_shape(lambda p: init_opt_state(p, cfg), params_shapes)


def adam_update(params, grads, state, cfg: AdamConfig):
    step = state["step"] + 1
    b1t = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2t = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def leaf(p, g, st):
        g = g.astype(jnp.float32)
        mu = cfg.b1 * st["mu"] + (1 - cfg.b1) * g
        nu = cfg.b2 * st["nu"] + (1 - cfg.b2) * g * g
        mhat = mu / b1t
        nhat = nu / b2t
        base = st.get("master", p.astype(jnp.float32))
        upd = mhat / (jnp.sqrt(nhat) + cfg.eps) + cfg.weight_decay * base
        new_master = base - cfg.lr * upd
        new_p = new_master.astype(p.dtype)
        out = {"mu": mu, "nu": nu}
        if "master" in st:
            out["master"] = new_master
        return new_p, out

    flat = jax.tree.map(leaf, params, grads, state["leaves"],
                        is_leaf=lambda x: isinstance(x, dict) and "mu" in x)
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_leaves = jax.tree.map(lambda t: t[1], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"leaves": new_leaves, "step": step}


# ---- flat-vector variant (VirtualCluster ZeRO shards) ----------------------
def init_flat_state(vec: jnp.ndarray) -> dict:
    return {"master": vec.astype(jnp.float32),
            "mu": jnp.zeros_like(vec, dtype=jnp.float32),
            "nu": jnp.zeros_like(vec, dtype=jnp.float32)}


def adam_update_flat(grad_vec, st, step: int, cfg: AdamConfig):
    """Update one flattened shard.  Returns (new_param_vec_f32, new_state)."""
    g = grad_vec.astype(jnp.float32)
    b1t = 1.0 - cfg.b1 ** step
    b2t = 1.0 - cfg.b2 ** step
    mu = cfg.b1 * st["mu"] + (1 - cfg.b1) * g
    nu = cfg.b2 * st["nu"] + (1 - cfg.b2) * g * g
    upd = (mu / b1t) / (jnp.sqrt(nu / b2t) + cfg.eps) + cfg.weight_decay * st["master"]
    master = st["master"] - cfg.lr * upd
    return master, {"master": master, "mu": mu, "nu": nu}


def adam_update_flat_np(grad_vec, st, step: int, cfg: AdamConfig):
    """Host-side (numpy) mirror of :func:`adam_update_flat`, bit-identical.

    IEEE basic ops (+, -, *, /, sqrt) are correctly rounded in both numpy
    and XLA's *eager* single-op kernels, so running the same op sequence in
    f32 produces identical bits — while avoiding the ~8 per-call dispatches
    and host<->device round-trips of the eager path.  (A *jitted* fused
    version is NOT equivalent: XLA contracts mul+add chains into FMAs.)
    Used by the VirtualCluster fast path and the batched SnapshotPool;
    bit-identity to the eager path is enforced end-to-end by
    ``tests/test_fast_path_numerics.py``.

    Returns the new state dict {master, mu, nu} (f32 numpy arrays).
    """
    g = np.asarray(grad_vec, dtype=np.float32)
    b1t = np.float32(1.0 - cfg.b1 ** step)
    b2t = np.float32(1.0 - cfg.b2 ** step)
    mu = np.float32(cfg.b1) * st["mu"] + np.float32(1 - cfg.b1) * g
    nu = np.float32(cfg.b2) * st["nu"] + np.float32(1 - cfg.b2) * g * g
    upd = (mu / b1t) / (np.sqrt(nu / b2t) + np.float32(cfg.eps)) \
        + np.float32(cfg.weight_decay) * st["master"]
    master = st["master"] - np.float32(cfg.lr) * upd
    return {"master": master, "mu": mu, "nu": nu}
