"""Optimizer kernels: ZeRO-1-shardable Adam with fp32 master weights."""
from .adam import AdamConfig, init_opt_state, adam_update, opt_state_shapes
