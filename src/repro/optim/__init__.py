from .adam import AdamConfig, init_opt_state, adam_update, opt_state_shapes
