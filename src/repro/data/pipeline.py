"""Deterministic sample-id-addressed data pipeline.

The elastic property ElasWave needs from the data layer: **any rank must be
able to materialize any sample by its global id**, so that micro-batch
resizing / resharding re-slices the *same* global batch instead of changing
it.  We synthesize tokens as a keyed hash of (sample_id, position) — a stand-
in for an indexed tokenized corpus (e.g. an array-record dataset addressed by
sample id, which has exactly this property in production).

Invariant (tested): for a given step, the multiset of (sample_id -> tokens)
pairs in the global batch is independent of DP size, micro-batch sizes, and
rank assignment.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class GlobalBatchSampler:
    """step -> global sample ids; slicing helpers for DP assignment."""
    global_batch: int
    seed: int = 0

    def sample_ids(self, step: int) -> np.ndarray:
        # contiguous ids: one epoch-free infinite stream
        start = step * self.global_batch
        return np.arange(start, start + self.global_batch, dtype=np.int64)

    def partition(self, step: int, micro_batch_sizes: Sequence[int],
                  num_micro_batches: int) -> List[List[np.ndarray]]:
        """Split the global batch among DP ranks × micro-batches.

        micro_batch_sizes[r] = per-micro-batch size of DP rank r (ElasWave
        dataflow resizing makes these uneven after a failure).
        Returns ids[r][m] = sample ids of rank r's m-th micro batch.
        """
        ids = self.sample_ids(step)
        total = sum(micro_batch_sizes) * num_micro_batches
        assert total == self.global_batch, (total, self.global_batch)
        out: List[List[np.ndarray]] = [[] for _ in micro_batch_sizes]
        cursor = 0
        for m in range(num_micro_batches):
            for r, sz in enumerate(micro_batch_sizes):
                out[r].append(ids[cursor:cursor + sz])
                cursor += sz
        return out


def materialize_samples(sample_ids: np.ndarray, seq_len: int,
                        vocab_size: int, seed: int = 0) -> np.ndarray:
    """Deterministic tokens for given sample ids: [n, seq_len] int32."""
    sample_ids = np.asarray(sample_ids, dtype=np.uint64)
    pos = np.arange(seq_len, dtype=np.uint64)[None, :]
    x = sample_ids[:, None] * np.uint64(6364136223846793005) \
        + pos * np.uint64(1442695040888963407) + np.uint64(seed)
    # splitmix64 finalizer
    x ^= x >> np.uint64(30); x *= np.uint64(0xbf58476d1ce4e5b9)
    x ^= x >> np.uint64(27); x *= np.uint64(0x94d049bb133111eb)
    x ^= x >> np.uint64(31)
    return (x % np.uint64(vocab_size)).astype(np.int32)


def make_batch(sample_ids: np.ndarray, seq_len: int, vocab_size: int,
               seed: int = 0) -> Dict[str, jnp.ndarray]:
    toks = materialize_samples(sample_ids, seq_len, vocab_size, seed)
    return {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks),
            "sample_ids": jnp.asarray(np.asarray(sample_ids, dtype=np.int32))}
