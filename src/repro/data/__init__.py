from .pipeline import GlobalBatchSampler, materialize_samples, make_batch
