"""Deterministic data pipeline: content-addressed global batch sampling
(what makes dataflow resizing loss-consistent)."""
from .pipeline import GlobalBatchSampler, materialize_samples, make_batch
