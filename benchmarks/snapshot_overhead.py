"""Table 3 — per-step snapshot overhead.

Two measurements:
 (1) wall-clock step time of the VirtualCluster with / without snapshots on a
     reduced model (the CPU-measurable equivalent);
 (2) the modeled hidden/critical-path ratio for the three Llama-2 workloads
     from the Fig. 6b timeline (grad D2D + D2H overlapped with Step/AG; host
     update hidden under the next iteration)."""
from __future__ import annotations

import time

import numpy as np

from repro.core.cluster import VirtualCluster
from repro.core.cost_model import SegmentCosts
from repro.models import registry as R
from .common import LLAMA2, WORKER_HW, emit


def measured_overhead(steps=4, reps=3):
    """Best-of-reps per-step wall time (resists scheduler noise on a shared
    machine; the modeled number below is the scale-faithful one)."""
    cfg = R.tiny_config("dense", num_layers=4)
    t = {}
    for snap in (False, True):
        cl = VirtualCluster(cfg, dp=2, pp=2, global_batch=8, num_micro=2,
                            seq_len=16, seed=0, snapshot_enabled=snap)
        cl.run(1)   # compile
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            cl.run(steps)
            best = min(best, (time.perf_counter() - t0) / steps)
        t[snap] = best
    return t


def modeled_rows():
    rows = []
    for wname, w in LLAMA2.items():
        cfg, dp = w["cfg"], w["dp"]
        seg = SegmentCosts.build(cfg, w["seq"], WORKER_HW)
        L = cfg.num_layers
        num_micro = w["global_batch"] // (w["mbs"] * dp)
        step_compute = seg.seg_fwd_flops(0, L - 1, w["mbs"]) * 3 * num_micro \
            / (WORKER_HW.peak_flops * WORKER_HW.mfu) / w["pp"]
        # per-worker shard: params/dp * 4B grads
        shard_grad_bytes = cfg.param_count() / w["pp"] / dp * 4
        d2d = shard_grad_bytes / 25e9
        d2h = shard_grad_bytes / 12e9
        host_update = shard_grad_bytes / 4 * 12 / 5e10
        exposed = max(0.0, d2d + d2h - 0.5 * step_compute) \
            + 0.004 * step_compute
        rows.append((wname, step_compute, d2d + d2h + host_update,
                     exposed / step_compute * 100))
    return rows


def run(verbose=True):
    t = measured_overhead()
    loss_pct = (t[True] - t[False]) / t[False] * 100
    if verbose:
        print(f"  measured (VirtualCluster, reduced): no_snap={t[False]*1e3:.1f}ms"
              f" with_snap={t[True]*1e3:.1f}ms overhead={loss_pct:.2f}%")
    rows = modeled_rows()
    for wname, comp, snap_work, exposed_pct in rows:
        if verbose:
            print(f"  {wname}: step={comp:.2f}s snapshot_work={snap_work:.3f}s "
                  f"exposed={exposed_pct:.2f}% (hidden by overlap)")
    return loss_pct, rows


def main():
    t0 = time.perf_counter()
    loss_pct, rows = run()
    us = (time.perf_counter() - t0) * 1e6
    worst_modeled = max(r[3] for r in rows)
    # The modeled number is the Table-3-faithful one (real workload ratios,
    # Fig. 6b overlap); the toy-scale measurement is dominated by the python
    # host-Adam loop relative to ~ms jitted steps and is reported for
    # transparency only.
    emit("table3_snapshot_overhead", us,
         f"modeled_overhead<={worst_modeled:.2f}%;"
         f"toy_scale_measured={loss_pct:.1f}%")
    return rows


if __name__ == "__main__":
    main()
