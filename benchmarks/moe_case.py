"""§7.7 — MoE case study: elastic recovery on a Llama2-13B-based MoE (expert
parallel) vs the TorchFT baseline after one failure."""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core.policies import ElasWavePolicy, TorchFTPolicy
from .common import LLAMA2, WORKER_HW, build_view, kill_nodes, emit


def moe_workload():
    w = dict(LLAMA2["llama2-13b"])
    w["cfg"] = dataclasses.replace(
        w["cfg"], name="llama2-13b-moe", family="moe", num_experts=8,
        top_k=2, moe_d_ff=w["cfg"].d_ff, moe_layer_period=2)
    return w


def run(verbose=True):
    w = moe_workload()
    seg, view0 = build_view(w)
    base = ElasWavePolicy(WORKER_HW).decide(seg, view0)
    thr0 = w["global_batch"] / base.step_time

    seg, view = build_view(w)
    kill_nodes(view, 1)
    d_ew = ElasWavePolicy(WORKER_HW).decide(seg, view)
    seg, view = build_view(w)
    kill_nodes(view, 1)
    d_tf = TorchFTPolicy().decide(seg, view)
    thr_ew = w["global_batch"] / d_ew.step_time
    thr_tf = w["global_batch"] / d_tf.step_time
    if verbose:
        print(f"  MoE initial: {thr0:.1f} samples/s (normalized 1.0)")
        print(f"  after failure: torchft={thr_tf / thr0:.3f} "
              f"elaswave={thr_ew / thr0:.3f} "
              f"improvement={(thr_ew / thr_tf - 1) * 100:.0f}%")

    # EP extension (beyond paper): expert reshard on EP-group shrink
    from repro.core.planners.expert import plan_expert_reshard
    import numpy as np
    E, W = w["cfg"].num_experts, 4
    rng = np.random.default_rng(0)
    load = rng.dirichlet(np.ones(E) * 2) * E          # skewed router load
    old = {e: e % W for e in range(E)}
    expert_bytes = int(2 * 3 * w["cfg"].d_model * w["cfg"].moe_d_ff)
    plan = plan_expert_reshard(load, old, surviving=[0, 1, 3],
                               expert_bytes=expert_bytes,
                               snapshot_holder={e: (e % W + 1) % W
                                                for e in range(E)})
    if verbose:
        print(f"  EP reshard: {len(plan.moves)} experts recovered from "
              f"snapshots, max load {plan.max_load:.2f} (ideal "
              f"{sum(load) / 3:.2f}), est {plan.est_seconds * 1e3:.1f} ms")
    return thr0, thr_ew, thr_tf


def main():
    t0 = time.perf_counter()
    thr0, thr_ew, thr_tf = run()
    us = (time.perf_counter() - t0) * 1e6
    emit("sec7p7_moe_case", us,
         f"elaswave_vs_torchft=+{(thr_ew / thr_tf - 1) * 100:.0f}%")
    return thr_ew / thr_tf


if __name__ == "__main__":
    main()
