"""Fig. 15a — fail-slow (straggler) mitigation at Low/Medium/High severity.

One worker is slowed by 1.1/1.25/1.45x; ElasWave rebalances layers + DVFS.
Reported: normalized throughput before mitigation vs after.

Thin wrapper over the scenario engine: each severity is a one-event
FAIL_SLOW scenario replayed twice through ``AnalyticScenarioRunner`` — once
with the mitigation axes disabled (``use_dvfs=False, use_migration=False``)
and once with the full multi-dimensional replan.
"""
from __future__ import annotations

import time

from repro.core.events import EventKind
from repro.core.policies import ElasWavePolicy
from repro.scenarios import AnalyticScenarioRunner, Scenario
from .common import LLAMA2, WORKER_HW, analytic_workload, emit

LEVELS = {"low": 1.1, "medium": 1.25, "high": 1.45}
STRAGGLER = (1, 2)     # (dp replica, stage)


def run(verbose=True):
    w = LLAMA2["llama2-13b"]
    wl = analytic_workload(w)
    reference = ElasWavePolicy(WORKER_HW)
    rows = []
    for name, f in LEVELS.items():
        scn = Scenario.single(f"failslow_{name}", EventKind.FAIL_SLOW, step=0,
                              ranks=(wl.rank(*STRAGGLER),), horizon=1,
                              slow_factor=f)
        # unmitigated: straggler gates its stage; no replan
        unmit = AnalyticScenarioRunner(
            scn, wl, ElasWavePolicy(WORKER_HW, use_dvfs=False,
                                    use_migration=False),
            reference_policy=reference).run()
        thr_unmit = unmit.steps[-1]["rel_throughput"]
        # mitigated: full multi-dim replan
        mit = AnalyticScenarioRunner(
            scn, wl, ElasWavePolicy(WORKER_HW),
            reference_policy=reference).run()
        thr_mit = mit.steps[-1]["rel_throughput"]
        recoup = (thr_mit - thr_unmit) / max(1 - thr_unmit, 1e-9)
        rows.append((name, f, thr_unmit, thr_mit, recoup))
        if verbose:
            print(f"  {name} (x{f}): degraded={thr_unmit:.3f} "
                  f"recovered={thr_mit:.3f} recouped={recoup * 100:.0f}% of loss")
    return rows


def main():
    t0 = time.perf_counter()
    rows = run()
    us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)
    worst = min(r[4] for r in rows[1:])   # medium/high per paper claim
    emit("fig15a_failslow", us, f"recouped>={worst * 100:.0f}%_med_high")
    return rows


if __name__ == "__main__":
    main()
