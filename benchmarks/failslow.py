"""Fig. 15a — fail-slow (straggler) mitigation at Low/Medium/High severity.

One worker is slowed by 1.1/1.25/1.45x; ElasWave rebalances layers + DVFS.
Reported: normalized throughput before mitigation vs after."""
from __future__ import annotations

import time

import numpy as np

from repro.core.policies import ElasWavePolicy
from .common import LLAMA2, WORKER_HW, build_view, emit

LEVELS = {"low": 1.1, "medium": 1.25, "high": 1.45}


def run(verbose=True):
    w = LLAMA2["llama2-13b"]
    seg, view0 = build_view(w)
    base = ElasWavePolicy(WORKER_HW).decide(seg, view0)
    thr0 = w["global_batch"] / base.step_time
    rows = []
    for name, f in LEVELS.items():
        # unmitigated: straggler gates its stage; no replan
        seg, view = build_view(w)
        view.slow[1, 2] = f
        unmit = ElasWavePolicy(WORKER_HW, use_dvfs=False,
                               use_migration=False).decide(seg, view)
        thr_unmit = w["global_batch"] / unmit.step_time / thr0
        # mitigated: full multi-dim replan
        seg, view = build_view(w)
        view.slow[1, 2] = f
        mit = ElasWavePolicy(WORKER_HW).decide(seg, view)
        thr_mit = w["global_batch"] / mit.step_time / thr0
        recoup = (thr_mit - thr_unmit) / max(1 - thr_unmit, 1e-9)
        rows.append((name, f, thr_unmit, thr_mit, recoup))
        if verbose:
            print(f"  {name} (x{f}): degraded={thr_unmit:.3f} "
                  f"recovered={thr_mit:.3f} recouped={recoup * 100:.0f}% of loss")
    return rows


def main():
    t0 = time.perf_counter()
    rows = run()
    us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)
    worst = min(r[4] for r in rows[1:])   # medium/high per paper claim
    emit("fig15a_failslow", us, f"recouped>={worst * 100:.0f}%_med_high")
    return rows


if __name__ == "__main__":
    main()
