"""Fig. 12b — communication-group recovery time: Dynamic Communicator
(in-place edit) vs partial vs full rebuild, 8..64 ranks."""
from __future__ import annotations

import time

from repro.core.communicator import DynamicCommunicator, build_hybrid_groups
from .common import emit


def run(verbose=True):
    rows = []
    for n_ranks in (8, 16, 32, 64):
        dp = max(n_ranks // 4, 2)
        pp = n_ranks // dp
        groups = build_hybrid_groups(dp, pp)
        dead = 1
        c1 = DynamicCommunicator(groups)
        t_edit = c1.edit(remove=[dead]).seconds
        c2 = DynamicCommunicator(groups)
        t_part = c2.partial_rebuild(remove=[dead]).seconds
        c3 = DynamicCommunicator(groups)
        ng = {k: [r for r in v if r != dead] for k, v in c3.groups.items()}
        t_full = c3.full_rebuild(ng).seconds
        rows.append((n_ranks, t_edit, t_part, t_full))
        if verbose:
            print(f"  ranks={n_ranks:3d} edit={t_edit:.3f}s "
                  f"partial={t_part:.3f}s full={t_full:.3f}s "
                  f"speedup_full={t_full / t_edit:.0f}x "
                  f"speedup_partial={t_part / t_edit:.1f}x")
    return rows


def main():
    t0 = time.perf_counter()
    rows = run()
    us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)
    worst_edit = max(r[1] for r in rows)
    best_full_speedup = max(r[3] / r[1] for r in rows)
    best_part_speedup = max(r[2] / r[1] for r in rows)
    emit("fig12b_communicator_mttr", us,
         f"edit<={worst_edit:.2f}s;vs_full={best_full_speedup:.0f}x;"
         f"vs_partial={best_part_speedup:.1f}x")
    return rows


if __name__ == "__main__":
    main()
