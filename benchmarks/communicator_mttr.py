"""Fig. 12b — communication-group recovery time: Dynamic Communicator
(in-place edit) vs partial vs full rebuild, 8..64 ranks.

Thin wrapper over the scenario engine: each rank count becomes a one-event
fail-stop scenario; the ``AnalyticScenarioRunner`` prices all three recovery
modes from identical pre-event communicator state (``clone()``) and records
them in the recovery record's communicator accounting.
"""
from __future__ import annotations

import time

from repro.core.events import EventKind
from repro.core.policies import ElasWavePolicy
from repro.scenarios import AnalyticScenarioRunner, Scenario
from .common import LLAMA2, WORKER_HW, analytic_workload, emit


def run(verbose=True):
    rows = []
    base = LLAMA2["llama2-7b"]
    for n_ranks in (8, 16, 32, 64):
        dp = max(n_ranks // 4, 2)
        pp = n_ranks // dp
        wl = analytic_workload({**base, "dp": dp, "pp": pp})
        dead = 1          # rank 1 = (d=0, p=1)
        scn = Scenario.single(f"comm_{n_ranks}ranks", EventKind.FAIL_STOP,
                              step=0, ranks=(dead,), horizon=1)
        res = AnalyticScenarioRunner(
            scn, wl, ElasWavePolicy(WORKER_HW)).run()
        acct = res.recoveries[0]["communicator"]
        t_edit = acct["edit_seconds"]
        t_part = acct["partial_rebuild_seconds"]
        t_full = acct["full_rebuild_seconds"]
        rows.append((n_ranks, t_edit, t_part, t_full))
        if verbose:
            print(f"  ranks={n_ranks:3d} edit={t_edit:.3f}s "
                  f"partial={t_part:.3f}s full={t_full:.3f}s "
                  f"speedup_full={t_full / t_edit:.0f}x "
                  f"speedup_partial={t_part / t_edit:.1f}x")
    return rows


def main():
    t0 = time.perf_counter()
    rows = run()
    us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)
    worst_edit = max(r[1] for r in rows)
    best_full_speedup = max(r[3] / r[1] for r in rows)
    best_part_speedup = max(r[2] / r[1] for r in rows)
    emit("fig12b_communicator_mttr", us,
         f"edit<={worst_edit:.2f}s;vs_full={best_full_speedup:.0f}x;"
         f"vs_partial={best_part_speedup:.1f}x")
    return rows


if __name__ == "__main__":
    main()
