"""BENCH_analytic_scale — the rank-vectorized analytic plane at paper scale.

Sweeps the cluster size 10^3 -> 10^5 ranks (Llama-2-7B layers over pp=8,
DP widened to fill), fires a correlated 2-rack-domain fail-stop burst plus a
later whole-domain rejoin, and prices the full scenario — policy decisions,
communicator edit-vs-partial-vs-full accounting, MTTR — end-to-end through
``AnalyticScenarioRunner`` for ElasWave, TorchFT and the Oobleck-style
pipeline-template fallback.

``BENCH_analytic_scale.json``:

.. code-block:: json

    {
      "sweep": {"100000": {"elaswave": {
          "wall_seconds": 0.7, "time_avg_rel_throughput": 0.75,
          "edit_seconds": ..., "partial_rebuild_seconds": ...,
          "full_rebuild_seconds": ..., "n_burst_ranks": 128}, ...}, ...},
      "oracle_ok": true,          // vectorized == dict/set legacy at 32 ranks
      "budget_s": 10.0, "gate_ok": true
    }

CI gate: the largest swept size must price each policy's whole scenario in
under ``ANALYTIC_SCALE_BUDGET_S`` wall-clock seconds (exit 1 otherwise).
Env knobs: ``ANALYTIC_SCALE_MAX_RANKS`` caps the sweep (CI uses 10^4),
``ANALYTIC_SCALE_BUDGET_S`` sets the budget (default 10 s, the acceptance
bar for the 10^5 sweep).
"""
from __future__ import annotations

import json
import os
import time
from pathlib import Path

from repro.core.legacy_comm import LegacyDynamicCommunicator
from repro.core.policies import ElasWavePolicy, OobleckPolicy, TorchFTPolicy
from repro.scenarios import AnalyticScenarioRunner, AnalyticWorkload, Scenario

from .common import LLAMA2, WORKER_HW, emit

PP = 8
DOMAIN_SIZE = 64          # ranks per rack domain (8 replicas at pp=8)
SWEEP = (1_000, 10_000, 100_000)


def _workload(n_ranks: int) -> AnalyticWorkload:
    base = LLAMA2["llama2-7b"]
    dp = n_ranks // PP
    return AnalyticWorkload(cfg=base["cfg"], dp=dp, pp=PP, mbs=1,
                            global_batch=PP * dp, seq=base["seq"],
                            hw=WORKER_HW, domain_size=DOMAIN_SIZE)


def _scenario(w: AnalyticWorkload) -> Scenario:
    dom = w.domains
    return Scenario.domain_burst("domain_burst", step=10,
                                 domain_ids=dom.sample(2, seed=7),
                                 domains=dom, horizon=100, regrow_step=60)


def _policies():
    return (ElasWavePolicy(hw=WORKER_HW), TorchFTPolicy(),
            OobleckPolicy(hw=WORKER_HW))


def _price(w: AnalyticWorkload, scn: Scenario, policy, **kw):
    t0 = time.perf_counter()
    res = AnalyticScenarioRunner(scn, w, policy, **kw).run()
    wall = time.perf_counter() - t0
    burst = next(r for r in res.recoveries if "communicator" in r)
    acct = burst["communicator"]
    return res, {
        "wall_seconds": round(wall, 4),
        "time_avg_rel_throughput": res.summary["time_avg_rel_throughput"],
        "final_rel_throughput": res.summary["final_rel_throughput"],
        "n_burst_ranks": len(burst["ranks"]),
        **{k: acct[k] for k in ("edit_seconds", "partial_rebuild_seconds",
                                "full_rebuild_seconds")},
    }


def _oracle_check(n_ranks: int = 32) -> bool:
    """Whole-scenario equivalence: vectorized communicator vs the seed
    dict/set implementation, identical recovery records and summary."""
    w = _workload(n_ranks)
    scn = _scenario(w)
    ok = True
    for policy_f in (lambda: ElasWavePolicy(hw=WORKER_HW), TorchFTPolicy,
                     lambda: OobleckPolicy(hw=WORKER_HW)):
        vec = AnalyticScenarioRunner(scn, w, policy_f()).run()
        leg = AnalyticScenarioRunner(
            scn, w, policy_f(), comm_factory=LegacyDynamicCommunicator).run()
        ok &= vec.recoveries == leg.recoveries
        ok &= vec.summary == leg.summary
    return ok


def run(verbose: bool = True):
    max_ranks = int(os.environ.get("ANALYTIC_SCALE_MAX_RANKS", SWEEP[-1]))
    budget = float(os.environ.get("ANALYTIC_SCALE_BUDGET_S", 10.0))
    sweep = [n for n in SWEEP if n <= max_ranks] or [SWEEP[0]]
    out = {"pp": PP, "domain_size": DOMAIN_SIZE, "budget_s": budget,
           "max_ranks": sweep[-1], "sweep": {}}
    for n in sweep:
        w = _workload(n)
        scn = _scenario(w)
        out["sweep"][str(n)] = row = {}
        for pol in _policies():
            _, row[pol.name] = _price(w, scn, pol)
            if verbose:
                r = row[pol.name]
                print(f"  ranks={n:>7d} {pol.name:<9s} "
                      f"wall={r['wall_seconds']:7.3f}s "
                      f"rel_thr={r['time_avg_rel_throughput']:.3f} "
                      f"edit={r['edit_seconds']:.2f}s "
                      f"full={r['full_rebuild_seconds']:.0f}s")
    out["oracle_ok"] = _oracle_check()
    worst = max(r["wall_seconds"] for r in out["sweep"][str(sweep[-1])].values())
    out["worst_wall_seconds"] = worst
    out["gate_ok"] = bool(out["oracle_ok"] and worst <= budget)
    if verbose:
        print(f"  oracle_ok={out['oracle_ok']} "
              f"worst_wall={worst:.3f}s budget={budget:.0f}s "
              f"gate_ok={out['gate_ok']}")
    return out


def main(out_path: str = "BENCH_analytic_scale.json"):
    t0 = time.perf_counter()
    result = run()
    us = (time.perf_counter() - t0) * 1e6
    Path(out_path).write_text(json.dumps(result, indent=2, sort_keys=True,
                                         default=float) + "\n")
    emit("analytic_scale", us,
         f"max_ranks={result['max_ranks']};"
         f"worst_wall={result['worst_wall_seconds']:.2f}s;"
         f"oracle_ok={result['oracle_ok']};gate_ok={result['gate_ok']}")
    if not result["gate_ok"]:
        raise SystemExit(
            f"analytic_scale gate failed: worst_wall="
            f"{result['worst_wall_seconds']:.2f}s budget="
            f"{result['budget_s']:.0f}s oracle_ok={result['oracle_ok']}")
    return result


if __name__ == "__main__":
    main()
