"""Fig. 11 — throughput under fail-stop shrinks (1/2/3 nodes) for the three
Llama-2 workloads, ElasWave vs ReCycle vs TorchFT.

Thin wrapper over the scenario engine: each (workload, shrink) pair is a
one-event SCALE_IN scenario replayed through ``AnalyticScenarioRunner`` for
every policy; rows keep the historical (wname, shrink, policy,
rel_throughput, feasible, decide_seconds) schema.
"""
from __future__ import annotations

import time

from repro.core.events import EventKind
from repro.core.policies import ElasWavePolicy, ReCyclePolicy, TorchFTPolicy
from repro.scenarios import AnalyticScenarioRunner, Scenario, node_shrink_cells
from .common import LLAMA2, WORKER_HW, analytic_workload, emit


def shrink_scenario(w, n_nodes: int) -> Scenario:
    if n_nodes == 0:
        return Scenario(f"failstop_shrink0", (), horizon=1)
    ranks = tuple(d * w["pp"] + p
                  for d, p in node_shrink_cells(n_nodes, w["dp"], w["pp"]))
    return Scenario.single(f"failstop_shrink{n_nodes}", EventKind.SCALE_IN,
                           step=0, ranks=ranks, horizon=1)


def run(verbose: bool = True):
    rows = []
    policies = [ElasWavePolicy(WORKER_HW), ReCyclePolicy(), TorchFTPolicy()]
    reference = ElasWavePolicy(WORKER_HW)
    for wname, w in LLAMA2.items():
        wl = analytic_workload(w)
        for shrink in (0, 1, 2, 3):
            scn = shrink_scenario(w, shrink)
            for pol in policies:
                res = AnalyticScenarioRunner(
                    scn, wl, pol, reference_policy=reference).run()
                rec = res.steps[-1]
                rows.append((wname, shrink, pol.name, rec["rel_throughput"],
                             rec["feasible"], rec["decide_wall_seconds"]))
                if verbose:
                    print(f"  {wname} shrink={shrink} {pol.name:9s} "
                          f"rel_throughput={rec['rel_throughput']:.3f} "
                          f"feasible={rec['feasible']}")
    # derived: ElasWave gain over baselines at 1-node shrink on 34B
    d = {(r[0], r[1], r[2]): r[3] for r in rows}
    g_re = d[("llama2-34b", 1, "elaswave")] / max(d[("llama2-34b", 1, "recycle")], 1e-9)
    g_tf = d[("llama2-34b", 1, "elaswave")] / max(d[("llama2-34b", 1, "torchft")], 1e-9)
    return rows, {"gain_vs_recycle_34b_1node": g_re,
                  "gain_vs_torchft_34b_1node": g_tf}


def main():
    t0 = time.perf_counter()
    rows, derived = run(verbose=True)
    us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)
    emit("fig11_throughput_failstop", us,
         f"elaswave/recycle={derived['gain_vs_recycle_34b_1node']:.2f}x;"
         f"elaswave/torchft={derived['gain_vs_torchft_34b_1node']:.2f}x")
    return derived


if __name__ == "__main__":
    main()
