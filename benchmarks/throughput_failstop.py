"""Fig. 11 — throughput under fail-stop shrinks (1/2/3 nodes) for the three
Llama-2 workloads, ElasWave vs ReCycle vs TorchFT."""
from __future__ import annotations

import time

import numpy as np

from repro.core.policies import ElasWavePolicy, ReCyclePolicy, TorchFTPolicy
from .common import LLAMA2, WORKER_HW, build_view, kill_nodes, emit


def run(verbose: bool = True):
    rows = []
    policies = [ElasWavePolicy(WORKER_HW), ReCyclePolicy(), TorchFTPolicy()]
    for wname, w in LLAMA2.items():
        seg, view0 = build_view(w)
        base = ElasWavePolicy(WORKER_HW).decide(seg, view0)
        thr0 = w["global_batch"] / base.step_time
        for shrink in (0, 1, 2, 3):
            for pol in policies:
                seg, view = build_view(w)
                kill_nodes(view, shrink)
                t0 = time.perf_counter()
                d = pol.decide(seg, view)
                dt = time.perf_counter() - t0
                thr = w["global_batch"] / d.step_time if d.feasible and \
                    np.isfinite(d.step_time) else 0.0
                rows.append((wname, shrink, pol.name, thr / thr0,
                             d.feasible, dt))
                if verbose:
                    print(f"  {wname} shrink={shrink} {pol.name:9s} "
                          f"rel_throughput={thr / thr0:.3f} "
                          f"feasible={d.feasible}")
    # derived: ElasWave gain over baselines at 1-node shrink on 34B
    d = {(r[0], r[1], r[2]): r[3] for r in rows}
    g_re = d[("llama2-34b", 1, "elaswave")] / max(d[("llama2-34b", 1, "recycle")], 1e-9)
    g_tf = d[("llama2-34b", 1, "elaswave")] / max(d[("llama2-34b", 1, "torchft")], 1e-9)
    return rows, {"gain_vs_recycle_34b_1node": g_re,
                  "gain_vs_torchft_34b_1node": g_tf}


def main():
    t0 = time.perf_counter()
    rows, derived = run(verbose=True)
    us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)
    emit("fig11_throughput_failstop", us,
         f"elaswave/recycle={derived['gain_vs_recycle_34b_1node']:.2f}x;"
         f"elaswave/torchft={derived['gain_vs_torchft_34b_1node']:.2f}x")
    return derived


if __name__ == "__main__":
    main()
