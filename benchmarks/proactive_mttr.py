"""BENCH_proactive — MTTR avoided by preemption-notice proactive drain.

Each scenario is run twice on identical tiny numeric workloads:

* **proactive** — the trace as written: ``PREEMPT_NOTICE`` events drain the
  doomed ranks inside the notice window (zero detection cost; communicator /
  remap / migration work overlaps ongoing training up to the deadline);
* **reactive** — ``Scenario.reactive_twin()``: every notice becomes a plain
  ``FAIL_STOP`` at the same step, so the executor pays the detection bound
  plus the full un-overlapped recovery stall.

Both runs execute the same recovery mechanics on the same state (losses are
bit-identical by construction — drain IS the shrink path), so the MTTR delta
isolates exactly what the advance warning buys:

``mttr_avoided = reactive_total - proactive_total``
              ``≈ detection bound + overlap_saved``

Emits ``BENCH_proactive.json``:

.. code-block:: json

    {"scenarios": {"single_preempt": {
        "proactive_mttr": ..., "reactive_mttr": ..., "mttr_avoided": ...,
        "overlap_saved": ..., "deadline": 120.0, "ok": true}, ...},
     "gate": {"all_avoided_positive": true}}

The gate is the acceptance criterion: ``mttr_avoided > 0`` on EVERY
preemption scenario; ``main`` exits non-zero otherwise.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path
from typing import Dict, Tuple

from repro.scenarios import ClusterScenarioRunner, ClusterWorkload, Scenario

from .common import emit

OUT = Path(__file__).resolve().parent.parent / "BENCH_proactive.json"


def _scenarios() -> Dict[str, Tuple[Scenario, ClusterWorkload]]:
    w32 = ClusterWorkload(dp=3, pp=2, num_layers=2, global_batch=12,
                          num_micro=2, seq_len=8, dropout_rate=0.0)
    w42 = ClusterWorkload(dp=4, pp=2, num_layers=2, global_batch=16,
                          num_micro=2, seq_len=8, dropout_rate=0.0)
    return {
        # the common case: a full two-minute spot notice hides all work
        "single_preempt": (Scenario.preempt_notice(
            "single_preempt", step=2, ranks=(w32.rank(1, 0),), horizon=5,
            deadline=120.0), w32),
        # a nearly-expired notice: only part of the work overlaps, the
        # detection bound is still avoided entirely
        "short_notice": (Scenario.preempt_notice(
            "short_notice", step=2, ranks=(w32.rank(1, 1),), horizon=5,
            deadline=0.05), w32),
        # a whole node: two workers in different stages, one notice burst
        "preempt_burst": (Scenario.preempt_notice(
            "preempt_burst", step=2,
            ranks=(w42.rank(1, 0), w42.rank(1, 1)), horizon=5,
            deadline=120.0), w42),
        # preempted capacity returns: drain, shrink, later rejoin
        "preempt_rejoin": (Scenario.preempt_notice(
            "preempt_rejoin", step=2, ranks=(w42.rank(2, 0),), horizon=7,
            deadline=120.0, rejoin_step=5), w42),
    }


def _total_mttr(result) -> float:
    return sum(r["mttr"].get("total", 0.0) for r in result.recoveries)


def run_pair(scn: Scenario, w: ClusterWorkload) -> Dict[str, float]:
    pro = ClusterScenarioRunner(scn, w).run()
    rea = ClusterScenarioRunner(scn.reactive_twin(), w).run()
    pro_t, rea_t = _total_mttr(pro), _total_mttr(rea)
    saved = sum(r["mttr"].get("overlap_saved", 0.0) for r in pro.recoveries)
    assert pro.summary["losses"] == rea.summary["losses"], \
        "proactive drain must be numerically identical to the reactive path"
    return {
        "proactive_mttr": pro_t,
        "reactive_mttr": rea_t,
        "mttr_avoided": rea_t - pro_t,
        "overlap_saved": saved,
        "deadline": float(scn.events[0].deadline),
        "ok": rea_t - pro_t > 0,
    }


def main() -> None:
    out: Dict[str, Dict] = {"scenarios": {}}
    for name, (scn, w) in _scenarios().items():
        rec = run_pair(scn, w)
        out["scenarios"][name] = rec
        emit(f"proactive/{name}", rec["proactive_mttr"] * 1e6,
             f"avoided={rec['mttr_avoided']:.4f}s "
             f"overlap={rec['overlap_saved']:.4f}s ok={rec['ok']}")
    all_ok = all(r["ok"] for r in out["scenarios"].values())
    out["gate"] = {"all_avoided_positive": all_ok}
    OUT.write_text(json.dumps(out, indent=2, sort_keys=True))
    print(f"wrote {OUT}")
    if not all_ok:
        bad = [n for n, r in out["scenarios"].items() if not r["ok"]]
        print(f"GATE FAILED: mttr_avoided <= 0 for {bad}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
