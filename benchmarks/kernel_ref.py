"""Kernel-vs-ref gate: timing + tolerance-tier conformance per Pallas kernel.

Runs the shared comparison corpus (``repro.kernels.check``) in interpret mode
on CPU, times both sides best-of-reps, and **exits nonzero** if any case
exceeds its declared tier in ``repro.kernels.ops.TOLERANCE_TIERS`` — this is
the CI gate for the kernel layer.  ``benchmarks.train_step_perf`` embeds the
same rows into ``BENCH_train_step.json`` so the perf artifact carries the
numerics evidence alongside the wall-clock numbers.

Interpret-mode timings measure the Pallas *interpreter* on CPU, not TPU
kernel performance; they are trajectory data (is interpret overhead stable
across commits?), never a speedup claim.  The ``within_tolerance`` column is
the load-bearing one.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

from repro.kernels.check import case_row, kernel_cases
from .common import emit

REPS = 3


def bench_kernels(seed: int = 0, reps: int = REPS) -> list:
    """One row per corpus case: the ``check.case_row`` comparison fields plus
    ``kernel_ms`` / ``ref_ms`` best-of-reps wall clock."""
    rows = []
    for case in kernel_cases(seed):
        row = case_row(case)        # also warms both sides
        best_k = best_r = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            case.run_kernel()
            best_k = min(best_k, time.perf_counter() - t0)
            t0 = time.perf_counter()
            case.run_ref()
            best_r = min(best_r, time.perf_counter() - t0)
        row["kernel_ms"] = best_k * 1e3
        row["ref_ms"] = best_r * 1e3
        rows.append(row)
    return rows


def run(verbose: bool = True, seed: int = 0) -> list:
    rows = bench_kernels(seed)
    if verbose:
        print(f"  {'case':34s} {'kernel_ms':>10s} {'ref_ms':>8s} "
              f"{'max_abs_err':>12s} {'tier':>16s} {'ok':>3s}")
        for r in rows:
            tier = f"{r['rtol']:g}/{r['atol']:g}"
            print(f"  {r['case']:34s} {r['kernel_ms']:10.2f} "
                  f"{r['ref_ms']:8.2f} {r['max_abs_err']:12.3e} "
                  f"{tier:>16s} {'ok' if r['within_tolerance'] else 'FAIL':>3s}")
    return rows


def main(out_path: str = "BENCH_kernel_ref.json") -> int:
    t0 = time.perf_counter()
    rows = run()
    us = (time.perf_counter() - t0) * 1e6
    Path(out_path).write_text(json.dumps({"rows": rows}, indent=2) + "\n")
    failures = [r["case"] for r in rows if not r["within_tolerance"]]
    emit("kernel_ref", us,
         f"cases={len(rows)};tier_failures={len(failures)}")
    if failures:
        print(f"FAIL: kernel(s) outside declared tolerance tier: {failures}")
    return len(failures)


if __name__ == "__main__":
    raise SystemExit(main())
