"""Benchmark aggregator — one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows (plus per-benchmark detail)."""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from . import (analytic_scale, communicator_mttr,
                   convergence_consistency, failslow, kernel_ref,
                   lse_breakdown, migration_mttr, moe_case, proactive_mttr,
                   roofline, scenarios_suite, serve_bench, snapshot_overhead,
                   spot_trace, throughput_failstop, train_step_perf)
    print("name,us_per_call,derived")
    mods = [
        ("fig11", throughput_failstop),
        ("fig12a", lse_breakdown),
        ("fig12b", communicator_mttr),
        ("fig13", migration_mttr),
        ("table3", snapshot_overhead),
        ("sec7.5", convergence_consistency),
        ("fig14", spot_trace),
        ("fig15a", failslow),
        ("sec7.7", moe_case),
        ("roofline", roofline),
        ("kernel_ref", kernel_ref),
        ("scenarios", scenarios_suite),
        ("bench_step", train_step_perf),
        ("bench_serve", serve_bench),
        ("analytic_scale", analytic_scale),
        ("proactive", proactive_mttr),
    ]
    failed = []
    for name, mod in mods:
        try:
            rc = mod.main()
            # gate-style benchmarks (kernel_ref, train_step_perf) return a
            # nonzero violation count instead of raising
            if isinstance(rc, int) and rc:
                failed.append(name)
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
