"""Fig. 13 — layer-migration MTTR: non-blocking + interleaved ZeRO (ours) vs
blocking + contiguous (baseline), moving 1/2/4 layers on the three Llama-2
models.

Thin wrapper over the scenario engine: a ``Scenario.migration_probe`` with
one MIGRATE event per layer count is replayed twice through
``AnalyticScenarioRunner`` — once under the baseline data-plane config
(contiguous layout, blocking copy) and once under ours (interleaved,
non-blocking) — and the per-event stall seconds are read from the recovery
records.
"""
from __future__ import annotations

import time

from repro.scenarios import AnalyticScenarioRunner, Scenario
from .common import LLAMA2, analytic_workload, emit

N_LAYERS = (1, 2, 4)


def run(verbose=True):
    rows = []
    probes = [tuple(range(n)) for n in N_LAYERS]
    for wname, w in LLAMA2.items():
        wl = analytic_workload(w)
        scn = Scenario.migration_probe(f"migration_{wname}", probes,
                                       src=0, dst=1)
        stalls = {}
        for mode, layout, blocking in (
                ("baseline", "contiguous", True),
                ("ours", "interleaved", False)):
            res = AnalyticScenarioRunner(
                scn, wl, _NullPolicy(), zero_layout=layout,
                blocking_migration=blocking).run()
            stalls[mode] = [r["mttr"]["migration"] for r in res.recoveries]
        for i, n_layers in enumerate(N_LAYERS):
            t_base, t_ours = stalls["baseline"][i], stalls["ours"][i]
            red = 1 - t_ours / t_base
            rows.append((wname, n_layers, t_base, t_ours, red))
            if verbose:
                print(f"  {wname} layers={n_layers}: blocking+contig="
                      f"{t_base:.3f}s nonblock+interleaved={t_ours:.3f}s"
                      f" (-{red * 100:.0f}%)")
    return rows


class _NullPolicy:
    """Migration probes need no throughput decision; keep the runner's
    decision hook trivial and infinitely fast."""
    name = "null"

    def decide(self, seg, view):
        from repro.core.policies import Decision
        return Decision(self.name, 1.0, True, {})


def main():
    t0 = time.perf_counter()
    rows = run()
    us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)
    best = max(r[4] for r in rows)
    emit("fig13_migration_mttr", us, f"max_mttr_reduction={best * 100:.0f}%")
    return rows


if __name__ == "__main__":
    main()
