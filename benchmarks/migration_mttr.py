"""Fig. 13 — layer-migration MTTR: non-blocking + interleaved ZeRO (ours) vs
blocking + contiguous (baseline), moving 1/2/4 layers on the three Llama-2
models."""
from __future__ import annotations

import time

from repro.core.cost_model import SegmentCosts
from repro.core.migration import MigrationSpec, migration_timing
from .common import LLAMA2, WORKER_HW, emit


def run(verbose=True):
    rows = []
    for wname, w in LLAMA2.items():
        cfg, dp = w["cfg"], w["dp"]
        seg = SegmentCosts.build(cfg, w["seq"], WORKER_HW)
        # compute window: one step's compute on a balanced stage
        L, pp = cfg.num_layers, w["pp"]
        fl = seg.seg_fwd_flops(0, L // pp - 1, w["mbs"]) * 3
        window = fl / (WORKER_HW.peak_flops * WORKER_HW.mfu) * \
            (w["global_batch"] // (w["mbs"] * dp))
        for n_layers in (1, 2, 4):
            pbytes = int(sum(seg.param_bytes[:n_layers]))
            obytes = int(sum(seg.opt_bytes[:n_layers]))
            t = {}
            for mode, layout, blocking in (
                    ("baseline", "contiguous", True),
                    ("ours", "interleaved", False)):
                spec = MigrationSpec(tuple(range(n_layers)), 0, 1, pbytes,
                                     obytes, dp, layout, blocking)
                tm = migration_timing(spec, WORKER_HW.link_bw, window)
                t[mode] = tm.stall_seconds
            red = 1 - t["ours"] / t["baseline"]
            rows.append((wname, n_layers, t["baseline"], t["ours"], red))
            if verbose:
                print(f"  {wname} layers={n_layers}: blocking+contig="
                      f"{t['baseline']:.3f}s nonblock+interleaved={t['ours']:.3f}s"
                      f" (-{red * 100:.0f}%)")
    return rows


def main():
    t0 = time.perf_counter()
    rows = run()
    us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)
    best = max(r[4] for r in rows)
    emit("fig13_migration_mttr", us, f"max_mttr_reduction={best * 100:.0f}%")
    return rows


if __name__ == "__main__":
    main()
