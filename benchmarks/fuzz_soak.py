"""Unbounded fuzz soak + one-line failure reproduction.

Nightly CI runs this with a large trace budget; every failure is greedily
minimized (single-event deletion, legality re-checked per candidate) and
written as a JSON artifact carrying the seed, the policy, the error, the
minimized trace, and the exact repro command.

Usage:
    PYTHONPATH=src python -m benchmarks.fuzz_soak --traces 2000 \
        --numeric-traces 40 --out fuzz_artifacts
    PYTHONPATH=src python -m benchmarks.fuzz_soak --mode analytic --seed 17 \
        --policy oobleck          # reproduce one failure (the printed line)

Exit status is the number of failing (seed, policy) pairs (0 = clean soak).
Not registered in benchmarks/run.py: this is correctness tooling, not a
paper figure.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import traceback

from repro.scenarios import (POLICY_NAMES, make_case, run_case,
                             run_chaos_case, shrink_case)


def _case_record(case, policy, err):
    rec = {
        "seed": case.seed,
        "mode": case.mode,
        "policy": policy,
        "workload": case.workload.describe(),
        "horizon": case.scenario.horizon,
        "events": [e.describe() for e in case.scenario.events],
        "error": str(err),
        "repro": case.repro(policy),
    }
    if case.mode == "chaos":
        rec["chaos_class"] = case.chaos_class
        rec["actions"] = [f"step={a.step} {a.kind} rank={a.rank}"
                          for a in case.actions]
    return rec


def _run(case, policy):
    if case.mode == "chaos":
        run_chaos_case(case)            # perturbed-detection-plane property
    else:
        run_case(case, policy=policy)   # perfectly-detected trace invariants


def _soak_one(mode: str, seed: int, policy, out_dir, minimize: bool):
    """Returns None on success, else the JSON failure record."""
    case = make_case(mode, seed)
    try:
        _run(case, policy)
        return None
    except Exception as err:                                # noqa: BLE001
        first_err = err

    rec = _case_record(case, policy, first_err)
    if minimize and mode != "chaos":    # chaos repro = seed, nothing to shrink
        def fails(c):
            try:
                run_case(c, policy=policy)
                return False
            except Exception:                               # noqa: BLE001
                return True

        small = shrink_case(case, fails)
        rec["minimized_events"] = [e.describe()
                                   for e in small.scenario.events]
        rec["minimized_from"] = len(case.scenario.events)
    if out_dir is not None:
        out_dir.mkdir(parents=True, exist_ok=True)
        path = out_dir / f"fuzz-{mode}-{seed}-{policy or 'default'}.json"
        path.write_text(json.dumps(rec, indent=2))
        rec["artifact"] = str(path)
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--traces", type=int, default=200,
                    help="analytic trace budget (x all three policies)")
    ap.add_argument("--numeric-traces", type=int, default=0,
                    help="numeric (VirtualCluster) trace budget — slow: "
                         "every cluster jit-compiles afresh")
    ap.add_argument("--pallas-traces", type=int, default=0,
                    help="pallas-mode numeric trace budget (kernels in the "
                         "hot path, tolerance-tier invariant 1) — slowest: "
                         "interpret-mode kernels on top of fresh jits")
    ap.add_argument("--chaos-traces", type=int, default=0,
                    help="detection-chaos trace budget (VirtualCluster under "
                         "dropped/delayed/duplicated/flapping probes and "
                         "corrupted snapshot shards) — slow, like "
                         "--numeric-traces")
    ap.add_argument("--base-seed", type=int, default=0,
                    help="first seed of the sweep")
    ap.add_argument("--seed", type=int, default=None,
                    help="reproduce exactly one seed and exit")
    ap.add_argument("--mode",
                    choices=("analytic", "cluster", "pallas", "chaos"),
                    default="analytic", help="mode for --seed repro runs")
    ap.add_argument("--policy", choices=POLICY_NAMES, default=None,
                    help="restrict to one policy (analytic mode)")
    ap.add_argument("--out", default="fuzz_artifacts",
                    help="directory for minimized-failure JSON artifacts")
    ap.add_argument("--no-minimize", action="store_true",
                    help="skip greedy trace minimization on failure")
    args = ap.parse_args(argv)
    out_dir = pathlib.Path(args.out)
    minimize = not args.no_minimize

    if args.seed is not None:               # one-line failure reproduction
        case = make_case(args.mode, args.seed)
        print(f"# {case.mode} seed {args.seed}: horizon "
              f"{case.scenario.horizon}, workload {case.workload.describe()}")
        for e in case.scenario.events:
            print(f"#   {e.describe()}")
        if args.mode == "chaos":
            print(f"# chaos class {case.chaos_class}; ground truth:")
            for a in case.actions:
                print(f"#   step={a.step} {a.kind} rank={a.rank}")
        policies = ([args.policy] if args.policy
                    else (list(POLICY_NAMES) if args.mode == "analytic"
                          else [None]))
        status = 0
        for pol in policies:
            try:
                _run(case, pol)
                print(f"PASS {pol or args.mode}")
            except Exception:                               # noqa: BLE001
                traceback.print_exc()
                status += 1
        return status

    failures = []
    runs = 0
    plan = [("analytic", args.traces,
             [args.policy] if args.policy else list(POLICY_NAMES)),
            ("cluster", args.numeric_traces, [None]),
            ("pallas", args.pallas_traces, [None]),
            ("chaos", args.chaos_traces, [None])]
    for mode, budget, policies in plan:
        for i in range(budget):
            seed = args.base_seed + i
            for pol in policies:
                runs += 1
                rec = _soak_one(mode, seed, pol, out_dir, minimize)
                if rec is not None:
                    failures.append(rec)
                    n_min = len(rec.get("minimized_events",
                                        rec["events"]))
                    print(f"FAIL {mode} seed {seed} "
                          f"policy={pol or mode} "
                          f"({rec.get('minimized_from', '?')}"
                          f" -> {n_min} events)\n  {rec['repro']}",
                          file=sys.stderr)
    print(f"fuzz soak: {runs} runs, {len(failures)} failures"
          + (f" (artifacts in {out_dir})" if failures else ""))
    return len(failures)


if __name__ == "__main__":
    raise SystemExit(main())
