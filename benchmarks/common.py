"""Shared benchmark fixtures: the paper's Table-2 workloads + helpers.

The view-building / shrink-pattern logic lives in ``repro.scenarios.spec``
(the scenario engine is the canonical implementation); this module keeps the
workload tables and thin compatibility wrappers for the benchmark scripts.
"""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

import numpy as np

from repro.core.cost_model import HardwareSpec, SegmentCosts
from repro.core.policies import ClusterView
from repro.models.config import ModelConfig
from repro.scenarios.spec import AnalyticWorkload, node_shrink_cells

# Paper Table 2 — Llama-2 workloads on 96 NPUs (TP=4 fixed; workers = TP
# groups; one node = 8 NPUs = 2 workers).
LLAMA2 = {
    "llama2-7b": dict(
        cfg=ModelConfig(name="llama2-7b", family="dense", num_layers=32,
                        d_model=4096, num_heads=32, num_kv_heads=32,
                        d_ff=11008, vocab_size=32000),
        tp=4, pp=3, dp=8, mbs=4, global_batch=8192, seq=4096),
    "llama2-13b": dict(
        cfg=ModelConfig(name="llama2-13b", family="dense", num_layers=40,
                        d_model=5120, num_heads=40, num_kv_heads=40,
                        d_ff=13824, vocab_size=32000),
        tp=4, pp=6, dp=4, mbs=2, global_batch=2048, seq=4096),
    "llama2-34b": dict(
        cfg=ModelConfig(name="llama2-34b", family="dense", num_layers=48,
                        d_model=8192, num_heads=64, num_kv_heads=8,
                        d_ff=22016, vocab_size=32000),
        tp=4, pp=8, dp=3, mbs=1, global_batch=768, seq=4096),
}

# a TP-4 worker of Ascend-910B-like chips, normalized
WORKER_HW = HardwareSpec(peak_flops=4 * 376e12 / 2, hbm_bw=4 * 1.6e12,
                         link_bw=25e9, hbm_bytes=4 * 32e9, mfu=0.4)


def analytic_workload(w: Dict, mem_cap=None) -> AnalyticWorkload:
    """A Table-2 workload dict as a scenario-engine AnalyticWorkload."""
    return AnalyticWorkload(cfg=w["cfg"], dp=w["dp"], pp=w["pp"], mbs=w["mbs"],
                            global_batch=w["global_batch"], seq=w["seq"],
                            hw=WORKER_HW, mem_cap=mem_cap)


def build_view(w: Dict, alive=None, slow=None, mem_cap=None) -> Tuple[SegmentCosts, ClusterView]:
    wl = analytic_workload(w, mem_cap=mem_cap)
    seg = wl.build_seg()
    return seg, wl.build_view(seg, alive=alive, slow=slow)


def kill_nodes(view: ClusterView, n_nodes: int):
    """One node = 2 workers: kill cells (d, p) pairs replica-major, matching
    the paper's shrink pattern (distinct replicas first).  The cell sequence
    is ``repro.scenarios.spec.node_shrink_cells`` — shared with the scenario
    engine's capacity-trace events."""
    for d, p in node_shrink_cells(n_nodes, view.dp, view.pp):
        view.alive[d, p] = False
    return view


def timeit(fn, *args, reps=3, **kw):
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    return (time.perf_counter() - t0) / reps, out


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
