"""Shared benchmark fixtures: the paper's Table-2 workloads + helpers."""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

import numpy as np

from repro.core.cost_model import HardwareSpec, SegmentCosts
from repro.core.policies import ClusterView
from repro.models.config import ModelConfig

# Paper Table 2 — Llama-2 workloads on 96 NPUs (TP=4 fixed; workers = TP
# groups; one node = 8 NPUs = 2 workers).
LLAMA2 = {
    "llama2-7b": dict(
        cfg=ModelConfig(name="llama2-7b", family="dense", num_layers=32,
                        d_model=4096, num_heads=32, num_kv_heads=32,
                        d_ff=11008, vocab_size=32000),
        tp=4, pp=3, dp=8, mbs=4, global_batch=8192, seq=4096),
    "llama2-13b": dict(
        cfg=ModelConfig(name="llama2-13b", family="dense", num_layers=40,
                        d_model=5120, num_heads=40, num_kv_heads=40,
                        d_ff=13824, vocab_size=32000),
        tp=4, pp=6, dp=4, mbs=2, global_batch=2048, seq=4096),
    "llama2-34b": dict(
        cfg=ModelConfig(name="llama2-34b", family="dense", num_layers=48,
                        d_model=8192, num_heads=64, num_kv_heads=8,
                        d_ff=22016, vocab_size=32000),
        tp=4, pp=8, dp=3, mbs=1, global_batch=768, seq=4096),
}

# a TP-4 worker of Ascend-910B-like chips, normalized
WORKER_HW = HardwareSpec(peak_flops=4 * 376e12 / 2, hbm_bw=4 * 1.6e12,
                         link_bw=25e9, hbm_bytes=4 * 32e9, mfu=0.4)


def build_view(w: Dict, alive=None, slow=None, mem_cap=None) -> Tuple[SegmentCosts, ClusterView]:
    cfg, dp, pp = w["cfg"], w["dp"], w["pp"]
    seg = SegmentCosts.build(cfg, w["seq"], WORKER_HW)
    num_micro = w["global_batch"] // (w["mbs"] * dp)
    L = cfg.num_layers
    per = L // pp
    rem = L % pp
    ranges, a = [], 0
    for p in range(pp):
        b = a + per + (1 if p < rem else 0) - 1
        ranges.append((a, b)); a = b + 1
    view = ClusterView(
        dp=dp, pp=pp, global_batch=w["global_batch"], num_micro=num_micro,
        seq=w["seq"], layer_assignment=ranges,
        alive=alive if alive is not None else np.ones((dp, pp), bool),
        freq=np.ones((dp, pp)), slow=slow if slow is not None else np.ones((dp, pp)),
        mem_cap=mem_cap if mem_cap is not None else WORKER_HW.hbm_bytes)
    return seg, view


def kill_nodes(view: ClusterView, n_nodes: int):
    """One node = 2 workers: kill cells (d, p) pairs replica-major, matching
    the paper's shrink pattern (distinct replicas first)."""
    killed = 0
    d = 0
    while killed < 2 * n_nodes and d < view.dp:
        for p in (0, 1):
            if killed < 2 * n_nodes:
                view.alive[d % view.dp, (p + d) % view.pp] = False
                killed += 1
        d += 1
    return view


def timeit(fn, *args, reps=3, **kw):
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    return (time.perf_counter() - t0) / reps, out


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
