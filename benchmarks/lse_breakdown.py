"""Fig. 12a — Linear Scaling Efficiency breakdown: +resize / +migration /
+DVFS ablation under 1/2/3-node failures.

LSE = (post-failure throughput / fault-free throughput) divided by the ideal
linear fraction (surviving compute / total compute)."""
from __future__ import annotations

import time

import numpy as np

from repro.core.policies import ElasWavePolicy
from .common import LLAMA2, WORKER_HW, build_view, kill_nodes, emit


def lse(w, shrink, use_migration, use_dvfs):
    seg, view = build_view(w)
    base = ElasWavePolicy(WORKER_HW).decide(seg, view)
    thr0 = w["global_batch"] / base.step_time
    seg, view = build_view(w)
    kill_nodes(view, shrink)
    alive_frac = view.alive.sum() / view.alive.size
    pol = ElasWavePolicy(WORKER_HW, use_dvfs=use_dvfs,
                         use_migration=use_migration)
    d = pol.decide(seg, view)
    if not d.feasible or not np.isfinite(d.step_time):
        return 0.0
    thr = w["global_batch"] / d.step_time
    return (thr / thr0) / alive_frac


def run(verbose=True):
    rows = []
    for wname, w in LLAMA2.items():
        for shrink in (1, 2, 3):
            l_resize = lse(w, shrink, use_migration=False, use_dvfs=False)
            l_migr = lse(w, shrink, use_migration=True, use_dvfs=False)
            l_full = lse(w, shrink, use_migration=True, use_dvfs=True)
            rows.append((wname, shrink, l_resize, l_migr, l_full))
            if verbose:
                print(f"  {wname} shrink={shrink}: resize-only LSE={l_resize:.3f}"
                      f" +migration={l_migr:.3f} +DVFS={l_full:.3f}")
    return rows


def main():
    t0 = time.perf_counter()
    rows = run()
    us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)
    final = [r[4] for r in rows if r[4] > 0]
    gains = [(r[3] - r[2], r[4] - r[3]) for r in rows if r[4] > 0]
    mig_share = np.mean([g[0] / max(g[0] + g[1], 1e-9) for g in gains
                         if g[0] + g[1] > 1e-9]) if gains else 0.0
    emit("fig12a_lse_breakdown", us,
         f"min_LSE={min(final):.2f};migration_share={mig_share:.2f}")
    return rows


if __name__ == "__main__":
    main()
