"""Scenario suite — the named cluster-mode scenarios the pre-engine scripts
could not express (concurrent burst, shrink-then-regrow rejoin, cascading
fail-slow with DVFS absorption), run end-to-end on the VirtualCluster with
real numerics.

Emits one row with the headline shape of each scenario; pass
``--artifacts-dir`` (via ``main(artifacts_dir=...)``) to keep the JSON
records.
"""
from __future__ import annotations

import time

from repro.scenarios import get_scenario, run_scenario
from .common import emit

SUITE = ("concurrent_burst", "shrink_regrow", "cascading_failslow")


def run(verbose=True, artifacts_dir=None):
    results = {}
    for name in SUITE:
        res = run_scenario(*get_scenario(name))
        results[name] = res
        if artifacts_dir:
            res.write(artifacts_dir)
        if verbose:
            s = res.summary
            print(f"  {name}: recoveries={s['n_recoveries']} "
                  f"mttr={s['mttr_total']:.3f}s "
                  f"loss {s['first_loss']:.3f}->{s['final_loss']:.3f}")
    return results


def main(artifacts_dir=None):
    t0 = time.perf_counter()
    results = run(artifacts_dir=artifacts_dir)
    us = (time.perf_counter() - t0) * 1e6 / max(len(results), 1)
    burst = results["concurrent_burst"]
    regrow = results["shrink_regrow"]
    widths = [s["dp_width"] for s in regrow.steps]
    casc = results["cascading_failslow"]
    t_series = [s["step_time"] for s in casc.steps]
    # DVFS absorption: step time after the setpoint < peak degraded time
    absorbed = t_series[-1] < max(t_series)
    emit("scenario_suite", us,
         f"burst_mttr={burst.mttr_total:.2f}s;"
         f"rejoin_width={widths[0]}->{min(widths)}->{widths[-1]};"
         f"dvfs_absorbed={absorbed}")
    return results


if __name__ == "__main__":
    main()
