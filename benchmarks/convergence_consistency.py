"""§7.5 — convergence consistency: average |loss_normal - loss_elastic| with
and without RNG resharding, on the VirtualCluster with dropout enabled.

The paper finetunes Llama2-7B/LoRA on GSM8K (8->7 NPUs) and reports a 78%
deviation reduction.  We run the same protocol shape at reduced scale: train,
fail one rank mid-run, continue; compare to the fault-free twin under both
RNG modes."""
from __future__ import annotations

import time

import numpy as np

from repro.core.cluster import VirtualCluster
from repro.models import registry as R
from .common import emit

CFG = R.tiny_config("dense", num_layers=8, dropout_rate=0.1)


def deviation(rng_mode: str, steps_pre=3, steps_post=5) -> float:
    base = VirtualCluster(CFG, dp=4, pp=2, global_batch=16, num_micro=2,
                          seq_len=16, seed=0, rng_mode=rng_mode)
    base_losses = base.run(steps_pre + steps_post)
    el = VirtualCluster(CFG, dp=4, pp=2, global_batch=16, num_micro=2,
                        seq_len=16, seed=0, rng_mode=rng_mode)
    losses = el.run(steps_pre)
    el.recover_fail_stop(1, 1)
    losses += el.run(steps_post)
    dev = np.abs(np.array(base_losses) - np.array(losses))[steps_pre:]
    return float(np.mean(dev))


def run(verbose=True):
    d_with = deviation("reshard")
    d_without = deviation("naive")
    reduction = 1 - d_with / max(d_without, 1e-12)
    if verbose:
        print(f"  avg |loss_normal - loss_elastic| w/o RNG reshard: {d_without:.6f}")
        print(f"  avg |loss_normal - loss_elastic| w/  RNG reshard: {d_with:.8f}")
        print(f"  deviation reduction: {reduction * 100:.1f}% (paper: 78%)")
    return d_with, d_without, reduction


def main():
    t0 = time.perf_counter()
    d_with, d_without, reduction = run()
    us = (time.perf_counter() - t0) * 1e6
    emit("sec7p5_convergence_consistency", us,
         f"reduction={reduction * 100:.1f}%;dev_with={d_with:.2e};"
         f"dev_without={d_without:.2e}")
    return reduction


if __name__ == "__main__":
    main()
