"""§Roofline — aggregate the dry-run artifacts into the per-(arch x shape x
mesh) roofline table: three terms, bottleneck, MODEL_FLOPS/HLO_FLOPs ratio.

Also appends the kernel-vs-ref rows (``benchmarks.kernel_ref`` corpus): per
Pallas kernel, interpret-mode wall clock vs the jnp oracle and the measured
error against its declared tolerance tier.  On CPU these time the Pallas
interpreter — trajectory data for the kernel layer, not a TPU roofline."""
from __future__ import annotations

import json
import time
from pathlib import Path

from .common import emit

ART_DIR = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def load_artifacts():
    arts = []
    for f in sorted(ART_DIR.glob("*.json")):
        arts.append(json.loads(f.read_text()))
    return arts


def table(arts, mesh="single", verbose=True):
    rows = []
    hdr = (f"{'arch':26s} {'shape':12s} {'compute_s':>10s} {'memory_s':>10s} "
           f"{'coll_s':>10s} {'bottleneck':>10s} {'useful':>7s}")
    if verbose:
        print("  " + hdr)
    for a in arts:
        if a.get("mesh") != mesh:
            continue
        if a["status"] == "skipped":
            if verbose:
                print(f"  {a['arch']:26s} {a['shape']:12s} "
                      f"{'—':>10s} {'—':>10s} {'—':>10s} {'skipped':>10s}")
            rows.append((a["arch"], a["shape"], None))
            continue
        r = a["roofline"]
        uf = a.get("useful_fraction")
        if verbose:
            print(f"  {a['arch']:26s} {a['shape']:12s} "
                  f"{r['compute_s']:10.3g} {r['memory_s']:10.3g} "
                  f"{r['collective_s']:10.3g} {r['bottleneck']:>10s} "
                  f"{uf:7.3f}" if uf else "")
        rows.append((a["arch"], a["shape"], r))
    return rows


def kernel_table(verbose=True):
    """Kernel-vs-ref rows: interpret-mode kernel vs jnp oracle wall clock and
    max error against the declared tier (``kernels.ops.TOLERANCE_TIERS``)."""
    from .kernel_ref import bench_kernels
    rows = bench_kernels()
    if verbose:
        print(f"  {'kernel case':34s} {'kernel_ms':>10s} {'ref_ms':>8s} "
              f"{'max_abs_err':>12s} {'ok':>4s}")
        for r in rows:
            print(f"  {r['case']:34s} {r['kernel_ms']:10.2f} "
                  f"{r['ref_ms']:8.2f} {r['max_abs_err']:12.3e} "
                  f"{'ok' if r['within_tolerance'] else 'FAIL':>4s}")
    return rows


def main():
    t0 = time.perf_counter()
    arts = load_artifacts()
    ok = [a for a in arts if a["status"] == "ok"]
    skipped = [a for a in arts if a["status"] == "skipped"]
    rows = table(arts, "single")
    us = (time.perf_counter() - t0) * 1e6
    bcounts = {}
    for a in ok:
        if a["mesh"] == "single":
            b = a["roofline"]["bottleneck"]
            bcounts[b] = bcounts.get(b, 0) + 1
    emit("roofline_dryrun", us,
         f"cells_ok={len(ok)};skipped={len(skipped)};bottlenecks={bcounts}")
    t0 = time.perf_counter()
    krows = kernel_table()
    us = (time.perf_counter() - t0) * 1e6
    nfail = sum(1 for r in krows if not r["within_tolerance"])
    emit("roofline_kernels", us,
         f"cases={len(krows)};tier_failures={nfail}")
    return rows


if __name__ == "__main__":
    main()
