"""§Roofline — aggregate the dry-run artifacts into the per-(arch x shape x
mesh) roofline table: three terms, bottleneck, MODEL_FLOPS/HLO_FLOPs ratio."""
from __future__ import annotations

import json
import time
from pathlib import Path

from .common import emit

ART_DIR = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def load_artifacts():
    arts = []
    for f in sorted(ART_DIR.glob("*.json")):
        arts.append(json.loads(f.read_text()))
    return arts


def table(arts, mesh="single", verbose=True):
    rows = []
    hdr = (f"{'arch':26s} {'shape':12s} {'compute_s':>10s} {'memory_s':>10s} "
           f"{'coll_s':>10s} {'bottleneck':>10s} {'useful':>7s}")
    if verbose:
        print("  " + hdr)
    for a in arts:
        if a.get("mesh") != mesh:
            continue
        if a["status"] == "skipped":
            if verbose:
                print(f"  {a['arch']:26s} {a['shape']:12s} "
                      f"{'—':>10s} {'—':>10s} {'—':>10s} {'skipped':>10s}")
            rows.append((a["arch"], a["shape"], None))
            continue
        r = a["roofline"]
        uf = a.get("useful_fraction")
        if verbose:
            print(f"  {a['arch']:26s} {a['shape']:12s} "
                  f"{r['compute_s']:10.3g} {r['memory_s']:10.3g} "
                  f"{r['collective_s']:10.3g} {r['bottleneck']:>10s} "
                  f"{uf:7.3f}" if uf else "")
        rows.append((a["arch"], a["shape"], r))
    return rows


def main():
    t0 = time.perf_counter()
    arts = load_artifacts()
    ok = [a for a in arts if a["status"] == "ok"]
    skipped = [a for a in arts if a["status"] == "skipped"]
    rows = table(arts, "single")
    us = (time.perf_counter() - t0) * 1e6
    bcounts = {}
    for a in ok:
        if a["mesh"] == "single":
            b = a["roofline"]["bottleneck"]
            bcounts[b] = bcounts.get(b, 0) + 1
    emit("roofline_dryrun", us,
         f"cells_ok={len(ok)};skipped={len(skipped)};bottlenecks={bcounts}")
    return rows


if __name__ == "__main__":
    main()
