"""BENCH_train_step — old-vs-new step and recovery wall clock.

The repo's first perf-trajectory artifact: times ``VirtualCluster.train_step``
and the recovery executor on the reduced workload used by
``benchmarks/snapshot_overhead.py`` (dp=2, pp=2, 4-layer tiny config), old
(seed, ``fast_path=False``) vs new (flat-state fast path), and emits
``BENCH_train_step.json``:

.. code-block:: json

    {
      "workload": {"dp": 2, "pp": 2, "num_layers": 4, "global_batch": 8,
                   "num_micro": 2, "seq_len": 16},
      "step":     {"ref_ms": ..., "fast_ms": ..., "speedup": ...},
      "recovery": {"fail_stop":         {"ref_ms": ..., "fast_ms": ..., "speedup": ...},
                   "scale_out":         {"ref_ms": ..., "fast_ms": ..., "speedup": ...},
                   "fail_slow_migrate": {"ref_ms": ..., "fast_ms": ..., "speedup": ...}},
      "pallas_step": {"jnp_ms": ..., "pallas_ms": ..., "interpret": true,
                      "loss_abs_diff": ...},
      "kernels": [{"kernel": ..., "case": ..., "kernel_ms": ..., "ref_ms": ...,
                   "max_abs_err": ..., "rtol": ..., "atol": ...,
                   "within_tolerance": true}, ...],
      "reps": 5, "steps_per_rep": 3
    }

Timings are best-of-reps (resists scheduler noise on shared machines); the
two paths are bit-identical in numerics (tests/test_fast_path_numerics.py),
so this measures pure implementation overhead.  Informational: consumers
should track the trajectory of ``speedup`` across commits, not gate on
absolute numbers — EXCEPT ``kernels[*].within_tolerance``, which is the
kernel-vs-ref numerics gate (``main`` exits nonzero on a violation, and CI
fails the build).  ``pallas_step`` runs the same workload with
``use_pallas=True``: on this CPU container the kernels execute under the
Pallas interpreter, so ``pallas_ms`` measures interpreter overhead, not TPU
speedup; ``loss_abs_diff`` is the observed pallas-vs-jnp divergence after
one step.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

from repro.core.cluster import VirtualCluster
from repro.models import registry as R
from .common import emit

WORKLOAD = dict(dp=2, pp=2, global_batch=8, num_micro=2, seq_len=16, seed=0)
NUM_LAYERS = 4
REPS = 5
STEPS_PER_REP = 3


def _mk(fast: bool, use_pallas: bool = False) -> VirtualCluster:
    cfg = R.tiny_config("dense", num_layers=NUM_LAYERS)
    return VirtualCluster(cfg, fast_path=fast, use_pallas=use_pallas,
                          **WORKLOAD)


def bench_step() -> dict:
    """Best-of-reps per-step wall time, interleaved so both paths see the
    same machine conditions."""
    cls = {fast: _mk(fast) for fast in (False, True)}
    for cl in cls.values():
        cl.run(1)       # compile / warm caches
    best = {False: float("inf"), True: float("inf")}
    for _ in range(REPS):
        for fast in (False, True):
            t0 = time.perf_counter()
            cls[fast].run(STEPS_PER_REP)
            best[fast] = min(best[fast],
                             (time.perf_counter() - t0) / STEPS_PER_REP)
    return {"ref_ms": best[False] * 1e3, "fast_ms": best[True] * 1e3,
            "speedup": best[False] / best[True]}


def bench_recovery() -> dict:
    """Wall clock of the recovery executor itself (plan + communicator edit
    + live remap + migration + dataflow): fail-stop, rejoin, and a
    migration-heavy fail-slow (layer rebalance — where the fast path's
    zero-rebuild of untouched stages pays), old vs new.  Fresh clusters per
    rep: recovery mutates group membership."""
    best = {k: {False: float("inf"), True: float("inf")}
            for k in ("fail_stop", "scale_out", "fail_slow_migrate")}
    for _ in range(REPS):
        for fast in (False, True):
            cl = _mk(fast)
            cl.run(1)
            t0 = time.perf_counter()
            cl.recover_fail_stop(1, 1)
            best["fail_stop"][fast] = min(best["fail_stop"][fast],
                                          time.perf_counter() - t0)
            t0 = time.perf_counter()
            cl.recover_scale_out(1, 1)
            best["scale_out"][fast] = min(best["scale_out"][fast],
                                          time.perf_counter() - t0)
            cl.inject_fail_slow(0, 0, 1.6)
            t0 = time.perf_counter()
            cl.recover_fail_slow(0, 0, 1.6)
            best["fail_slow_migrate"][fast] = min(
                best["fail_slow_migrate"][fast], time.perf_counter() - t0)
    return {k: {"ref_ms": v[False] * 1e3, "fast_ms": v[True] * 1e3,
                "speedup": v[False] / v[True]}
            for k, v in best.items()}


def bench_pallas_step(reps: int = 2, steps: int = 2) -> dict:
    """Per-step wall clock with the Pallas kernels in the hot path vs plain
    jnp, plus the observed loss divergence after the first step.  Fewer reps
    than the fast/legacy comparison: interpret-mode kernels are slow and this
    row is trajectory data, not a speedup claim."""
    import os
    cls = {up: _mk(True, use_pallas=up) for up in (False, True)}
    loss = {up: float(cl.train_step()) for up, cl in cls.items()}  # + compile
    best = {False: float("inf"), True: float("inf")}
    for _ in range(reps):
        for up in (False, True):
            t0 = time.perf_counter()
            cls[up].run(steps)
            best[up] = min(best[up], (time.perf_counter() - t0) / steps)
    return {"jnp_ms": best[False] * 1e3, "pallas_ms": best[True] * 1e3,
            "interpret": os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0",
            "loss_abs_diff": abs(loss[True] - loss[False])}


def run(verbose: bool = True) -> dict:
    from .kernel_ref import bench_kernels
    step = bench_step()
    recovery = bench_recovery()
    pallas_step = bench_pallas_step()
    kernels = bench_kernels()
    result = {
        "workload": {**{k: v for k, v in WORKLOAD.items() if k != "seed"},
                     "num_layers": NUM_LAYERS},
        "step": step,
        "recovery": recovery,
        "pallas_step": pallas_step,
        "kernels": kernels,
        "reps": REPS,
        "steps_per_rep": STEPS_PER_REP,
    }
    if verbose:
        print(f"  step: ref={step['ref_ms']:.1f}ms fast={step['fast_ms']:.1f}ms "
              f"speedup={step['speedup']:.2f}x")
        for k, v in recovery.items():
            print(f"  {k}: ref={v['ref_ms']:.2f}ms fast={v['fast_ms']:.2f}ms "
                  f"speedup={v['speedup']:.2f}x")
        print(f"  pallas_step: jnp={pallas_step['jnp_ms']:.1f}ms "
              f"pallas={pallas_step['pallas_ms']:.1f}ms "
              f"(interpret={pallas_step['interpret']}) "
              f"loss_abs_diff={pallas_step['loss_abs_diff']:.3e}")
        for r in kernels:
            print(f"  kernel {r['case']:34s} err={r['max_abs_err']:.3e} "
                  f"{'ok' if r['within_tolerance'] else 'FAIL'}")
    return result


def main(out_path: str = "BENCH_train_step.json") -> int:
    t0 = time.perf_counter()
    result = run()
    us = (time.perf_counter() - t0) * 1e6
    Path(out_path).write_text(json.dumps(result, indent=2) + "\n")
    failures = [r["case"] for r in result["kernels"]
                if not r["within_tolerance"]]
    emit("bench_train_step", us,
         f"step_speedup={result['step']['speedup']:.2f}x;"
         f"failstop_speedup={result['recovery']['fail_stop']['speedup']:.2f}x;"
         f"kernel_tier_failures={len(failures)}")
    if failures:
        print(f"FAIL: kernel(s) outside declared tolerance tier: {failures}")
    return len(failures)


if __name__ == "__main__":
    raise SystemExit(main())
