"""Fig. 14 — time-averaged throughput on spot-instance-style traces.

Trace A: plateau-heavy (long stable windows, occasional shrink/regrow).
Trace B: shrink-heavy (frequent preemptions).  Capacity pattern follows the
SpotServe-style traces the paper replays.  Each policy pays its own MTTR on
every capacity change (TorchFT: restart ~20 s; ReCycle/ElasWave: online)."""
from __future__ import annotations

import time

import numpy as np

from repro.core.policies import ElasWavePolicy, ReCyclePolicy, TorchFTPolicy
from .common import LLAMA2, WORKER_HW, build_view, kill_nodes, emit

# (duration_s, nodes_down) segments
TRACE_A = [(600, 0), (300, 1), (900, 1), (120, 2), (600, 1), (900, 0)]
TRACE_B = [(180, 0), (120, 1), (120, 2), (180, 3), (120, 2), (120, 3),
           (180, 1), (120, 2), (120, 0)]

MTTR = {"elaswave": 1.2, "recycle": 3.0, "torchft": 20.0}


def run_trace(w, trace, pol):
    seg, view0 = build_view(w)
    base = ElasWavePolicy(WORKER_HW).decide(seg, view0)
    thr0 = w["global_batch"] / base.step_time
    total_samples = 0.0
    total_time = 0.0
    prev_down = None
    for dur, down in trace:
        seg, view = build_view(w)
        kill_nodes(view, down)
        d = pol.decide(seg, view)
        thr = w["global_batch"] / d.step_time if d.feasible and \
            np.isfinite(d.step_time) else 0.0
        pay = MTTR[pol.name] if prev_down is not None and down != prev_down else 0.0
        total_samples += thr * max(dur - pay, 0)
        total_time += dur
        prev_down = down
    return total_samples / total_time / thr0


def run(verbose=True):
    rows = []
    for tname, trace in (("traceA", TRACE_A), ("traceB", TRACE_B)):
        for wname, w in LLAMA2.items():
            vals = {}
            for pol in (ElasWavePolicy(WORKER_HW), ReCyclePolicy(),
                        TorchFTPolicy()):
                vals[pol.name] = run_trace(w, trace, pol)
            rows.append((tname, wname, vals))
            if verbose:
                print(f"  {tname} {wname}: " + " ".join(
                    f"{k}={v:.3f}" for k, v in vals.items()))
    return rows


def main():
    t0 = time.perf_counter()
    rows = run()
    us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)
    gains_re = [r[2]["elaswave"] / max(r[2]["recycle"], 1e-9) for r in rows]
    gains_tf = [r[2]["elaswave"] / max(r[2]["torchft"], 1e-9) for r in rows]
    emit("fig14_spot_traces", us,
         f"vs_recycle={min(gains_re):.2f}-{max(gains_re):.2f}x;"
         f"vs_torchft={min(gains_tf):.2f}-{max(gains_tf):.2f}x")
    return rows


if __name__ == "__main__":
    main()
