"""Fig. 14 — time-averaged throughput on spot-instance-style traces.

Trace A: plateau-heavy (long stable windows, occasional shrink/regrow).
Trace B: shrink-heavy (frequent preemptions).  Capacity pattern follows the
SpotServe-style traces the paper replays.  Each policy pays its own MTTR on
every capacity change (TorchFT: restart ~20 s; ReCycle/ElasWave: online).

Thin wrapper over the scenario engine: ``Scenario.from_capacity_trace``
turns each (duration, nodes_down) segment list into timed SCALE_IN /
SCALE_OUT delta events, and ``AnalyticScenarioRunner`` integrates throughput
over the intervals, charging ``MTTR[policy]`` per capacity change.
"""
from __future__ import annotations

import time

from repro.core.policies import ElasWavePolicy, ReCyclePolicy, TorchFTPolicy
from repro.scenarios import AnalyticScenarioRunner, Scenario
from .common import LLAMA2, WORKER_HW, analytic_workload, emit

# (duration_s, nodes_down) segments
TRACE_A = [(600, 0), (300, 1), (900, 1), (120, 2), (600, 1), (900, 0)]
TRACE_B = [(180, 0), (120, 1), (120, 2), (180, 3), (120, 2), (120, 3),
           (180, 1), (120, 2), (120, 0)]

MTTR = {"elaswave": 1.2, "recycle": 3.0, "torchft": 20.0}


def run_trace(w, trace, pol, name: str = "spot"):
    """Time-averaged throughput of ``pol`` on a capacity trace, normalized to
    the fault-free ElasWave baseline (historical signature, kept for
    examples/spot_trace_replay.py)."""
    return replay(w, trace, pol, name).summary["time_avg_rel_throughput"]


def replay(w, trace, pol, name: str = "spot"):
    """Full scenario-engine replay returning the ScenarioResult artifact."""
    wl = analytic_workload(w)
    scn = Scenario.from_capacity_trace(name, trace, dp=wl.dp, pp=wl.pp)
    return AnalyticScenarioRunner(
        scn, wl, pol, reference_policy=ElasWavePolicy(WORKER_HW),
        mttr_model=MTTR).run()


def run(verbose=True):
    rows = []
    for tname, trace in (("traceA", TRACE_A), ("traceB", TRACE_B)):
        for wname, w in LLAMA2.items():
            vals = {}
            for pol in (ElasWavePolicy(WORKER_HW), ReCyclePolicy(),
                        TorchFTPolicy()):
                vals[pol.name] = run_trace(w, trace, pol,
                                           name=f"{tname}_{wname}")
            rows.append((tname, wname, vals))
            if verbose:
                print(f"  {tname} {wname}: " + " ".join(
                    f"{k}={v:.3f}" for k, v in vals.items()))
    return rows


def main():
    t0 = time.perf_counter()
    rows = run()
    us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)
    gains_re = [r[2]["elaswave"] / max(r[2]["recycle"], 1e-9) for r in rows]
    gains_tf = [r[2]["elaswave"] / max(r[2]["torchft"], 1e-9) for r in rows]
    emit("fig14_spot_traces", us,
         f"vs_recycle={min(gains_re):.2f}-{max(gains_re):.2f}x;"
         f"vs_torchft={min(gains_tf):.2f}-{max(gains_tf):.2f}x")
    return rows


if __name__ == "__main__":
    main()
