"""BENCH_serve — elastic serving plane on the paper's capacity traces.

Replays the Fig.-14 spot traces (A: plateau-heavy, B: shrink-heavy) through
the continuous-batching :class:`~repro.serving.engine.ServingEngine` via
``ServeScenarioRunner``, once per recovery policy (ElasWave KV-migration /
prefix rebuild / SpotServe-less drop baseline), and emits
``BENCH_serve.json``:

.. code-block:: json

    {
      "workload": {"n_replicas": 4, "slots_per_replica": 6, ...},
      "time_scale": 0.02,
      "traces": {
        "trace_A": {
          "elaswave_migrate": {"completed": ..., "dropped": 0,
                               "ttft_p50": ..., "ttft_p99": ...,
                               "per_token_p50": ..., "per_token_p99": ...,
                               "goodput_tokens_per_s": ...,
                               "slo_attainment": ...,
                               "drops_per_capacity_change": [...]},
          "rebuild": {...}, "drop": {...}},
        "trace_B": {...}},
      "scale_in_zero_drop": {"dropped": 0, "migrated": ..., "ok": true}
    }

Traces are time-compressed (``TIME_SCALE``) so the open-loop Poisson stream
keeps the slot pools busy and capacity changes land on in-flight requests —
otherwise every policy trivially ties.  Scheduling runs in synthetic token
mode: the simulated clock (and hence every latency metric) is deterministic
and replayable; numerics are covered by ``tests/test_serving.py``.

The ``scale_in_zero_drop`` record is the acceptance check: a single-replica
SCALE_IN under the migration policy must drop ZERO in-flight requests
(``main`` exits non-zero if it does not hold).
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

from repro.core.events import ElasticEvent, EventKind
from repro.scenarios import Scenario, ServeWorkload, run_serve_scenario
from repro.serving import SERVE_POLICIES, Request
from .common import emit
from .spot_trace import TRACE_A, TRACE_B

TIME_SCALE = 0.02
POLICIES = ("elaswave_migrate", "rebuild", "drop")
WORKLOAD = ServeWorkload(mode="synthetic", request_rate=0.15, prompt_len=16,
                         max_new_tokens=48, max_len=80)

SUMMARY_KEYS = ("n_requests", "completed", "dropped", "rejected",
                "in_flight_at_end", "deferrals", "migrations", "re_prefills",
                "ttft_p50", "ttft_p99", "per_token_p50", "per_token_p99",
                "slo_attainment", "goodput_tokens_per_s", "kv_bytes_moved",
                "drops_per_capacity_change")


def replay(trace_name: str, trace, policy_name: str):
    scn = Scenario.from_capacity_trace(trace_name, trace, dp=4, pp=2)
    res = run_serve_scenario(scn, WORKLOAD,
                             policy=SERVE_POLICIES[policy_name],
                             time_scale=TIME_SCALE)
    return {k: res.summary[k] for k in SUMMARY_KEYS}


def check_scale_in_zero_drop() -> dict:
    """Acceptance: a single-replica SCALE_IN with in-flight requests on the
    departing replica migrates (or rebuilds) every one of them — zero drops,
    and every request still completes."""
    engine = WORKLOAD.make_engine(SERVE_POLICIES["elaswave_migrate"])
    rng = np.random.default_rng(0)
    for rid in range(2 * WORKLOAD.slots_per_replica):
        prompt = rng.integers(0, engine.cfg.vocab_size,
                              size=WORKLOAD.prompt_len).astype(np.int32)
        engine.submit(Request(rid=rid, arrival=0.0, prompt=prompt,
                              max_new_tokens=WORKLOAD.max_new_tokens))
    for _ in range(4):           # get requests resident on every replica
        engine.tick()
    assert engine.replicas[0].pool.n_active > 0
    ranks = tuple(range(WORKLOAD.ranks_per_replica))      # replica 0's node
    stats = engine.apply_event(
        ElasticEvent(EventKind.SCALE_IN, 0, ranks, detail="bench acceptance"))
    engine.drain()
    s = engine.summary()
    ok = (stats["dropped"] == 0 and s["dropped"] == 0
          and s["completed"] == s["n_requests"])
    return {"event_replicas": stats["replicas"], "dropped": stats["dropped"],
            "migrated": stats["migrated"], "rebuilt": stats["rebuilt"],
            "kv_bytes_moved": stats["kv_bytes_moved"],
            "completed": s["completed"], "n_requests": s["n_requests"],
            "ok": bool(ok)}


def run(verbose: bool = True) -> dict:
    traces = {}
    for tname, trace in (("trace_A", TRACE_A), ("trace_B", TRACE_B)):
        traces[tname] = {}
        for pname in POLICIES:
            s = traces[tname][pname] = replay(tname, trace, pname)
            if verbose:
                print(f"  {tname} {pname}: done={s['completed']}"
                      f"/{s['n_requests']} dropped={s['dropped']} "
                      f"migr={s['migrations']} re_prefill={s['re_prefills']} "
                      f"ttft_p99={s['ttft_p99']:.2f}s "
                      f"goodput={s['goodput_tokens_per_s']:.0f}tok/s")
    zero_drop = check_scale_in_zero_drop()
    if verbose:
        print(f"  scale_in_zero_drop: dropped={zero_drop['dropped']} "
              f"migrated={zero_drop['migrated']} "
              f"rebuilt={zero_drop['rebuilt']} ok={zero_drop['ok']}")
    return {"workload": WORKLOAD.describe(), "time_scale": TIME_SCALE,
            "traces": traces, "scale_in_zero_drop": zero_drop}


def main(out_path: str = "BENCH_serve.json"):
    t0 = time.perf_counter()
    result = run()
    us = (time.perf_counter() - t0) * 1e6
    Path(out_path).write_text(json.dumps(result, indent=2, sort_keys=True,
                                         default=float) + "\n")
    a, b = result["traces"]["trace_A"], result["traces"]["trace_B"]
    emit("bench_serve", us,
         f"dropsA_migrate={a['elaswave_migrate']['dropped']};"
         f"dropsA_drop={a['drop']['dropped']};"
         f"dropsB_migrate={b['elaswave_migrate']['dropped']};"
         f"dropsB_drop={b['drop']['dropped']};"
         f"zero_drop_ok={result['scale_in_zero_drop']['ok']}")
    if not result["scale_in_zero_drop"]["ok"]:
        raise SystemExit("serve bench: single-replica SCALE_IN dropped "
                         "in-flight requests under the migration policy")
    return result


if __name__ == "__main__":
    main()
