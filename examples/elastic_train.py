"""End-to-end elastic training driver.

Trains a configurable decoder-only model on the deterministic corpus for a
few hundred steps while a scripted fault schedule (fail-stop at 1/3 of the
run, fail-slow at 2/3) exercises the full ElasWave recovery path:
Agent detection -> ScheduleEngine multi-dim plan -> communicator edit ->
live remap -> layer migration -> dataflow/DVFS/RNG application.

    PYTHONPATH=src python examples/elastic_train.py \
        [--steps 200] [--dmodel 256] [--layers 8] [--report-every 10]

At the default size this is a ~10M-param model; --dmodel 896 --layers 12
gives ~100M (slow on CPU — sized down by default for the container).
"""
import argparse
import time

import numpy as np

from repro.core.cluster import VirtualCluster
from repro.models.config import ModelConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--dmodel", type=int, default=256)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--vocab", type=int, default=2048)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--report-every", type=int, default=10)
    args = ap.parse_args()

    cfg = ModelConfig(name="elastic-demo", family="dense",
                      num_layers=args.layers, d_model=args.dmodel,
                      num_heads=args.dmodel // 64 or 2,
                      num_kv_heads=max((args.dmodel // 64 or 2) // 2, 1),
                      d_ff=args.dmodel * 4, vocab_size=args.vocab,
                      dropout_rate=0.05, dtype="float32",
                      rope_theta=10000.0)
    print(f"model: {cfg.param_count() / 1e6:.1f}M params, "
          f"{args.steps} steps, global_batch={args.global_batch}")

    cl = VirtualCluster(cfg, dp=4, pp=2, global_batch=args.global_batch,
                        num_micro=2, seq_len=args.seq, seed=0)
    fail_stop_at = args.steps // 3
    fail_slow_at = 2 * args.steps // 3
    t0 = time.time()
    for step in range(args.steps):
        if step == fail_stop_at:
            print(f"-- step {step}: FAIL-STOP injected at rank (dp=2, stage=0)")
            cl.inject_fail_stop(2, 0)
            rec = cl.detect_and_recover()
            print(f"   recovered: MTTR={rec['total']:.3f}s "
                  f"(comm={rec['communicator']:.3f}s remap={rec['remap']:.4f}s "
                  f"migration={rec['migration']:.3f}s) rng_moves={rec['rng_moves']}")
        if step == fail_slow_at:
            print(f"-- step {step}: FAIL-SLOW injected (1.4x) at (dp=0, stage=1)")
            cl.inject_fail_slow(0, 1, 1.4)
            rec = cl.recover_fail_slow(0, 1, 1.4)
            print(f"   rebalanced: migration stall={rec['migration']:.3f}s")
        loss = cl.train_step()
        if step % args.report_every == 0 or step == args.steps - 1:
            dt = time.time() - t0
            print(f"step {step:4d}  loss={loss:.4f}  "
                  f"({dt / (step + 1) * 1e3:.0f} ms/step)")
    first, last = cl.losses[0], np.mean(cl.losses[-10:])
    print(f"\nloss {first:.4f} -> {last:.4f} "
          f"({'converging OK' if last < first else 'NOT converging'})")
    print(f"recoveries: {len(cl.recoveries)}; "
          f"final step time (simulated cluster): {cl.simulate_step_time():.3e}s")


if __name__ == "__main__":
    main()
