"""Replay a spot-instance capacity trace against the three recovery policies
(paper Fig. 14) through the scenario engine, print the time-averaged
throughput, and optionally dump the full per-interval JSON artifacts.

    PYTHONPATH=src python examples/spot_trace_replay.py \
        [--model llama2-13b] [--artifacts-dir out/]
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))  # repo root

from benchmarks.common import LLAMA2, WORKER_HW
from benchmarks.spot_trace import TRACE_A, TRACE_B, replay
from repro.core.policies import ElasWavePolicy, ReCyclePolicy, TorchFTPolicy


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="llama2-13b", choices=list(LLAMA2))
    ap.add_argument("--artifacts-dir", default=None,
                    help="write per-run ScenarioResult JSON here")
    args = ap.parse_args()
    w = LLAMA2[args.model]
    for tname, trace in (("plateau-heavy (A)", TRACE_A),
                         ("shrink-heavy (B)", TRACE_B)):
        print(f"\ntrace {tname}: segments={trace}")
        for pol in (ElasWavePolicy(WORKER_HW), ReCyclePolicy(),
                    TorchFTPolicy()):
            res = replay(w, trace, pol,
                         name=f"spot_{tname[-2]}_{args.model}_{pol.name}")
            v = res.summary["time_avg_rel_throughput"]
            bar = "#" * int(v * 40)
            print(f"  {pol.name:9s} {v:.3f} {bar}")
            if args.artifacts_dir:
                path = res.write(args.artifacts_dir)
                print(f"            artifact: {path}")


if __name__ == "__main__":
    main()
