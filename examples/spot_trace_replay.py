"""Replay a spot-instance capacity trace against the three recovery policies
(paper Fig. 14) and print the time-averaged throughput.

    PYTHONPATH=src python examples/spot_trace_replay.py [--model llama2-13b]
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))  # repo root

from benchmarks.common import LLAMA2
from benchmarks.spot_trace import TRACE_A, TRACE_B, run_trace
from benchmarks.common import WORKER_HW
from repro.core.policies import ElasWavePolicy, ReCyclePolicy, TorchFTPolicy


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="llama2-13b", choices=list(LLAMA2))
    args = ap.parse_args()
    w = LLAMA2[args.model]
    for tname, trace in (("plateau-heavy (A)", TRACE_A),
                         ("shrink-heavy (B)", TRACE_B)):
        print(f"\ntrace {tname}: segments={trace}")
        for pol in (ElasWavePolicy(WORKER_HW), ReCyclePolicy(),
                    TorchFTPolicy()):
            v = run_trace(w, trace, pol)
            bar = "#" * int(v * 40)
            print(f"  {pol.name:9s} {v:.3f} {bar}")


if __name__ == "__main__":
    main()
