"""Batched serving example: continuous-batching generation with KV caches on
a reduced config of an assigned architecture — including enc-dec archs
(the engine prepares encoder outputs per request).

    PYTHONPATH=src python examples/serve.py [--arch codeqwen1p5_7b] \
        [--tokens 32] [--temperature 0.8]

Thin wrapper over :func:`repro.serving.offline_generate`; the elastic parts
(SLO admission, KV-cache migration across replicas) are exercised by
``benchmarks/serve_bench.py`` and ``tests/test_serving.py``.
"""
import argparse

from repro import configs
from repro.launch.serve import add_generation_args, run_smoke


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="codeqwen1p5_7b",
                    choices=configs.ARCH_IDS)
    add_generation_args(ap)
    args = ap.parse_args()
    run_smoke(args.arch, args)


if __name__ == "__main__":
    main()
