"""Batched serving example: prefill + decode with KV caches on a reduced
config of an assigned architecture.

    PYTHONPATH=src python examples/serve.py [--arch codeqwen1p5_7b] [--tokens 32]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import registry as R
from repro.models import transformer as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="codeqwen1p5_7b",
                    choices=configs.ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = configs.get_smoke_config(args.arch)
    if cfg.is_encdec:
        raise SystemExit("use an LM arch for this example (enc-dec: see tests)")
    print(f"serving {cfg.name}: batch={args.batch}, "
          f"prompt={args.prompt_len}, decode={args.tokens} tokens")

    params = R.init_model(jax.random.key(0), cfg)
    max_len = args.prompt_len + args.tokens
    caches = T.init_caches(cfg, args.batch, max_len)
    prompts = jax.random.randint(jax.random.key(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)

    prefill = jax.jit(lambda p, c, t: T.prefill(p, cfg, t, c))
    decode = jax.jit(lambda p, c, t, i: T.decode_step(p, cfg, t, c, i))

    t0 = time.time()
    logits, caches = prefill(params, caches, prompts)
    logits.block_until_ready()
    t_prefill = time.time() - t0
    print(f"prefill: {t_prefill * 1e3:.1f} ms "
          f"({args.batch * args.prompt_len / t_prefill:.0f} tok/s)")

    tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None]
    out = [tok]
    t0 = time.time()
    for i in range(args.tokens - 1):
        logits, caches = decode(params, caches, tok,
                                jnp.int32(args.prompt_len + i))
        tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None]
        out.append(tok)
    jax.block_until_ready(tok)
    t_dec = time.time() - t0
    seqs = np.concatenate([np.asarray(t) for t in out], axis=1)
    print(f"decode: {t_dec / (args.tokens - 1) * 1e3:.1f} ms/token "
          f"({args.batch * (args.tokens - 1) / t_dec:.0f} tok/s)")
    print("greedy continuations (token ids):")
    for b in range(args.batch):
        print(f"  [{b}] {seqs[b][:16].tolist()}...")


if __name__ == "__main__":
    main()
