"""Quickstart: train a small model with the public API, inject a failure,
watch ElasWave recover within the step — loss trajectory unchanged.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.cluster import VirtualCluster
from repro.models import registry as R


def main():
    cfg = R.tiny_config("dense", num_layers=8, dropout_rate=0.1)
    print(f"model: {cfg.name} ({cfg.param_count() / 1e6:.2f}M params)")

    print("\n== fault-free run (DP=4, PP=2, ZeRO-1 interleaved) ==")
    base = VirtualCluster(cfg, dp=4, pp=2, global_batch=16, num_micro=2,
                          seq_len=16, seed=0)
    base_losses = base.run(8)
    for i, l in enumerate(base_losses):
        print(f"  step {i}: loss={l:.6f}")

    print("\n== elastic run: rank (dp=1, stage=1) fails after step 3 ==")
    el = VirtualCluster(cfg, dp=4, pp=2, global_batch=16, num_micro=2,
                        seq_len=16, seed=0)
    losses = el.run(4)
    rec = el.recover_fail_stop(1, 1)
    print(f"  RECOVERY: total={rec['total']:.3f}s "
          f"(detect={rec['detect']:.2f}s plan={rec['plan'] * 1e3:.1f}ms "
          f"communicator={rec['communicator']:.3f}s "
          f"remap={rec['remap'] * 1e3:.3f}ms migration={rec['migration']:.3f}s)")
    losses += el.run(4)
    for i, l in enumerate(losses):
        mark = " <- post-failure" if i >= 4 else ""
        print(f"  step {i}: loss={l:.6f}{mark}")

    dev = np.abs(np.array(base_losses) - np.array(losses)).max()
    print(f"\nmax |loss_faultfree - loss_elastic| = {dev:.2e}")
    print("computation consistency:", "OK" if dev < 1e-4 else "VIOLATED")


if __name__ == "__main__":
    main()
