"""Detection-chaos fuzz layer: the four guarantees under imperfect probes.

Fast shard: a 150-seed pure control-plane sweep (Agent + ElasticController,
milliseconds per seed) plus a 2-seed numeric smoke of the full
VirtualCluster chaos runner.  Slow shard: a numeric sweep whose budget is
tunable via ``ELASWAVE_CHAOS_NUMERIC`` (nightly CI runs 100+ seeds through
``benchmarks/fuzz_soak.py --chaos-traces``).
"""
import os

import numpy as np
import pytest

from repro.scenarios import (CHAOS_CLASSES, make_chaos_case, run_chaos_case,
                             run_detector_chaos)
from repro.scenarios.spec import ClusterWorkload


class TestDetectorChaosSweep:
    """Control-plane only: no numerics, so the sweep is wide and cheap."""

    def test_150_seeds_no_permanent_false_evictions(self):
        for seed in range(150):
            run_detector_chaos(seed)

    def test_case_generation_is_deterministic(self):
        a, b = make_chaos_case(17), make_chaos_case(17)
        assert a.chaos_class == b.chaos_class
        assert a.actions == b.actions
        assert a.workload == b.workload

    def test_classes_and_repro_lines_covered(self):
        seen = set()
        for seed in range(40):
            c = make_chaos_case(seed)
            assert c.chaos_class in CHAOS_CLASSES
            assert f"--mode chaos --seed {seed}" in c.repro()
            if c.chaos_class == "flap_only":
                assert c.actions == ()      # every eviction is false
            seen.add(c.chaos_class)
        assert seen == set(CHAOS_CLASSES)


class TestNumericChaosSmoke:
    """Full VirtualCluster under probe chaos — two seeds in the fast shard
    (one corrupt-class, one flap-only), the rest behind the slow marker."""

    @pytest.mark.parametrize("seed", [2, 4])
    def test_chaos_case_upholds_invariants(self, seed):
        run_chaos_case(make_chaos_case(seed))

    @pytest.mark.slow
    def test_numeric_chaos_sweep(self):
        budget = int(os.environ.get("ELASWAVE_CHAOS_NUMERIC", "8"))
        for seed in range(budget):
            run_chaos_case(make_chaos_case(seed))


class TestFalsePositiveEvictionCluster:
    """End-to-end on the numeric cluster: a false-positive eviction followed
    by resurrection keeps training consistent, and a LATER real failure of
    the same worker is still detected and recovered."""

    def test_false_eviction_rejoin_then_real_failure(self):
        from repro.core.agent import Probe
        w = ClusterWorkload(dp=3, pp=1, num_layers=2, global_batch=6,
                            num_micro=1, seq_len=8, dropout_rate=0.0)
        cl = w.make_cluster()
        cl.run(2)

        def truth_probes(alive_ranks):
            return [Probe(cl.step_count, r, heartbeat=(r in alive_ranks),
                          step_seconds=0.1)
                    for r in range(cl.dp0 * cl.pp)]

        # partition rank 1: its heartbeats are lost but the worker is fine
        events = []
        for _ in range(cl.controller.max_confirm_misses()):
            events += cl.controller.observe(truth_probes({0, 2}))
        assert [e.kind.value for e in events] == ["fail_stop"]
        cl.apply_event(events[0])
        assert not cl.alive[1, 0]
        cl.run(2)

        # the partition heals: resurrection re-admits through SCALE_OUT
        events = cl.controller.observe(truth_probes({0, 1, 2}))
        assert [e.kind.value for e in events] == ["scale_out"]
        rec = cl.apply_event(events[0])
        assert cl.alive[1, 0] and rec["total"] > 0
        cl.run(2)
        assert all(np.isfinite(cl.losses))

        # later the SAME worker genuinely dies: re-detected and recovered
        cl.inject_fail_stop(1, 0)
        rec = cl.detect_and_recover()
        assert rec is not None and rec["detect"] > 0
        assert not cl.alive[1, 0]
        cl.run(2)
        assert all(np.isfinite(cl.losses))


class TestOomWarningCluster:
    def test_mem_pressure_probe_feeds_oom_warning(self):
        """``Probe.mem_used`` is live: a rising footprint on one worker
        produces an advisory OOM_RISK warning before the limit is hit."""
        w = ClusterWorkload(dp=2, pp=1, num_layers=2, global_batch=4,
                            num_micro=1, seq_len=8, dropout_rate=0.0)
        cl = w.make_cluster()
        for frac in (0.5, 0.65, 0.8):
            cl.inject_mem_pressure(0, 0, frac)
            cl.detect_and_recover()
            cl.train_step()
        assert [e.kind.value for e in cl.warnings] == ["oom_risk"]
        assert cl.warnings[0].ranks == (0,)
        assert bool(cl.alive.all())         # advisory: nobody was evicted
