"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_kernel
from repro.kernels.rmsnorm import rmsnorm_kernel
from repro.kernels.ssd_scan import ssd_scan_kernel

KEY = jax.random.key(42)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


class TestFlashAttention:
    @pytest.mark.parametrize("S", [64, 128, 256])
    @pytest.mark.parametrize("hd", [32, 64, 128])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_vs_oracle(self, S, hd, dtype):
        BH = 4
        q = jax.random.normal(jax.random.fold_in(KEY, 1), (BH, S, hd), dtype)
        k = jax.random.normal(jax.random.fold_in(KEY, 2), (BH, S, hd), dtype)
        v = jax.random.normal(jax.random.fold_in(KEY, 3), (BH, S, hd), dtype)
        o = flash_attention_kernel(q, k, v, causal=True, block_q=64, block_k=64)
        o_ref = ref.mha_reference(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(o, np.float32),
                                   np.asarray(o_ref, np.float32), **_tol(dtype))

    @pytest.mark.parametrize("blocks", [(32, 64), (64, 32), (128, 128)])
    def test_block_shapes(self, blocks):
        bq, bk = blocks
        S = 128
        q = jax.random.normal(jax.random.fold_in(KEY, 4), (2, S, 64))
        k = jax.random.normal(jax.random.fold_in(KEY, 5), (2, S, 64))
        v = jax.random.normal(jax.random.fold_in(KEY, 6), (2, S, 64))
        o = flash_attention_kernel(q, k, v, block_q=bq, block_k=bk)
        o_ref = ref.mha_reference(q, k, v)
        np.testing.assert_allclose(o, o_ref, rtol=2e-5, atol=2e-5)

    def test_noncausal(self):
        q = jax.random.normal(jax.random.fold_in(KEY, 7), (2, 128, 64))
        k = jax.random.normal(jax.random.fold_in(KEY, 8), (2, 128, 64))
        v = jax.random.normal(jax.random.fold_in(KEY, 9), (2, 128, 64))
        o = flash_attention_kernel(q, k, v, causal=False, block_q=64, block_k=64)
        np.testing.assert_allclose(o, ref.mha_reference(q, k, v, causal=False),
                                   rtol=2e-5, atol=2e-5)

    def test_gqa_wrapper(self):
        B, S, H, Hkv, hd = 2, 128, 8, 2, 64
        q = jax.random.normal(jax.random.fold_in(KEY, 10), (B, S, H, hd))
        k = jax.random.normal(jax.random.fold_in(KEY, 11), (B, S, Hkv, hd))
        v = jax.random.normal(jax.random.fold_in(KEY, 12), (B, S, Hkv, hd))
        o = ops.flash_attention(q, k, v)
        from repro.models.layers import _sdpa
        o_ref = _sdpa(q, k, v, causal=True)
        np.testing.assert_allclose(o, o_ref, rtol=1e-4, atol=1e-4)


class TestRmsnorm:
    @pytest.mark.parametrize("shape", [(4, 64), (2, 7, 96), (1, 1, 1, 128),
                                       (300, 256)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_vs_oracle(self, shape, dtype):
        x = jax.random.normal(jax.random.fold_in(KEY, 20), shape, dtype)
        s = jax.random.normal(jax.random.fold_in(KEY, 21), (shape[-1],))
        o = rmsnorm_kernel(x, s)
        np.testing.assert_allclose(np.asarray(o, np.float32),
                                   np.asarray(ref.rmsnorm_reference(x, s),
                                              np.float32), **_tol(dtype))


class TestSsdScan:
    @pytest.mark.parametrize("s,chunk", [(32, 8), (64, 16), (128, 32), (64, 64)])
    @pytest.mark.parametrize("dtype", [jnp.float32])
    def test_vs_sequential_oracle(self, s, chunk, dtype):
        b, h, p, n = 2, 4, 16, 8
        x = jax.random.normal(jax.random.fold_in(KEY, 30), (b, s, h, p), dtype) * 0.5
        dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(KEY, 31), (b, s, h)))
        A = -jnp.exp(jax.random.normal(jax.random.fold_in(KEY, 32), (h,)) * 0.3)
        Bm = jax.random.normal(jax.random.fold_in(KEY, 33), (b, s, h, n)) * 0.5
        Cm = jax.random.normal(jax.random.fold_in(KEY, 34), (b, s, h, n)) * 0.5
        y = ssd_scan_kernel(x, dt, A, Bm, Cm, chunk=chunk)
        y_ref, _ = ref.ssd_reference(x, dt, A, Bm, Cm)
        np.testing.assert_allclose(y, y_ref, rtol=5e-5, atol=5e-5)

    def test_groups_broadcast_via_ops(self):
        b, s, h, p, n, g = 2, 32, 4, 8, 8, 2
        x = jax.random.normal(jax.random.fold_in(KEY, 35), (b, s, h, p)) * 0.5
        dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(KEY, 36), (b, s, h)))
        A = -jnp.exp(jax.random.normal(jax.random.fold_in(KEY, 37), (h,)) * 0.3)
        Bm = jax.random.normal(jax.random.fold_in(KEY, 38), (b, s, g, n)) * 0.5
        Cm = jax.random.normal(jax.random.fold_in(KEY, 39), (b, s, g, n)) * 0.5
        y, _ = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=8)
        Bh = jnp.repeat(Bm, h // g, axis=2)
        Ch = jnp.repeat(Cm, h // g, axis=2)
        y_ref, _ = ref.ssd_reference(x, dt, A, Bh, Ch)
        np.testing.assert_allclose(y, y_ref, rtol=5e-5, atol=5e-5)

    def test_chunked_jnp_matches_oracle(self):
        """The model's jnp SSD path (mamba.ssd_chunked) == sequential oracle."""
        from repro.models.mamba import ssd_chunked
        b, s, h, p, n, g = 2, 64, 4, 8, 8, 1
        x = jax.random.normal(jax.random.fold_in(KEY, 40), (b, s, h, p)) * 0.5
        dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(KEY, 41), (b, s, h)))
        A = -jnp.exp(jax.random.normal(jax.random.fold_in(KEY, 42), (h,)) * 0.3)
        Bm = jax.random.normal(jax.random.fold_in(KEY, 43), (b, s, g, n)) * 0.5
        Cm = jax.random.normal(jax.random.fold_in(KEY, 44), (b, s, g, n)) * 0.5
        y, fin = ssd_chunked(x, dt, A, Bm, Cm, chunk=16)
        Bh = jnp.repeat(Bm, h, axis=2)
        Ch = jnp.repeat(Cm, h, axis=2)
        y_ref, fin_ref = ref.ssd_reference(x, dt, A, Bh, Ch)
        np.testing.assert_allclose(y, y_ref, rtol=5e-5, atol=5e-5)
        np.testing.assert_allclose(fin, fin_ref, rtol=5e-5, atol=5e-5)
