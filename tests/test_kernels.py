"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_kernel
from repro.kernels.rmsnorm import rmsnorm_kernel
from repro.kernels.ssd_scan import ssd_scan_kernel

KEY = jax.random.key(42)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


class TestFlashAttention:
    @pytest.mark.parametrize("S", [64, 128, 256])
    @pytest.mark.parametrize("hd", [32, 64, 128])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_vs_oracle(self, S, hd, dtype):
        BH = 4
        q = jax.random.normal(jax.random.fold_in(KEY, 1), (BH, S, hd), dtype)
        k = jax.random.normal(jax.random.fold_in(KEY, 2), (BH, S, hd), dtype)
        v = jax.random.normal(jax.random.fold_in(KEY, 3), (BH, S, hd), dtype)
        o = flash_attention_kernel(q, k, v, causal=True, block_q=64, block_k=64)
        o_ref = ref.mha_reference(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(o, np.float32),
                                   np.asarray(o_ref, np.float32), **_tol(dtype))

    @pytest.mark.parametrize("blocks", [(32, 64), (64, 32), (128, 128)])
    def test_block_shapes(self, blocks):
        bq, bk = blocks
        S = 128
        q = jax.random.normal(jax.random.fold_in(KEY, 4), (2, S, 64))
        k = jax.random.normal(jax.random.fold_in(KEY, 5), (2, S, 64))
        v = jax.random.normal(jax.random.fold_in(KEY, 6), (2, S, 64))
        o = flash_attention_kernel(q, k, v, block_q=bq, block_k=bk)
        o_ref = ref.mha_reference(q, k, v)
        np.testing.assert_allclose(o, o_ref, rtol=2e-5, atol=2e-5)

    def test_noncausal(self):
        q = jax.random.normal(jax.random.fold_in(KEY, 7), (2, 128, 64))
        k = jax.random.normal(jax.random.fold_in(KEY, 8), (2, 128, 64))
        v = jax.random.normal(jax.random.fold_in(KEY, 9), (2, 128, 64))
        o = flash_attention_kernel(q, k, v, causal=False, block_q=64, block_k=64)
        np.testing.assert_allclose(o, ref.mha_reference(q, k, v, causal=False),
                                   rtol=2e-5, atol=2e-5)

    def test_gqa_wrapper(self):
        B, S, H, Hkv, hd = 2, 128, 8, 2, 64
        q = jax.random.normal(jax.random.fold_in(KEY, 10), (B, S, H, hd))
        k = jax.random.normal(jax.random.fold_in(KEY, 11), (B, S, Hkv, hd))
        v = jax.random.normal(jax.random.fold_in(KEY, 12), (B, S, Hkv, hd))
        o = ops.flash_attention(q, k, v)
        from repro.models.layers import _sdpa
        o_ref = _sdpa(q, k, v, causal=True)
        np.testing.assert_allclose(o, o_ref, rtol=1e-4, atol=1e-4)


class TestRmsnorm:
    @pytest.mark.parametrize("shape", [(4, 64), (2, 7, 96), (1, 1, 1, 128),
                                       (300, 256)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_vs_oracle(self, shape, dtype):
        x = jax.random.normal(jax.random.fold_in(KEY, 20), shape, dtype)
        s = jax.random.normal(jax.random.fold_in(KEY, 21), (shape[-1],))
        o = rmsnorm_kernel(x, s)
        np.testing.assert_allclose(np.asarray(o, np.float32),
                                   np.asarray(ref.rmsnorm_reference(x, s),
                                              np.float32), **_tol(dtype))


class TestSsdScan:
    @pytest.mark.parametrize("s,chunk", [(32, 8), (64, 16), (128, 32), (64, 64)])
    @pytest.mark.parametrize("dtype", [jnp.float32])
    def test_vs_sequential_oracle(self, s, chunk, dtype):
        b, h, p, n = 2, 4, 16, 8
        x = jax.random.normal(jax.random.fold_in(KEY, 30), (b, s, h, p), dtype) * 0.5
        dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(KEY, 31), (b, s, h)))
        A = -jnp.exp(jax.random.normal(jax.random.fold_in(KEY, 32), (h,)) * 0.3)
        Bm = jax.random.normal(jax.random.fold_in(KEY, 33), (b, s, h, n)) * 0.5
        Cm = jax.random.normal(jax.random.fold_in(KEY, 34), (b, s, h, n)) * 0.5
        y = ssd_scan_kernel(x, dt, A, Bm, Cm, chunk=chunk)
        y_ref, _ = ref.ssd_reference(x, dt, A, Bm, Cm)
        np.testing.assert_allclose(y, y_ref, rtol=5e-5, atol=5e-5)

    def test_groups_broadcast_via_ops(self):
        b, s, h, p, n, g = 2, 32, 4, 8, 8, 2
        x = jax.random.normal(jax.random.fold_in(KEY, 35), (b, s, h, p)) * 0.5
        dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(KEY, 36), (b, s, h)))
        A = -jnp.exp(jax.random.normal(jax.random.fold_in(KEY, 37), (h,)) * 0.3)
        Bm = jax.random.normal(jax.random.fold_in(KEY, 38), (b, s, g, n)) * 0.5
        Cm = jax.random.normal(jax.random.fold_in(KEY, 39), (b, s, g, n)) * 0.5
        y, _ = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=8)
        Bh = jnp.repeat(Bm, h // g, axis=2)
        Ch = jnp.repeat(Cm, h // g, axis=2)
        y_ref, _ = ref.ssd_reference(x, dt, A, Bh, Ch)
        np.testing.assert_allclose(y, y_ref, rtol=5e-5, atol=5e-5)

    def test_initial_state_raises(self):
        """The kernel always scans from zero state; a caller passing a resume
        state must get a crisp error, not silently-wrong results."""
        b, s, h, p, n = 1, 8, 2, 4, 4
        x = jnp.zeros((b, s, h, p))
        dt = jnp.ones((b, s, h))
        A = -jnp.ones((h,))
        Bm = jnp.zeros((b, s, h, n))
        Cm = jnp.zeros((b, s, h, n))
        state = jnp.zeros((b, h, p, n))
        with pytest.raises(ValueError, match="initial_state"):
            ops.ssd_scan(x, dt, A, Bm, Cm, chunk=8, initial_state=state)
        # also at trace time under an enclosing jit (Python-level check)
        with pytest.raises(ValueError, match="initial_state"):
            jax.jit(lambda *a: ops.ssd_scan(*a, chunk=8,
                                            initial_state=state))(
                x, dt, A, Bm, Cm)

    def test_group_divisibility_raises(self):
        b, s, h, p, n, g = 1, 8, 4, 4, 4, 3        # 4 % 3 != 0
        x = jnp.zeros((b, s, h, p))
        dt = jnp.ones((b, s, h))
        A = -jnp.ones((h,))
        Bm = jnp.zeros((b, s, g, n))
        Cm = jnp.zeros((b, s, g, n))
        with pytest.raises(ValueError, match="h=4.*g=3"):
            ops.ssd_scan(x, dt, A, Bm, Cm, chunk=8)

    def test_chunked_jnp_matches_oracle(self):
        """The model's jnp SSD path (mamba.ssd_chunked) == sequential oracle."""
        from repro.models.mamba import ssd_chunked
        b, s, h, p, n, g = 2, 64, 4, 8, 8, 1
        x = jax.random.normal(jax.random.fold_in(KEY, 40), (b, s, h, p)) * 0.5
        dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(KEY, 41), (b, s, h)))
        A = -jnp.exp(jax.random.normal(jax.random.fold_in(KEY, 42), (h,)) * 0.3)
        Bm = jax.random.normal(jax.random.fold_in(KEY, 43), (b, s, g, n)) * 0.5
        Cm = jax.random.normal(jax.random.fold_in(KEY, 44), (b, s, g, n)) * 0.5
        y, fin = ssd_chunked(x, dt, A, Bm, Cm, chunk=16)
        Bh = jnp.repeat(Bm, h, axis=2)
        Ch = jnp.repeat(Cm, h, axis=2)
        y_ref, fin_ref = ref.ssd_reference(x, dt, A, Bh, Ch)
        np.testing.assert_allclose(y, y_ref, rtol=5e-5, atol=5e-5)
        np.testing.assert_allclose(fin, fin_ref, rtol=5e-5, atol=5e-5)


class TestOpsWrappers:
    """The jitted public wrappers: GQA broadcast, non-default eps, errors."""

    @pytest.mark.parametrize("causal", [True, False])
    @pytest.mark.parametrize("Hkv", [2, 8])
    def test_gqa_vs_ref(self, causal, Hkv):
        """Wrapper (GQA layout, Hkv <= H) == manual kv-repeat + oracle."""
        B, S, H, hd = 2, 64, 8, 32
        q = jax.random.normal(jax.random.fold_in(KEY, 50), (B, S, H, hd))
        k = jax.random.normal(jax.random.fold_in(KEY, 51), (B, S, Hkv, hd))
        v = jax.random.normal(jax.random.fold_in(KEY, 52), (B, S, Hkv, hd))
        o = ops.flash_attention(q, k, v, causal=causal)
        rep = H // Hkv
        kf = jnp.repeat(k, rep, axis=2).transpose(0, 2, 1, 3).reshape(B * H, S, hd)
        vf = jnp.repeat(v, rep, axis=2).transpose(0, 2, 1, 3).reshape(B * H, S, hd)
        qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
        o_ref = ref.mha_reference(qf, kf, vf, causal=causal)
        o_ref = o_ref.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
        tier = ops.TOLERANCE_TIERS["flash_attention"]
        np.testing.assert_allclose(o, o_ref, **tier)

    def test_head_divisibility_raises(self):
        B, S, H, Hkv, hd = 1, 64, 8, 3, 32         # 8 % 3 != 0
        q = jnp.zeros((B, S, H, hd))
        k = jnp.zeros((B, S, Hkv, hd))
        with pytest.raises(ValueError, match="H=8.*Hkv=3"):
            ops.flash_attention(q, k, k)

    @pytest.mark.parametrize("eps", [1e-3, 0.5])
    def test_rmsnorm_eps_threaded(self, eps):
        """ops.rmsnorm forwards a non-default eps to the kernel (the silent
        bug class this PR removes: kwargs accepted but dropped)."""
        x = jax.random.normal(jax.random.fold_in(KEY, 53), (4, 64))
        s = jax.random.normal(jax.random.fold_in(KEY, 54), (64,))
        o = ops.rmsnorm(x, s, eps=eps)
        tier = ops.TOLERANCE_TIERS["rmsnorm"]
        np.testing.assert_allclose(o, ref.rmsnorm_reference(x, s, eps=eps),
                                   **tier)
        # with a large eps the default-eps oracle must NOT match — proves the
        # value actually reached the kernel
        assert not np.allclose(o, ref.rmsnorm_reference(x, s), **tier)


class TestFusedAdam:
    @pytest.mark.parametrize("n", [128, 33, 4097])
    @pytest.mark.parametrize("step", [1, 7])
    def test_vs_hot_path_oracle(self, n, step):
        """fused_adam == optim.adam.adam_update_flat_np within its tier
        (n=33/4097 exercise the lane-padding path)."""
        from repro.optim.adam import AdamConfig, adam_update_flat_np
        acfg = AdamConfig()
        rng = np.random.default_rng(n * 10 + step)
        g = rng.standard_normal(n).astype(np.float32)
        st = {"master": rng.standard_normal(n).astype(np.float32),
              "mu": (rng.standard_normal(n) * 0.01).astype(np.float32),
              "nu": np.abs(rng.standard_normal(n) * 0.01).astype(np.float32)}
        m, mu, nu = ops.fused_adam(
            jnp.asarray(g), jnp.asarray(st["master"]), jnp.asarray(st["mu"]),
            jnp.asarray(st["nu"]), step=step, b1=acfg.b1, b2=acfg.b2,
            eps=acfg.eps, lr=acfg.lr, weight_decay=acfg.weight_decay)
        want = adam_update_flat_np(g, st, step, acfg)
        tier = ops.TOLERANCE_TIERS["fused_adam"]
        np.testing.assert_allclose(m, want["master"], **tier)
        np.testing.assert_allclose(mu, want["mu"], **tier)
        np.testing.assert_allclose(nu, want["nu"], **tier)

    def test_shape_mismatch_raises(self):
        z = jnp.zeros(8)
        with pytest.raises(ValueError, match="mismatched operand shapes"):
            ops.fused_adam(z, z, z, jnp.zeros(9), step=1)


class TestKernelCorpus:
    def test_all_cases_within_declared_tier(self):
        """The shared corpus (kernels/check.py) — same rows the
        KernelConsistencyChecker spot-checks and CI gates on."""
        from repro.kernels.check import check_kernels
        rows = check_kernels(seed=0)
        assert {r["kernel"] for r in rows} == set(ops.TOLERANCE_TIERS)
        bad = [r for r in rows if not r["within_tolerance"]]
        assert not bad, f"kernel cases outside declared tier: {bad}"
