"""Unit + property tests for the four ElasWave planners."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # container lacks hypothesis -> deterministic stub
    from _hypothesis_stub import given, settings, strategies as st

from repro.core.planners.dataflow import plan_dataflow
from repro.core.planners.graph import (brute_force_partition,
                                       minimax_layer_partition)
from repro.core.planners.dvfs import (ACHIEVABLE, UNACHIEVABLE,
                                      bisect_min_feasible, plan_dvfs)
from repro.core.planners.rng import plan_rng_reshard, verify_equivalence


# ---------------------------------------------------------------- dataflow --
class TestDataflow:
    def test_paper_example(self):
        """Paper §4.1: DP=3, mbs=2 -> DP=2, mbs=3; product invariant."""
        plan = plan_dataflow(global_batch=6, num_micro_batches=1, surviving_dp=2)
        assert plan.micro_batch_sizes == (3, 3)
        assert sum(plan.micro_batch_sizes) * plan.num_micro_batches == 6

    def test_uneven_split_weights(self):
        plan = plan_dataflow(global_batch=16, num_micro_batches=2, surviving_dp=3)
        assert sum(plan.micro_batch_sizes) == 8
        assert abs(sum(plan.grad_weights) - 1.0) < 1e-12
        # weights proportional to sizes
        for s, w in zip(plan.micro_batch_sizes, plan.grad_weights):
            assert abs(w - s / 8) < 1e-12

    @given(st.integers(1, 64), st.integers(1, 8), st.integers(1, 16))
    @settings(max_examples=100, deadline=None)
    def test_global_batch_invariant(self, per_micro, num_micro, dp):
        gb = per_micro * num_micro
        plan = plan_dataflow(gb, num_micro, dp)
        plan.validate()
        assert max(plan.micro_batch_sizes) - min(plan.micro_batch_sizes) <= 1


# ------------------------------------------------------------------- graph --
def _mk_costs(layer_costs, layer_mems):
    pre_c = np.concatenate([[0], np.cumsum(layer_costs)])
    pre_m = np.concatenate([[0], np.cumsum(layer_mems)])

    def t(p, a, b):
        return float(pre_c[b + 1] - pre_c[a])

    def mem(p, a, b):
        return float(pre_m[b + 1] - pre_m[a])

    return t, mem


class TestMinimaxPartition:
    def test_balanced_uniform(self):
        t, mem = _mk_costs([1.0] * 8, [1.0] * 8)
        plan = minimax_layer_partition(8, 4, t, mem, [100] * 4)
        assert plan.feasible
        assert plan.layers_per_stage == (2, 2, 2, 2)
        assert plan.worst_mini_step == 2.0

    def test_memory_infeasible(self):
        t, mem = _mk_costs([1.0] * 4, [10.0] * 4)
        # caps allow 2 layers per stage -> feasible balanced split
        plan = minimax_layer_partition(4, 2, t, mem, [25.0, 25.0])
        assert plan.feasible and plan.layers_per_stage == (2, 2)
        # caps allow at most 1 layer per stage -> 4 layers over 2 stages fail
        plan = minimax_layer_partition(4, 2, t, mem, [15.0, 15.0])
        assert not plan.feasible

    def test_respects_caps(self):
        t, mem = _mk_costs([1, 1, 1, 1], [4, 1, 1, 1])
        plan = minimax_layer_partition(4, 2, t, mem, [4.0, 100.0])
        assert plan.feasible
        a, b = plan.stage_ranges[0]
        assert mem(0, a, b) <= 4.0

    @given(st.lists(st.floats(0.1, 10), min_size=4, max_size=9),
           st.integers(2, 4))
    @settings(max_examples=60, deadline=None)
    def test_matches_bruteforce(self, costs, P):
        if len(costs) < P:
            return
        mems = [1.0] * len(costs)
        t, mem = _mk_costs(costs, mems)
        caps = [100.0] * P
        dp = minimax_layer_partition(len(costs), P, t, mem, caps)
        bf = brute_force_partition(len(costs), P, t, mem, caps)
        assert dp.feasible == bf.feasible
        if dp.feasible:
            assert abs(dp.worst_mini_step - bf.worst_mini_step) < 1e-9

    @given(st.lists(st.floats(0.5, 5), min_size=6, max_size=8),
           st.lists(st.floats(0.5, 3), min_size=6, max_size=8))
    @settings(max_examples=40, deadline=None)
    def test_matches_bruteforce_with_caps(self, costs, mems):
        n = min(len(costs), len(mems))
        costs, mems = costs[:n], mems[:n]
        P = 3
        if n < P:
            return
        t, mem = _mk_costs(costs, mems)
        caps = [sum(mems) / P * 1.5] * P
        dp = minimax_layer_partition(n, P, t, mem, caps)
        bf = brute_force_partition(n, P, t, mem, caps)
        assert dp.feasible == bf.feasible
        if dp.feasible:
            assert abs(dp.worst_mini_step - bf.worst_mini_step) < 1e-9


# -------------------------------------------------------------------- dvfs --
class TestDvfs:
    def test_already_aligned(self):
        plan = plan_dvfs(lambda f: 1.0, 1.0, 1.2, target=1.0, eps=0.05,
                         df_min=0.01)
        assert plan.status == ACHIEVABLE and plan.freq == 1.0

    def test_unachievable(self):
        # even at f_max the stage lags
        plan = plan_dvfs(lambda f: 2.0 / f, 1.0, 1.2, target=1.0, eps=0.01,
                         df_min=0.01)
        assert plan.status == UNACHIEVABLE and plan.freq == 1.2

    def test_minimum_uplift(self):
        # time = 1.15/f; need <= 1.0 -> f* = 1.15
        plan = plan_dvfs(lambda f: 1.15 / f, 1.0, 1.2, target=1.0, eps=0.0,
                         df_min=0.001)
        assert plan.status == ACHIEVABLE
        assert 1.15 <= plan.freq <= 1.16

    @given(st.floats(1.0, 1.2), st.floats(0.001, 0.05))
    @settings(max_examples=50, deadline=None)
    def test_bisect_bound(self, f_needed, df_min):
        f = bisect_min_feasible(1.0, 1.2, lambda x: x >= f_needed, df_min)
        assert f >= f_needed - 1e-9
        assert f <= min(1.2, f_needed + max(df_min, 1e-9) + 1e-9)


# --------------------------------------------------------------------- rng --
class TestRngPlanner:
    def test_stream_moves(self):
        plan = plan_rng_reshard(
            old_layer_stage=[0, 0, 1, 1], new_layer_stage=[0, 1, 1, 1],
            old_sample_rank={0: 0, 1: 1, 2: 2}, new_sample_rank={0: 0, 1: 0, 2: 1})
        assert plan.layer_stream_moves == ((1, 0, 1),)
        assert (1, 1, 0) in plan.sample_stream_moves
        assert plan.transfer_bytes == 3 * 16

    def test_equivalence(self):
        import jax
        assert verify_equivalence(jax.random.key(0), step=3,
                                  layer_ids=range(4), sample_ids=range(8))
