"""Dynamic communicator: in-place edits vs rebuilds."""
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # container lacks hypothesis -> deterministic stub
    from _hypothesis_stub import given, settings, strategies as st

from repro.core.communicator import (DynamicCommunicator, build_hybrid_groups,
                                     ring_links)


class TestGroups:
    def test_hybrid_group_shapes(self):
        g = build_hybrid_groups(dp=4, pp=3)
        assert len([k for k in g if k.startswith("dp_")]) == 3
        assert len([k for k in g if k.startswith("pp_")]) == 4
        for name, ranks in g.items():
            assert len(set(ranks)) == len(ranks)


class TestEdit:
    def test_scale_down_touches_only_affected(self):
        comm = DynamicCommunicator(build_hybrid_groups(dp=4, pp=4))
        dead = 5  # (d=1, p=1)
        st_ = comm.edit(remove=[dead])
        assert st_.mode == "edit"
        # the dead rank is gone from every group
        for ranks in comm.groups.values():
            assert dead not in ranks
        # only the two groups containing the rank were touched:
        # each ring loses 2 links, gains at most 1 (neighbor reconnect)
        assert st_.links_created <= 2
        assert st_.links_destroyed <= 4

    def test_edit_faster_than_rebuilds(self):
        for n in (8, 16, 32, 64):
            groups = build_hybrid_groups(dp=n // 4, pp=4)
            c1 = DynamicCommunicator(groups)
            c2 = DynamicCommunicator(groups)
            c3 = DynamicCommunicator(groups)
            dead = 1
            t_edit = c1.edit(remove=[dead]).seconds
            t_part = c2.partial_rebuild(remove=[dead]).seconds
            new_groups = {k: [r for r in v if r != dead]
                          for k, v in c3.groups.items()}
            t_full = c3.full_rebuild(new_groups).seconds
            assert t_edit < t_part < t_full
            assert t_edit < 1.0          # paper: sub-second

    def test_scale_up_reuses_links(self):
        comm = DynamicCommunicator({"g": [0, 1, 2]})
        before = set(comm.links)
        st_ = comm.edit(add=[("g", 3)])
        assert st_.links_reused >= 1
        assert 3 in comm.groups["g"]
        # previously intact links still present unless displaced by the ring
        assert before & comm.links

    @given(st.integers(2, 6), st.integers(2, 6))
    @settings(max_examples=30, deadline=None)
    def test_links_consistent_after_edit(self, dp, pp):
        comm = DynamicCommunicator(build_hybrid_groups(dp, pp))
        comm.edit(remove=[0])
        # invariant: links == union of ring links of all groups
        want = set()
        for g in comm.groups.values():
            want |= ring_links(g)
        assert want <= comm.links


class TestMTTRScaling:
    def test_edit_cost_flat_in_cluster_size(self):
        """Paper: edit cost is O(degree), rebuilds grow with scale."""
        times_edit, times_full = [], []
        for dp in (2, 4, 8, 16):
            groups = build_hybrid_groups(dp, 4)
            c = DynamicCommunicator(groups)
            times_edit.append(c.edit(remove=[1]).seconds)
            c2 = DynamicCommunicator(groups)
            ng = {k: [r for r in v if r != 1] for k, v in c2.groups.items()}
            times_full.append(c2.full_rebuild(ng).seconds)
        assert max(times_edit) / min(times_edit) < 1.5      # ~flat
        assert times_full[-1] / times_full[0] > 4           # grows with scale
