"""ZeRO layouts, migration plans, snapshot, live remap — unit + property."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # container lacks hypothesis -> deterministic stub
    from _hypothesis_stub import given, settings, strategies as st

from repro.core import zero
from repro.core.fabric.remap import IntegrityError, LiveRemap
from repro.core.fabric.snapshot import SnapshotPool
from repro.optim.adam import AdamConfig, adam_update_flat


# -------------------------------------------------------------- zero layout --
class TestLayouts:
    @given(st.lists(st.integers(8, 200), min_size=1, max_size=6),
           st.integers(1, 8), st.sampled_from(["contiguous", "interleaved"]))
    @settings(max_examples=80, deadline=None)
    def test_partition_exact(self, sizes, dp, kind):
        lay = zero.Layout(kind, tuple(sizes), dp)
        covered = []
        for j in range(dp):
            covered += lay.owner_intervals(j)
        covered.sort()
        # exact disjoint cover of [0, total)
        cur = 0
        for s, e in covered:
            assert s == cur
            cur = e
        assert cur == lay.total

    def test_interleaved_same_rank_owns_every_layer(self):
        lay = zero.Layout("interleaved", (40, 80, 120), 4)
        ivs = lay.owner_intervals(2)
        assert len(ivs) == 3      # one shard per layer


class TestMigrationPlan:
    @given(st.lists(st.integers(64, 512), min_size=2, max_size=5),
           st.integers(2, 6))
    @settings(max_examples=60, deadline=None)
    def test_interleaved_is_pure_p2p(self, sizes, dp):
        pos = len(sizes) // 2
        plan = zero.migration_plan("interleaved", sizes, pos, dp, 0, 1, sizes[:1])
        assert all(not t.intra_stage for t in plan)
        assert len(plan) == dp
        assert sum(t.nbytes for t in plan) == sizes[pos]
        # disjoint rank-to-rank: src == dst index
        assert all(t.src_rank == t.dst_rank for t in plan)

    @given(st.lists(st.integers(64, 512), min_size=2, max_size=5),
           st.integers(2, 6))
    @settings(max_examples=60, deadline=None)
    def test_contiguous_costs_more(self, sizes, dp):
        pos = len(sizes) // 2
        plan_c = zero.migration_plan("contiguous", sizes, pos, dp, 0, 1, sizes[:1])
        b = zero.plan_bytes(plan_c)
        # cross-stage bytes = the migrating layer exactly
        assert b["cross_stage"] == sizes[pos]
        # intra-stage resharding appears for dp > 1 (unless cuts align)
        theo = zero.theoretical_bytes("contiguous", sizes[pos], dp)
        inter = zero.theoretical_bytes("interleaved", sizes[pos], dp)
        assert inter == sizes[pos]
        assert b["total"] >= inter  # contiguous never cheaper
        # theoretical closed form is an upper-bound-ish estimate
        assert b["total"] <= theo * 2.5 + 64


# ---------------------------------------------------------------- snapshot --
class TestSnapshot:
    def test_ring_identity_after_steps(self):
        """Host snapshot == neighbor device state after every step."""
        import jax.numpy as jnp
        n, m = 4, 64
        rng = np.random.default_rng(0)
        adam = AdamConfig()
        states = [{"master": rng.normal(size=m).astype(np.float32),
                   "mu": np.zeros(m, np.float32), "nu": np.zeros(m, np.float32)}
                  for _ in range(n)]
        pool = SnapshotPool(n, adam)
        pool.bootstrap(0, states)
        for step in range(1, 4):
            grads = [rng.normal(size=m).astype(np.float32) for _ in range(n)]
            # device updates
            for j in range(n):
                _, new = adam_update_flat(jnp.asarray(grads[j]),
                                          {k: jnp.asarray(v) for k, v in states[j].items()},
                                          step, adam)
                states[j] = {k: np.asarray(v) for k, v in new.items()}
            pool.snapshot_step(step, grads, step)
            for i in range(n):
                j = pool.backup_rank(i)
                for comp in ("master", "mu", "nu"):
                    np.testing.assert_array_equal(pool.host[i][comp],
                                                  states[j][comp])

    def test_grad_bytes_4x_smaller(self):
        pool = SnapshotPool(2, AdamConfig())
        pool.bootstrap(0, [{"master": np.zeros(10, np.float32),
                            "mu": np.zeros(10, np.float32),
                            "nu": np.zeros(10, np.float32)}] * 2)
        st_ = pool.snapshot_step(1, [np.zeros(10, np.float32)] * 2, 1)
        assert st_.state_bytes_equiv >= 3 * st_.grad_bytes_sent

    def test_bf16_compression_halves_bytes_bounded_drift(self):
        import jax.numpy as jnp
        rng = np.random.default_rng(1)
        n, m = 2, 256
        states = [{"master": rng.normal(size=m).astype(np.float32),
                   "mu": np.zeros(m, np.float32), "nu": np.zeros(m, np.float32)}
                  for _ in range(n)]
        exact = SnapshotPool(n, AdamConfig())
        comp = SnapshotPool(n, AdamConfig(), compress="bf16")
        exact.bootstrap(0, states)
        comp.bootstrap(0, states)
        grads = [rng.normal(size=m).astype(np.float32) for _ in range(n)]
        s1 = exact.snapshot_step(1, grads, 1)
        s2 = comp.snapshot_step(1, grads, 1)
        assert s2.grad_bytes_sent * 2 == s1.grad_bytes_sent
        # drift bounded by bf16 rounding through one Adam step
        for i in range(n):
            d = np.abs(exact.host[i]["master"] - comp.host[i]["master"]).max()
            assert d < 1e-4, d


# -------------------------------------------------------------- live remap --
class TestLiveRemap:
    def _setup(self, total, dp, kind):
        lay = zero.Layout(kind, (total,), dp) if kind == "contiguous" else \
            zero.Layout(kind, (total // 2, total - total // 2), dp)
        return lay

    @given(st.integers(2, 6), st.integers(0, 5),
           st.sampled_from(["contiguous", "interleaved"]))
    @settings(max_examples=60, deadline=None)
    def test_shrink_preserves_state(self, dp, fail_idx, kind):
        if fail_idx >= dp or dp < 2:
            return
        sizes = (96, 160)
        lay = zero.Layout(kind, sizes, dp)
        total = lay.total
        truth = np.arange(total, dtype=np.float32)
        surviving = [r for r in range(dp) if r != fail_idx]
        device_parts = {r: lay.owner_intervals(r) for r in surviving}
        host_parts = {fail_idx: lay.owner_intervals(fail_idx)}
        new_lay = zero.Layout(kind, sizes, dp - 1)
        target = {r: new_lay.owner_intervals(j) for j, r in enumerate(surviving)}
        rm = LiveRemap()
        plan = rm.compute_plan(total, device_parts, host_parts, target)
        # every target byte covered exactly once
        m = plan.overlap_matrix(dp)
        assert m.sum() == total

        def segs_for(parts):
            return {r: { (s, e): truth[s:e] for (s, e) in ivs }
                    for r, ivs in parts.items()}

        out = rm.execute(plan, total, segs_for(device_parts), segs_for(host_parts))
        # reassemble and compare
        rebuilt = np.zeros(total, np.float32)
        for j, r in enumerate(surviving):
            off = 0
            shard = out[r]
            for s, e in new_lay.owner_intervals(j):
                rebuilt[s:e] = shard[off:off + (e - s)]
                off += e - s
        np.testing.assert_array_equal(rebuilt, truth)

    def test_integrity_failure_detected(self):
        rm = LiveRemap()
        with pytest.raises(IntegrityError):
            rm.integrity_check(100, {0: [(0, 40)]}, {1: [(50, 100)]})
