"""The flat-state fast path is BIT-identical to the seed implementation.

The numerics guardrail of the fast-path refactor: a full elastic run
(train -> fail-stop -> recover -> train -> rejoin -> train) produces exactly
the same loss trajectory and post-recovery shard contents under
``fast_path=True`` (vmap-batched grads, fused host Adam, indexed scatter,
batched recovery) as under ``fast_path=False`` (the seed per-item /
per-shard / per-entry loops preserved in ``core/legacy.py``).  No tolerance:
``==`` on floats.
"""
import numpy as np
import pytest

from repro.core.cluster import VirtualCluster
from repro.core.statespace import COMPONENTS
from repro.models import registry as R

# every test here drives real jit-compiled training on TWO clusters — the
# whole module lives in the slow shard (fast CI runs -m "not slow")
pytestmark = pytest.mark.slow

CFG = R.tiny_config("dense", num_layers=8, dropout_rate=0.1)


def mk(fast, dp=4, pp=2, **kw):
    return VirtualCluster(CFG, dp=dp, pp=pp, global_batch=16, num_micro=2,
                          seq_len=16, seed=0, fast_path=fast, **kw)


def assert_state_identical(a: VirtualCluster, b: VirtualCluster):
    assert len(a.stages) == len(b.stages)
    for p, (sa, sb) in enumerate(zip(a.stages, b.stages)):
        assert sa.dp_ranks == sb.dp_ranks
        assert sa.entries == sb.entries and sa.sizes == sb.sizes
        for c in COMPONENTS:
            np.testing.assert_array_equal(
                a._stage_full_vec(sa, c), b._stage_full_vec(sb, c),
                err_msg=f"stage {p} component {c}")
        # per-rank shard contents too (layout permutations must agree)
        for r in sa.dp_ranks:
            for c in COMPONENTS:
                np.testing.assert_array_equal(
                    sa.shard(r)[c], sb.shard(r)[c],
                    err_msg=f"stage {p} rank {r} component {c}")


class TestElasticTrajectoryBitIdentical:
    """8+ steps with a fail-stop AND a scale-out on a tiny config; dropout
    on (RNG resharding exercised); uneven post-failure micro-batches
    (16/2/3 ranks -> sizes [3,3,2]) exercise the bucketed grad path."""

    @pytest.fixture(scope="class")
    def trajectories(self):
        out = {}
        for fast in (False, True):
            cl = mk(fast)
            losses = cl.run(3)
            rec1 = cl.recover_fail_stop(1, 1)
            losses += cl.run(3)
            rec2 = cl.recover_scale_out(1, 1)
            losses += cl.run(2)
            out[fast] = (cl, losses, rec1, rec2)
        return out

    def test_losses_bit_identical(self, trajectories):
        _, ref, _, _ = trajectories[False]
        _, fast, _, _ = trajectories[True]
        assert len(ref) == len(fast) == 8
        assert ref == fast          # exact float equality, no tolerance

    def test_post_recovery_shards_bit_identical(self, trajectories):
        assert_state_identical(trajectories[False][0], trajectories[True][0])

    def test_params_bit_identical(self, trajectories):
        from jax.flatten_util import ravel_pytree
        a, b = trajectories[False][0], trajectories[True][0]
        va = np.asarray(ravel_pytree((a.stem, a.layer_params, a.head))[0])
        vb = np.asarray(ravel_pytree((b.stem, b.layer_params, b.head))[0])
        np.testing.assert_array_equal(va, vb)

    def test_mttr_records_identical(self, trajectories):
        """Deterministic record fields agree (``plan`` is measured planner
        wall clock, so only its presence is checked)."""
        _, _, r1a, r2a = trajectories[False]
        _, _, r1b, r2b = trajectories[True]
        for ka in ("detect", "rng_moves"):
            assert r1a[ka] == r1b[ka]
        assert set(r1a) == set(r1b) and set(r2a) == set(r2b)


class TestOtherModesBitIdentical:
    def test_naive_rng_mode(self):
        """The rank-addressed sids construction differs between paths —
        must still agree bit-for-bit."""
        ref = mk(False, rng_mode="naive").run(2)
        fast = mk(True, rng_mode="naive").run(2)
        assert ref == fast

    @pytest.mark.parametrize("layout", ["contiguous"])
    def test_contiguous_layout(self, layout):
        a, b = mk(False, zero_layout=layout), mk(True, zero_layout=layout)
        la = a.run(2)
        lb = b.run(2)
        a.recover_fail_stop(2, 0)
        b.recover_fail_stop(2, 0)
        la += a.run(1)
        lb += b.run(1)
        assert la == lb
        assert_state_identical(a, b)

    @pytest.mark.parametrize("family", ["moe", "ssm"])
    def test_families(self, family):
        """vmap-batched grads stay bit-identical across block types (MoE
        routing, SSD recurrences)."""
        cfg = R.tiny_config(family, dropout_rate=0.1) if family != "moe" \
            else R.tiny_config(family, dropout_rate=0.1, capacity_factor=16.0)
        losses = {}
        for fast in (False, True):
            cl = VirtualCluster(cfg, dp=2, pp=2, global_batch=8, num_micro=2,
                                seq_len=16, seed=0, fast_path=fast)
            losses[fast] = cl.run(2)
        assert losses[False] == losses[True]


class TestRecoveryRecordSchema:
    """All recovery records share ONE schema (fail-slow / scale-out / DVFS
    included), so ``_merge_recovery_records`` output shape never depends on
    the event kind."""

    def test_all_kinds_share_schema(self):
        from repro.core.events import ElasticEvent, EventKind
        cl = mk(True)
        cl.run(1)
        recs = {
            "fail_stop": cl.recover_fail_stop(0, 0),
            "fail_slow": cl.recover_fail_slow(1, 1, 1.5),
            "scale_out": cl.recover_scale_out(0, 0),
            "dvfs": cl.apply_event(ElasticEvent(
                EventKind.DVFS_SET, cl.step_count, (3,), freq=1.2)),
        }
        keysets = {k: frozenset(v) for k, v in recs.items()}
        assert len(set(keysets.values())) == 1, keysets
        assert all("rng_moves" in v for v in recs.values())
        # merged burst records keep the same shape
        from repro.core.cluster import _merge_recovery_records
        merged = _merge_recovery_records([recs["fail_stop"],
                                          recs["fail_slow"]])
        assert set(merged) == set(recs["fail_stop"])
