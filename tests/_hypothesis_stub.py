"""Deterministic fallback for the ``hypothesis`` API.

The container image does not ship ``hypothesis`` (see requirements-dev.txt,
which pins it for CI).  Rather than skipping every property-based module at
collection time, this stub re-implements the tiny slice of the API the test
suite uses — ``given``, ``settings``, and the ``integers``/``floats``/
``lists``/``sampled_from``/``tuples``/``booleans``/``one_of``/``data``
strategies — drawing a fixed number of examples from a seed derived from the
test's qualified name, so runs are reproducible and the properties still get
exercised on real values.

Failure reporting: when a property raises, the wrapper prints the derived
seed string and every drawn value of the falsifying example before
re-raising, so a stub-found counterexample is reproducible without
hypothesis' shrinking database.

When ``hypothesis`` IS installed the test modules import it directly and this
file is inert.
"""
from __future__ import annotations

import functools
import inspect
import random
from types import SimpleNamespace

# examples per property when running on the stub (hypothesis defaults to 100;
# the stub trades breadth for zero-dependency determinism)
MAX_EXAMPLES = 5


class Strategy:
    """A strategy is just a draw function over a seeded ``random.Random``."""

    def __init__(self, draw):
        self.draw = draw

    def map(self, f):
        return Strategy(lambda rnd: f(self.draw(rnd)))

    def filter(self, pred):
        def draw(rnd):
            for _ in range(1000):
                v = self.draw(rnd)
                if pred(v):
                    return v
            raise ValueError("filter predicate never satisfied")
        return Strategy(draw)


def integers(min_value=None, max_value=None):
    lo = 0 if min_value is None else int(min_value)
    hi = lo + 100 if max_value is None else int(max_value)
    return Strategy(lambda rnd: rnd.randint(lo, hi))


def floats(min_value=None, max_value=None, **_kw):
    lo = 0.0 if min_value is None else float(min_value)
    hi = lo + 1.0 if max_value is None else float(max_value)
    return Strategy(lambda rnd: rnd.uniform(lo, hi))


def lists(elements, min_size=0, max_size=None, **_kw):
    mx = (min_size + 5) if max_size is None else max_size
    return Strategy(lambda rnd: [elements.draw(rnd)
                                 for _ in range(rnd.randint(min_size, mx))])


def sampled_from(seq):
    seq = list(seq)
    return Strategy(lambda rnd: seq[rnd.randrange(len(seq))])


def booleans():
    return sampled_from([False, True])


def just(value):
    return Strategy(lambda rnd: value)


def tuples(*strats):
    return Strategy(lambda rnd: tuple(s.draw(rnd) for s in strats))


def one_of(*strats):
    if len(strats) == 1 and not isinstance(strats[0], Strategy):
        strats = tuple(strats[0])       # hypothesis accepts one iterable too
    if not strats:
        raise ValueError("one_of requires at least one strategy")
    return Strategy(lambda rnd: strats[rnd.randrange(len(strats))].draw(rnd))


class DataObject:
    """Interactive draws (``st.data()``): mid-test strategy pulls from the
    same seeded stream, recorded for the falsifying-example report."""

    def __init__(self, rnd):
        self._rnd = rnd
        self.draws = []

    def draw(self, strategy, label=None):
        v = strategy.draw(self._rnd)
        self.draws.append((label, v))
        return v

    def __repr__(self):
        inner = ", ".join(f"{lb or i}={v!r}"
                          for i, (lb, v) in enumerate(self.draws))
        return f"data({inner})"


def data():
    return Strategy(DataObject)


strategies = SimpleNamespace(integers=integers, floats=floats, lists=lists,
                             sampled_from=sampled_from, booleans=booleans,
                             just=just, tuples=tuples, one_of=one_of,
                             data=data)


def settings(max_examples=None, deadline=None, **_kw):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco


def given(*arg_strats, **kw_strats):
    """Replace the test with a loop over deterministically drawn examples.

    Positional strategies fill the test's rightmost positional parameters
    (hypothesis semantics), so ``self`` and pytest fixtures pass through.
    """
    def deco(fn):
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        n = len(arg_strats)
        names = [p.name for p in params[len(params) - n:]] if n else []
        drawn = set(names) | set(kw_strats)
        kept = [p for p in params if p.name not in drawn]
        seed_str = f"{fn.__module__}.{fn.__qualname__}"

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            limit = (getattr(wrapper, "_stub_max_examples", None)
                     or getattr(fn, "_stub_max_examples", None) or MAX_EXAMPLES)
            limit = min(int(limit), MAX_EXAMPLES)
            rnd = random.Random(seed_str)
            for i in range(limit):
                vals = [s.draw(rnd) for s in arg_strats]
                kvals = {k: s.draw(rnd) for k, s in kw_strats.items()}
                try:
                    fn(*args, *vals, **kwargs, **kvals)
                except Exception:
                    pairs = list(zip(names, vals)) + sorted(kvals.items())
                    shown = ", ".join(f"{k}={v!r}" for k, v in pairs)
                    print(f"\n[hypothesis-stub] falsifying example "
                          f"{i + 1}/{limit} of {seed_str}\n"
                          f"  seed string: {seed_str!r}\n"
                          f"  drawn: {shown}")
                    raise

        del wrapper.__wrapped__          # hide drawn params from pytest
        wrapper.__signature__ = sig.replace(parameters=kept)
        return wrapper
    return deco
