"""Schedule engine + agent unit tests."""
import numpy as np
import pytest

from repro.core.agent import Agent, Probe
from repro.core.cost_model import HardwareSpec
from repro.core.engine import ScheduleEngine
from repro.core.events import ElasticEvent, EventKind
from repro.models import registry as R


class TestEngine:
    def setup_method(self):
        self.cfg = R.tiny_config("dense", num_layers=8)
        self.engine = ScheduleEngine(self.cfg, seq=64,
                                     hw=HardwareSpec(), mem_cap=1e12)

    def _plan(self, **kw):
        args = dict(dp=4, pp=2, global_batch=32, num_micro=2,
                    layer_assignment=[(0, 3), (4, 7)],
                    failed_dp_ranks=[1],
                    old_sample_rank={i: i // 4 for i in range(16)})
        args.update(kw)
        ev = ElasticEvent(EventKind.FAIL_STOP, 10, (3,))
        return self.engine.plan(ev, **args)

    def test_plan_structure(self):
        plan = self._plan()
        assert plan.capacity_ok
        assert plan.new_dp == 3
        plan.dataflow.validate()
        assert plan.graph.feasible
        assert plan.plan_seconds < 0.5      # planning is cheap (paper: fast)

    def test_unbalanced_widths_shift_layers(self):
        """A narrower failed stage gets fewer layers (minimax rebalance)."""
        plan = self._plan(stage_widths=[2, 4])
        a0 = plan.graph.stage_ranges[0]
        a1 = plan.graph.stage_ranges[1]
        assert (a0[1] - a0[0]) < (a1[1] - a1[0])

    def test_memory_infeasible_flagged(self):
        eng = ScheduleEngine(self.cfg, seq=64, hw=HardwareSpec(), mem_cap=1.0)
        ev = ElasticEvent(EventKind.FAIL_STOP, 10, (3,))
        plan = eng.plan(ev, dp=4, pp=2, global_batch=32, num_micro=2,
                        layer_assignment=[(0, 3), (4, 7)],
                        failed_dp_ranks=[1],
                        old_sample_rank={i: i // 4 for i in range(16)})
        assert not plan.capacity_ok

    def test_rng_plan_covers_moves(self):
        plan = self._plan(stage_widths=[2, 4])
        moved_layers = {lid for (lid, _, _) in plan.migrations}
        rng_layers = {lid for (lid, _, _) in plan.rng.layer_stream_moves}
        assert moved_layers == rng_layers


class TestAgent:
    def test_fail_stop_detection(self):
        ag = Agent(num_ranks=4, miss_limit=2)
        probes = [Probe(0, r, heartbeat=(r != 2), step_seconds=1.0)
                  for r in range(4)]
        assert ag.observe(probes) == []          # first miss: not yet
        evs = ag.observe(probes)
        assert len(evs) == 1
        assert evs[0].kind == EventKind.FAIL_STOP and evs[0].ranks == (2,)
        # no duplicate reports
        assert ag.observe(probes) == []

    def test_fail_slow_detection(self):
        ag = Agent(num_ranks=4, window=4, slow_threshold=1.3)
        evs = []
        for step in range(6):
            probes = [Probe(step, r, True, 2.0 if r == 1 else 1.0)
                      for r in range(4)]
            evs += ag.observe(probes)
        kinds = [(e.kind, e.ranks) for e in evs]
        assert (EventKind.FAIL_SLOW, (1,)) in kinds

    def test_healthy_cluster_silent(self):
        ag = Agent(num_ranks=8)
        for step in range(10):
            probes = [Probe(step, r, True, 1.0 + 0.01 * r) for r in range(8)]
            assert ag.observe(probes) == []
