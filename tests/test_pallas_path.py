"""Pallas kernels in the training hot path: flag threading, per-family
pallas-vs-jnp forward-loss tolerance, the REPRO_USE_PALLAS knob, and the
tolerance-tier invariant stack over an elastic scenario (fail-stop +
scale-out).  Tiny configs throughout; interpret-mode numeric cases beyond the
dense smoke are marked ``slow``."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cluster import VirtualCluster
from repro.core.events import ElasticEvent, EventKind
from repro.core.invariants import (KernelConsistencyChecker,
                                   default_cluster_checkers)
from repro.kernels import ops
from repro.models import registry as R
from repro.scenarios import (ClusterScenarioRunner, ClusterWorkload, Scenario,
                             make_pallas_case, run_case)

LOSS_RTOL = KernelConsistencyChecker.LOSS_RTOL
LOSS_ATOL = KernelConsistencyChecker.LOSS_ATOL


def _cfg(family):
    if family in ("moe", "hybrid"):
        # full capacity: no token dropping, so both modes route identically
        kw = {"capacity_factor": 16.0}
    else:
        kw = {}
    if family == "hybrid":
        kw["num_layers"] = 4       # block_pattern needs L % attn_period == 0
    return R.tiny_config(family, **kw)


def _batch(cfg, batch=2, seq=16):
    key = jax.random.key(7)
    toks = jax.random.randint(key, (batch, seq), 0, cfg.vocab_size)
    b = {"tokens": toks, "labels": toks}
    if cfg.is_encdec:
        b["frames"] = jax.random.normal(jax.random.fold_in(key, 1),
                                        (batch, seq, cfg.d_model))
    return b


class TestForwardLossTolerance:
    """make_train_loss(use_pallas=True) vs plain jnp, per family, within the
    KernelConsistencyChecker's loss tolerance."""

    @pytest.mark.parametrize("family", [
        "dense",
        pytest.param("moe", marks=pytest.mark.slow),
        pytest.param("ssm", marks=pytest.mark.slow),
        pytest.param("hybrid", marks=pytest.mark.slow),
        pytest.param("audio", marks=pytest.mark.slow),
    ])
    def test_loss_within_tier(self, family):
        cfg = _cfg(family)
        params = R.init_model(jax.random.key(0), cfg)
        b = _batch(cfg)
        l_jnp = float(R.make_train_loss(cfg, use_pallas=False)(params, b))
        l_pal = float(R.make_train_loss(cfg, use_pallas=True)(params, b))
        assert abs(l_pal - l_jnp) <= LOSS_ATOL + LOSS_RTOL * abs(l_jnp), \
            f"{family}: pallas loss {l_pal!r} vs jnp {l_jnp!r}"

    def test_grads_within_attention_tier(self):
        """The custom VJPs backpropagate the oracle's gradients; the only
        divergence source is the pallas forward activations, so grads stay
        within the (loosest) attention tier."""
        cfg = _cfg("dense")
        params = R.init_model(jax.random.key(0), cfg)
        b = _batch(cfg)
        g0 = jax.grad(R.make_train_loss(cfg, use_pallas=False))(params, b)
        g1 = jax.grad(R.make_train_loss(cfg, use_pallas=True))(params, b)
        tier = ops.TOLERANCE_TIERS["flash_attention"]
        for a, c in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
            np.testing.assert_allclose(a, c, rtol=10 * tier["rtol"],
                                       atol=10 * tier["atol"])

    @pytest.mark.slow
    def test_encdec_remat_threads_with_pallas(self):
        """Satellite 1: make_train_loss forwards use_pallas AND remat to the
        enc-dec family (previously dropped on the floor).  Remat must not
        change the forward value in either kernel mode."""
        cfg = _cfg("audio")
        assert cfg.is_encdec
        params = R.init_model(jax.random.key(0), cfg)
        b = _batch(cfg)
        for up in (False, True):
            l0 = float(R.make_train_loss(cfg, use_pallas=up)(params, b))
            l1 = float(R.make_train_loss(cfg, use_pallas=up, remat=True)(
                params, b))
            assert l0 == l1, f"remat changed forward loss (use_pallas={up})"
            g = jax.grad(R.make_train_loss(cfg, use_pallas=up, remat=True))(
                params, b)
            assert all(bool(jnp.isfinite(x).all())
                       for x in jax.tree.leaves(g))


CLUSTER_KW = dict(dp=2, pp=1, global_batch=2, num_micro=1, seq_len=8, seed=0)


class TestUsePallasKnob:
    def test_env_and_arg_resolution(self, monkeypatch):
        cfg = R.tiny_config("dense", num_layers=2)
        monkeypatch.delenv("REPRO_USE_PALLAS", raising=False)
        assert VirtualCluster(cfg, **CLUSTER_KW).use_pallas is False
        monkeypatch.setenv("REPRO_USE_PALLAS", "1")
        assert VirtualCluster(cfg, **CLUSTER_KW).use_pallas is True
        monkeypatch.setenv("REPRO_USE_PALLAS", "0")
        assert VirtualCluster(cfg, **CLUSTER_KW).use_pallas is False
        # explicit argument beats the environment
        monkeypatch.setenv("REPRO_USE_PALLAS", "1")
        assert VirtualCluster(cfg, use_pallas=False,
                              **CLUSTER_KW).use_pallas is False

    def test_workload_field_reaches_cluster(self):
        w = ClusterWorkload(dp=2, pp=1, global_batch=2, num_micro=1,
                            seq_len=8, num_layers=2, use_pallas=True)
        assert w.make_cluster().use_pallas is True
        # the checker's twin flips the flag via the same override path
        assert w.make_cluster(use_pallas=False).use_pallas is False


class TestKernelConsistencyChecker:
    def test_default_checkers_swap(self):
        names = [c.name for c in default_cluster_checkers()]
        assert "parameter-consistency" in names
        assert "kernel-consistency" not in names
        names_p = [c.name for c in default_cluster_checkers(use_pallas=True)]
        assert "kernel-consistency" in names_p
        assert "parameter-consistency" not in names_p
        assert len(names) == len(names_p) == 4

    def test_pallas_elastic_scenario(self):
        """Acceptance: a fail-stop + scale-out scenario runs end-to-end in
        pallas mode under the four-invariant stack, with the jnp twin within
        the declared tolerance at every event and step boundary.  (Corpus
        spot-check skipped here for speed — tested directly in
        test_kernels.py.)"""
        w = ClusterWorkload(dp=2, pp=1, global_batch=2, num_micro=1,
                            seq_len=8, num_layers=2, use_pallas=True)
        sc = Scenario("pallas-elastic", (
            ElasticEvent(EventKind.FAIL_STOP, 1, (1,)),
            ElasticEvent(EventKind.SCALE_OUT, 2, (1,)),
        ), horizon=3)
        cks = default_cluster_checkers(use_pallas=True)
        cks[0].spot_check = False
        res = ClusterScenarioRunner(sc, w, checkers=cks).run()
        assert res is not None

    @pytest.mark.slow
    def test_pallas_elastic_scenario_full(self):
        """Fuller variant: pp=2, corpus spot-check on, run via the fuzz
        harness path (run_case picks the pallas checker stack from
        workload.use_pallas)."""
        from repro.scenarios import FuzzCase
        w = ClusterWorkload(dp=2, pp=2, global_batch=2, num_micro=1,
                            seq_len=8, num_layers=4, use_pallas=True)
        sc = Scenario("pallas-elastic-full", (
            ElasticEvent(EventKind.FAIL_STOP, 1, (1,)),
            ElasticEvent(EventKind.SCALE_OUT, 2, (1,)),
        ), horizon=3)
        run_case(FuzzCase(0, "pallas", sc, w))


class TestPallasFuzzMode:
    def test_case_shape(self):
        for seed in range(8):
            c = make_pallas_case(seed)
            assert c.mode == "pallas"
            assert c.workload.use_pallas is True
            assert c.workload.family in ("dense", "ssm")
            assert c.scenario.horizon <= 3
            assert "--mode pallas" in c.repro()
