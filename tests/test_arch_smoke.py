"""Per-architecture smoke tests: reduced same-family config, one forward +
one train step on CPU, asserting output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import registry as R
from repro.models import transformer as T
from repro.models import encdec as E
from repro.optim.adam import AdamConfig, adam_update, init_opt_state

ARCHS = configs.ARCH_IDS


def _batch(cfg, batch=2, seq=16):
    key = jax.random.key(7)
    toks = jax.random.randint(key, (batch, seq), 0, cfg.vocab_size)
    b = {"tokens": toks, "labels": toks}
    if cfg.is_encdec:
        b["frames"] = jax.random.normal(jax.random.fold_in(key, 1),
                                        (batch, seq, cfg.d_model))
    if cfg.frontend_embeds:
        b["prefix_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 2), (batch, cfg.frontend_embeds, cfg.d_model))
    return b


@pytest.mark.parametrize("arch", ARCHS)
class TestSmoke:
    def test_forward_shapes_and_finite(self, arch):
        cfg = configs.get_smoke_config(arch)
        params = R.init_model(jax.random.key(0), cfg)
        b = _batch(cfg)
        if cfg.is_encdec:
            enc = E.encode(params, cfg, b["frames"])
            logits, _ = E.decode(params, cfg, b["tokens"], enc)
            assert logits.shape == (2, 16, cfg.vocab_size)
        else:
            logits, _, _ = T.forward(params, cfg, b["tokens"],
                                     prefix_embeds=b.get("prefix_embeds"))
            P = cfg.frontend_embeds
            assert logits.shape == (2, 16 + P, cfg.vocab_size)
        assert bool(jnp.isfinite(logits).all())

    def test_one_train_step(self, arch):
        cfg = configs.get_smoke_config(arch)
        params = R.init_model(jax.random.key(0), cfg)
        adam = AdamConfig(lr=1e-3)
        opt = init_opt_state(params, adam)
        loss_fn = R.make_train_loss(cfg)
        b = _batch(cfg)
        l0, grads = jax.value_and_grad(loss_fn)(params, b)
        params2, opt = adam_update(params, grads, opt, adam)
        l1 = loss_fn(params2, b)
        assert bool(jnp.isfinite(l0)) and bool(jnp.isfinite(l1))
        assert float(l1) < float(l0)       # one step on same batch reduces loss
        # all updated params finite
        assert all(bool(jnp.isfinite(p).all()) for p in jax.tree.leaves(params2))

    def test_decode_step(self, arch):
        cfg = configs.get_smoke_config(arch)
        params = R.init_model(jax.random.key(0), cfg)
        b = _batch(cfg)
        if cfg.is_encdec:
            enc = E.encode(params, cfg, b["frames"])
            caches = E.init_decoder_caches(cfg, 2, 32)
            logits, caches = E.encdec_decode_step(
                params, cfg, b["tokens"][:, :1], enc, caches, 0)
        else:
            caches = T.init_caches(cfg, 2, 32)
            _, caches = T.prefill(params, cfg, b["tokens"], caches)
            logits, caches = T.decode_step(params, cfg, b["tokens"][:, :1],
                                           caches, 16)
        assert logits.shape == (2, 1, cfg.vocab_size)
        assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    """The full configs carry the exact published dimensions."""
    cfg = configs.get_config(arch)
    expect = {
        "internvl2_76b": (80, 8192, 64, 8, 28672, 128256),
        "mamba2_2p7b": (64, 2560, 0, 0, 0, 50280),
        "llama4_scout_17b_a16e": (48, 5120, 40, 8, 8192, 202048),
        "deepseek_v3_671b": (61, 7168, 128, 128, 18432, 129280),
        "jamba_1p5_large_398b": (72, 8192, 64, 8, 24576, 65536),
        "codeqwen1p5_7b": (32, 4096, 32, 32, 13440, 92416),
        "llama3_405b": (126, 16384, 128, 8, 53248, 128256),
        "deepseek_67b": (95, 8192, 64, 8, 22016, 102400),
        "nemotron_4_15b": (32, 6144, 48, 8, 24576, 256000),
        "whisper_base": (6, 512, 8, 8, 2048, 51865),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expect


def test_param_counts_sane():
    """Totals should land near the published sizes."""
    bands = {
        "mamba2_2p7b": (2.4e9, 3.0e9),
        "deepseek_v3_671b": (650e9, 690e9),
        "llama3_405b": (395e9, 415e9),
        "jamba_1p5_large_398b": (380e9, 410e9),
        "deepseek_67b": (64e9, 70e9),
        "nemotron_4_15b": (14e9, 17e9),
    }
    for arch, (lo, hi) in bands.items():
        n = configs.get_config(arch).param_count()
        assert lo <= n <= hi, (arch, n)
