"""Hardened detection (Agent state machine) + ElasticController policy."""
import numpy as np
import pytest

from repro.core.agent import Agent, HealthState, Probe
from repro.core.controller import ElasticController
from repro.core.events import EventKind


def probes(step, alive, times=None, mem=None, n=4):
    times = times or {}
    mem = mem or {}
    return [Probe(step, r, heartbeat=(r in alive),
                  step_seconds=times.get(r, 0.1),
                  mem_used=mem.get(r, 0.0))
            for r in range(n)]


class TestSuspicionStateMachine:
    def test_healthy_suspect_confirmed(self):
        ag = Agent(num_ranks=4, miss_limit=2)
        assert ag.state_of(3) is HealthState.HEALTHY
        evs = ag.observe(probes(0, alive={0, 1, 2}))
        assert evs == [] and ag.state_of(3) is HealthState.SUSPECT
        evs = ag.observe(probes(1, alive={0, 1, 2}))
        assert [e.kind for e in evs] == [EventKind.FAIL_STOP]
        assert evs[0].ranks == (3,)
        assert ag.state_of(3) is HealthState.CONFIRMED
        assert 3 in ag.reported_dead

    def test_confirmed_reported_once(self):
        ag = Agent(num_ranks=2, miss_limit=1)
        assert len(ag.observe(probes(0, alive={0}, n=2))) == 1
        assert ag.observe(probes(1, alive={0}, n=2)) == []

    def test_heartbeat_resets_suspicion(self):
        ag = Agent(num_ranks=2, miss_limit=3)
        ag.observe(probes(0, alive={0}, n=2))
        ag.observe(probes(1, alive={0}, n=2))
        assert ag.health[1].consecutive_misses == 2
        ag.observe(probes(2, alive={0, 1}, n=2))
        assert ag.state_of(1) is HealthState.HEALTHY
        assert ag.health[1].consecutive_misses == 0

    def test_flap_backoff_doubles_threshold(self):
        ag = Agent(num_ranks=2, miss_limit=2, backoff_cap=3)
        assert ag.confirm_needed(1) == 2
        ag.observe(probes(0, alive={0}, n=2))          # miss -> SUSPECT
        ag.observe(probes(1, alive={0, 1}, n=2))       # beat while SUSPECT
        assert ag.health[1].flaps == 1
        assert ag.confirm_needed(1) == 4               # doubled
        # a flapping rank now needs 4 consecutive misses, not 2
        for s in range(3):
            assert ag.observe(probes(2 + s, alive={0}, n=2)) == []
        evs = ag.observe(probes(5, alive={0}, n=2))
        assert [e.kind for e in evs] == [EventKind.FAIL_STOP]

    def test_backoff_is_capped(self):
        ag = Agent(num_ranks=2, miss_limit=2, backoff_cap=2)
        for s in range(10):                            # 10 flap cycles
            ag.observe(probes(2 * s, alive={0}, n=2))
            ag.observe(probes(2 * s + 1, alive={0, 1}, n=2))
        assert ag.confirm_needed(1) == 2 * 2 ** 2
        assert ag.max_confirm_misses() == 8

    def test_duplicate_and_reordered_probes_harmless(self):
        """Any surviving heartbeat copy counts as life, regardless of order."""
        ag = Agent(num_ranks=2, miss_limit=1)
        ps = probes(0, alive={0, 1}, n=2)
        dead_dup = Probe(0, 1, heartbeat=False, step_seconds=0.1)
        assert ag.observe([dead_dup] + ps + [dead_dup]) == []
        assert ag.state_of(1) is HealthState.HEALTHY


class TestStagePeerFailSlow:
    def test_slow_vs_stage_peers_only(self):
        """Stage 1 is legitimately 2x slower than stage 0: no false positive;
        a genuine straggler within stage 0 still fires."""
        stage_of = {0: 0, 1: 0, 2: 0, 3: 1, 4: 1, 5: 1}
        ag = Agent(num_ranks=6, window=4, slow_threshold=1.3,
                   stage_of=stage_of)
        t = {0: 0.1, 1: 0.1, 2: 0.1, 3: 0.2, 4: 0.2, 5: 0.2}
        for s in range(4):
            evs = ag.observe(probes(s, alive=set(range(6)), times=t, n=6))
        assert evs == []                    # inter-stage skew tolerated
        t[2] = 0.3                          # 3x its stage-0 peers
        for s in range(4, 9):
            evs = ag.observe(probes(s, alive=set(range(6)), times=t, n=6))
            if evs:
                break
        assert [e.kind for e in evs] == [EventKind.FAIL_SLOW]
        assert evs[0].ranks == (2,)
        # fires as soon as the rolling median crosses the threshold (the
        # window still mixes pre-degradation samples, so factor < full 3x)
        assert evs[0].slow_factor > 1.3

    def test_clear_slow_rearms_detection(self):
        """DVFS round-trip: detect, absorb (clear_slow), re-detect."""
        ag = Agent(num_ranks=4, window=4, slow_threshold=1.3)
        t = {r: 0.1 for r in range(4)}
        t[1] = 0.2
        fired = []
        for s in range(8):
            fired += ag.observe(probes(s, alive=set(range(4)), times=t))
        assert len(fired) == 1 and fired[0].ranks == (1,)
        ag.clear_slow(1)                    # executor absorbed via DVFS
        fired2 = []
        for s in range(8, 16):
            fired2 += ag.observe(probes(s, alive=set(range(4)), times=t))
        assert len(fired2) == 1 and fired2[0].kind == EventKind.FAIL_SLOW


class TestOomEarlyWarning:
    def test_trend_projection_fires_before_limit(self):
        ag = Agent(num_ranks=2, mem_cap=1.0, mem_threshold=0.9,
                   mem_horizon=3)
        evs = []
        for s, frac in enumerate((0.5, 0.6, 0.7, 0.8)):
            evs += ag.observe(probes(s, alive={0, 1}, mem={1: frac}, n=2))
        oom = [e for e in evs if e.kind == EventKind.OOM_RISK]
        # 0.8 + 0.1/obs * 3 obs = 1.1 >= 0.9: warned while only at 80%
        assert len(oom) == 1 and oom[0].ranks == (1,)

    def test_rearmed_after_pressure_recedes(self):
        ag = Agent(num_ranks=2, mem_cap=1.0, mem_threshold=0.9,
                   mem_horizon=3, window=4)
        ramp = (0.5, 0.7, 0.9, 0.4, 0.3, 0.3, 0.3, 0.5, 0.7, 0.9)
        kinds = []
        for s, frac in enumerate(ramp):
            kinds += [e.kind for e in
                      ag.observe(probes(s, alive={0, 1}, mem={1: frac}, n=2))]
        # fired on the first ramp, re-armed by the dip, fired on the second
        assert kinds.count(EventKind.OOM_RISK) == 2

    def test_flat_high_usage_no_spam(self):
        ag = Agent(num_ranks=2, mem_cap=1.0, mem_threshold=0.9)
        n_oom = 0
        for s in range(6):
            n_oom += sum(e.kind == EventKind.OOM_RISK for e in
                         ag.observe(probes(s, alive={0, 1}, mem={1: 0.95},
                                           n=2)))
        assert n_oom == 1                   # advisory once, not every round


class TestElasticController:
    def _mk(self, n=4, pp=2, **kw):
        ag = Agent(n, miss_limit=2, stage_of={r: r % pp for r in range(n)})
        return ag, ElasticController(ag, **kw)

    def test_forwards_confirmed_evictions(self):
        ag, ctl = self._mk()
        evs = []
        for s in range(ag.max_confirm_misses()):
            evs += ctl.observe(probes(s, alive={0, 1, 2}))
        assert [e.kind for e in evs] == [EventKind.FAIL_STOP]

    def test_vetoes_last_rank_of_stage(self):
        """Stage 1's only registered rank can never be confirm-evicted."""
        ag, ctl = self._mk(n=4, pp=2)
        ag.remove_rank(1)                   # stage 1 now only has rank 3
        evs = []
        for s in range(4 * ag.max_confirm_misses()):
            evs += ctl.observe(probes(s, alive={0, 2}))
        assert evs == []
        assert ag.state_of(3) is HealthState.SUSPECT    # rolled back
        assert 3 not in ag.reported_dead

    def test_vetoed_eviction_proceeds_once_peer_joins(self):
        ag, ctl = self._mk(n=4, pp=2)
        ag.remove_rank(1)
        for s in range(3):
            assert ctl.observe(probes(s, alive={0, 2})) == []
        ag.add_rank(1, stage=1)             # replacement capacity arrives
        ctl.note_join(1)
        evs = []
        for s in range(3, 3 + ag.max_confirm_misses()):
            evs += ctl.observe(probes(s, alive={0, 1, 2}))
        dead = [e for e in evs if e.kind == EventKind.FAIL_STOP]
        assert len(dead) == 1 and dead[0].ranks == (3,)

    def test_resurrection_after_false_positive(self):
        ag, ctl = self._mk()
        evs = []
        for s in range(ag.max_confirm_misses()):
            evs += ctl.observe(probes(s, alive={0, 1, 2}))
        assert [e.kind for e in evs] == [EventKind.FAIL_STOP]
        ag.remove_rank(3)                   # executor applies the eviction
        # the "dead" rank heartbeats again: controller asks for a rejoin
        evs = ctl.observe(probes(10, alive={0, 1, 2, 3}))
        assert [e.kind for e in evs] == [EventKind.SCALE_OUT]
        assert evs[0].ranks == (3,)
        ag.add_rank(3, stage=1)
        ctl.note_join(3)
        # ...and a LATER real failure of the same rank is still re-detected
        evs = []
        for s in range(11, 11 + ag.max_confirm_misses()):
            evs += ctl.observe(probes(s, alive={0, 1, 2}))
        assert [e.kind for e in evs] == [EventKind.FAIL_STOP]
        assert evs[0].ranks == (3,)

    def test_resurrection_window_expires(self):
        ag, ctl = self._mk(resurrection_window=2)
        for s in range(ag.max_confirm_misses()):
            ctl.observe(probes(s, alive={0, 1, 2}))
        ag.remove_rank(3)
        for s in range(5):                  # let the window lapse
            ctl.observe(probes(s, alive={0, 1, 2}, n=3))
        assert ctl.observe(probes(9, alive={0, 1, 2, 3})) == []

    def test_stuck_grant_recovered(self):
        ag, ctl = self._mk(grant_timeout=3)
        ctl.grant(7, "spot capacity")
        assert [g.rank for g in ctl.pending_grants()] == [7]
        for s in range(3):
            ctl.observe(probes(s, alive={0, 1, 2, 3}))
        assert ctl.pending_grants() == []
        assert [g.rank for g in ctl.stuck_grants()] == [7]

    def test_joined_grant_not_stuck(self):
        ag, ctl = self._mk(grant_timeout=3)
        ctl.grant(7)
        ctl.observe(probes(0, alive={0, 1, 2, 3}))
        ag.add_rank(7, stage=1)
        ctl.note_join(7)
        for s in range(1, 6):
            ctl.observe(probes(s, alive={0, 1, 2, 3}))
        assert ctl.stuck_grants() == []
