"""Deliverable guards: the 80-cell dry-run artifact set is complete and
internally consistent; the HLO collective parser handles the grammar."""
import json
from pathlib import Path

import pytest

from repro import configs
from repro.launch import hlo_analysis as H

ART = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


@pytest.mark.skipif(not ART.exists(), reason="dry-run not yet executed")
class TestArtifacts:
    def _load(self):
        return {tuple(f.stem.split("__")): json.loads(f.read_text())
                for f in ART.glob("*.json")}

    def test_all_80_cells_present(self):
        arts = self._load()
        missing = []
        for arch in configs.ARCH_IDS:
            cfg = configs.get_config(arch)
            for (shape, _, _) in cfg.shapes:
                for mesh in ("single", "multi"):
                    if (arch, shape, mesh) not in arts:
                        missing.append((arch, shape, mesh))
        assert not missing, missing

    def test_skips_match_configs(self):
        arts = self._load()
        for arch in configs.ARCH_IDS:
            cfg = configs.get_config(arch)
            skip_names = {n for n, _ in cfg.skip_shapes}
            for (shape, _, _) in cfg.shapes:
                for mesh in ("single", "multi"):
                    a = arts[(arch, shape, mesh)]
                    if shape in skip_names:
                        assert a["status"] == "skipped", (arch, shape)
                    else:
                        assert a["status"] == "ok", (arch, shape, mesh)

    def test_ok_cells_have_roofline_terms(self):
        for a in self._load().values():
            if a["status"] != "ok":
                continue
            r = a["roofline"]
            assert r["compute_s"] > 0 and r["memory_s"] > 0
            assert r["bottleneck"] in ("compute", "memory", "collective")
            assert a["chips"] == (512 if a["mesh"] == "multi" else 256)

    def test_multi_pod_shards_state(self):
        """pod axis actually shards: argument bytes/chip shrink vs single."""
        arts = self._load()
        for arch in ("llama3_405b", "deepseek_v3_671b", "deepseek_67b"):
            s = arts[(arch, "train_4k", "single")]["memory_analysis"]
            m = arts[(arch, "train_4k", "multi")]["memory_analysis"]
            assert m["argument_size_in_bytes"] < 0.75 * s["argument_size_in_bytes"]


class TestHloParser:
    def test_parses_kinds_and_groups(self):
        hlo = """
  %ar = f32[128,256]{1,0} all-reduce(%x), replica_groups=[16,16]<=[256]
  %ag = bf16[512,64]{1,0} all-gather(%y), replica_groups=[16,16]<=[256], dimensions={0}
  %rs = f32[32,64]{1,0} reduce-scatter(%z), replica_groups={{0,1,2,3}}
  %cp = bf16[8,8]{1,0} collective-permute(%w), source_target_pairs={{0,1}}
"""
        out = H.collective_bytes(hlo)
        assert out["all-reduce"] == 128 * 256 * 4
        assert out["all-gather"] == 512 * 64 * 2 / 16      # operand = result/G
        assert out["reduce-scatter"] == 32 * 64 * 4 * 4    # operand = result*G
        assert out["collective-permute"] == 8 * 8 * 2
        assert out["total"] == sum(out[k] for k in H.COLLECTIVES)

    def test_async_pairs_counted_once(self):
        hlo = """
  %s = f32[64,64]{1,0} all-reduce-start(%x), replica_groups={{0,1}}
  %d = f32[64,64]{1,0} all-reduce-done(%s)
"""
        out = H.collective_bytes(hlo)
        assert out["all-reduce"] == 64 * 64 * 4

    def test_roofline_terms_bottleneck(self):
        t = H.roofline_terms(flops=197e12, bytes_accessed=819e9 * 2,
                             coll_bytes=50e9, chips=1)
        assert t["bottleneck"] == "memory"
        assert abs(t["memory_s"] - 2.0) < 1e-9
        assert abs(t["compute_s"] - 1.0) < 1e-9
