"""Flat-state backbone: vectorized interval tables vs the seed zero.Layout.

The memoized ``statespace.IntervalTable`` must reproduce the pure-Python
``zero.Layout`` reference exactly — ``owner_intervals`` / ``layer_interval``
for both layout kinds across dp × layer-size grids, including the last-rank
remainder — and its gather/scatter/view algebra must be a faithful
permutation of the stage state space.
"""
import numpy as np
import pytest

from repro.core import zero
from repro.core.statespace import (COMPONENTS, IntervalTable, StageState,
                                   get_table)

# dp × layer-size grids; several entries force last-rank remainders
# (sizes not divisible by dp) for both kinds
SIZE_GRIDS = [
    (7,),                    # single layer, remainder for every dp > 1
    (8, 16, 24),             # divisible by 2/4/8
    (5, 5, 5),               # odd sizes
    (97, 64),                # prime-ish + power of two
    (10,),
    (33, 1, 129, 12),        # includes a tiny layer smaller than dp
]
DPS = [1, 2, 3, 4, 5, 8]
KINDS = ["contiguous", "interleaved"]


@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("dp", DPS)
@pytest.mark.parametrize("sizes", SIZE_GRIDS)
class TestLayoutEquivalence:
    def test_owner_intervals_match_seed(self, kind, dp, sizes):
        lay = zero.Layout(kind, tuple(sizes), dp)
        tbl = get_table(kind, sizes, dp)
        for j in range(dp):
            assert tbl.owner_intervals(j) == lay.owner_intervals(j), (j,)

    def test_layer_interval_matches_seed(self, kind, dp, sizes):
        lay = zero.Layout(kind, tuple(sizes), dp)
        tbl = get_table(kind, sizes, dp)
        for pos in range(len(sizes)):
            assert tbl.layer_interval(pos) == lay.layer_interval(pos)

    def test_last_rank_remainder(self, kind, dp, sizes):
        """The last rank absorbs the remainder: total coverage is exact."""
        tbl = get_table(kind, sizes, dp)
        covered = sorted(iv for j in range(dp)
                         for iv in tbl.owner_intervals(j))
        cur = 0
        for s, e in covered:
            assert s == cur
            cur = e
        assert cur == tbl.total == sum(sizes)
        assert int(tbl.shard_sizes.sum()) == tbl.total

    def test_gather_scatter_roundtrip(self, kind, dp, sizes):
        tbl = get_table(kind, sizes, dp)
        rng = np.random.default_rng(hash((kind, dp, sizes)) % 2**32)
        full = rng.normal(size=tbl.total).astype(np.float32)
        flat = tbl.gather(full)
        # shard-order semantics: rank j's view == its interval concatenation
        for j in range(dp):
            expect = np.concatenate(
                [full[s:e] for s, e in tbl.owner_intervals(j)]) \
                if tbl.total else np.zeros(0, np.float32)
            np.testing.assert_array_equal(tbl.shard_view(flat, j), expect)
        np.testing.assert_array_equal(tbl.scatter(flat), full)

    def test_scatter_shard_matches_full_scatter(self, kind, dp, sizes):
        tbl = get_table(kind, sizes, dp)
        rng = np.random.default_rng(0)
        full = rng.normal(size=tbl.total).astype(np.float32)
        flat = tbl.gather(full)
        out = np.zeros(tbl.total, np.float32)
        for j in range(dp):
            tbl.scatter_shard(j, tbl.shard_view(flat, j), out)
        np.testing.assert_array_equal(out, full)

    def test_segments_cover_shard(self, kind, dp, sizes):
        tbl = get_table(kind, sizes, dp)
        full = np.arange(tbl.total, dtype=np.float32)
        flat = tbl.gather(full)
        for j in range(dp):
            segs = tbl.segments(j, tbl.shard_view(flat, j))
            assert sorted(segs) == sorted(
                (s, e) for s, e in tbl.owner_intervals(j))
            for (s, e), arr in segs.items():
                np.testing.assert_array_equal(arr, full[s:e])


class TestMemoization:
    def test_get_table_memoized(self):
        a = get_table("interleaved", (40, 80), 4)
        b = get_table("interleaved", [40, 80], 4)
        assert a is b

    def test_layout_table_delegates(self):
        lay = zero.Layout("contiguous", (96, 32), 3)
        tbl = lay.table()
        assert tbl is get_table("contiguous", (96, 32), 3)
        for j in range(3):
            assert tbl.owner_intervals(j) == lay.owner_intervals(j)

    def test_owner_intervals_returns_fresh_list(self):
        """Callers may mutate the returned list without corrupting the cache."""
        tbl = IntervalTable("interleaved", (64, 64), 2)
        ivs = tbl.owner_intervals(0)
        ivs.append((999, 1000))
        assert tbl.owner_intervals(0) != ivs


class TestStageState:
    def _mk(self, kind="interleaved", dp=3):
        sizes = [48, 30, 66]
        rng = np.random.default_rng(1)
        full = {c: rng.normal(size=sum(sizes)).astype(np.float32)
                for c in COMPONENTS}
        st = StageState.from_full([0, 1, 2], sizes, kind,
                                  list(range(dp)), full)
        return st, full

    @pytest.mark.parametrize("kind", KINDS)
    def test_full_roundtrip(self, kind):
        st, full = self._mk(kind)
        for c in COMPONENTS:
            np.testing.assert_array_equal(st.full(c), full[c])

    def test_shards_are_views(self):
        st, _ = self._mk()
        sh = st.shards
        sh[1]["master"][:] = 7.0
        assert (st.shard(1)["master"] == 7.0).all()
        # and the flat buffer itself changed
        tbl = st.table
        np.testing.assert_array_equal(
            tbl.shard_view(st.flat["master"], 1), st.shard(1)["master"])

    def test_write_shard(self):
        st, _ = self._mk()
        new = {c: np.full_like(st.shard(2)[c], 3.5) for c in COMPONENTS}
        st.write_shard(2, new)
        for c in COMPONENTS:
            np.testing.assert_array_equal(st.shard(2)[c], new[c])

    def test_replace_shards_widens(self):
        st, full = self._mk(dp=2)
        wide = get_table(st.layout_kind, st.sizes, 3)
        shards = {r: {c: np.concatenate(
            [full[c][s:e] for s, e in wide.owner_intervals(j)])
            for c in COMPONENTS} for j, r in enumerate([0, 1, 5])}
        st.replace_shards([0, 1, 5], shards)
        assert st.dp_ranks == [0, 1, 5]
        for c in COMPONENTS:
            np.testing.assert_array_equal(st.full(c), full[c])
