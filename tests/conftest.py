import os

# Tests run on the single real CPU device (the dry-run alone forces 512
# placeholder devices, inside its own subprocess).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
