"""Model-level invariants + the §Perf alternative paths (chunked attention,
SP activation constraint, remat) stay numerically identical."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import registry as R
from repro.models import transformer as T
from repro.models.layers import _sdpa, _sdpa_chunked


class TestChunkedAttention:
    @pytest.mark.parametrize("S,cq,ckv", [(96, 32, 48), (200, 64, 64),
                                          (128, 512, 1024)])
    @pytest.mark.parametrize("causal", [True, False])
    def test_matches_full(self, S, cq, ckv, causal):
        key = jax.random.key(0)
        q = jax.random.normal(jax.random.fold_in(key, 1), (2, S, 4, 32))
        k = jax.random.normal(jax.random.fold_in(key, 2), (2, S, 2, 32))
        v = jax.random.normal(jax.random.fold_in(key, 3), (2, S, 2, 32))
        a = _sdpa(q, k, v, causal=causal)
        b = _sdpa_chunked(q, k, v, causal=causal, chunk_q=cq, chunk_kv=ckv)
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)

    def test_model_loss_identical(self):
        cfg = R.tiny_config("dense")
        cfg_c = dataclasses.replace(cfg, attn_chunked=True, attn_chunk_q=8,
                                    attn_chunk_kv=8)
        params = R.init_model(jax.random.key(0), cfg)
        toks = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab_size)
        batch = {"tokens": toks, "labels": toks}
        l0 = R.make_train_loss(cfg)(params, batch)
        l1 = R.make_train_loss(cfg_c)(params, batch)
        np.testing.assert_allclose(float(l0), float(l1), rtol=1e-5)

    def test_grads_match(self):
        cfg = R.tiny_config("dense", num_layers=2)
        cfg_c = dataclasses.replace(cfg, attn_chunked=True, attn_chunk_q=8,
                                    attn_chunk_kv=8)
        params = R.init_model(jax.random.key(0), cfg)
        toks = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab_size)
        batch = {"tokens": toks, "labels": toks}
        g0 = jax.grad(R.make_train_loss(cfg))(params, batch)
        g1 = jax.grad(R.make_train_loss(cfg_c))(params, batch)
        for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
            np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-5)


class TestChunkedPrefill:
    @pytest.mark.parametrize("mla", [False, True])
    def test_prefill_into_cache_matches_plain(self, mla):
        kw = dict(use_mla=True, q_lora_rank=32, kv_lora_rank=32,
                  qk_rope_dim=16, qk_nope_dim=16, v_head_dim=24) if mla else {}
        cfg = R.tiny_config("moe", capacity_factor=16.0, **kw) if mla \
            else R.tiny_config("dense")
        cfg_c = dataclasses.replace(cfg, attn_chunked=True, attn_chunk_q=8,
                                    attn_chunk_kv=8)
        params = R.init_model(jax.random.key(0), cfg)
        toks = jax.random.randint(jax.random.key(1), (2, 12), 0, cfg.vocab_size)
        outs = []
        for c in (cfg, cfg_c):
            caches = T.init_caches(c, 2, 16)
            lg, ch = T.prefill(params, c, toks, caches)
            lg2, _ = T.decode_step(params, c, toks[:, :1], ch, 12)
            outs.append((np.asarray(lg), np.asarray(lg2)))
        np.testing.assert_allclose(outs[0][0], outs[1][0], rtol=3e-4, atol=3e-4)
        np.testing.assert_allclose(outs[0][1], outs[1][1], rtol=3e-4, atol=3e-4)


class TestMlaAbsorption:
    def test_absorbed_decode_matches_plain(self):
        cfg = R.tiny_config("moe", use_mla=True, q_lora_rank=32,
                            kv_lora_rank=32, qk_rope_dim=16, qk_nope_dim=16,
                            v_head_dim=24, capacity_factor=16.0)
        cfg_a = dataclasses.replace(cfg, mla_absorb=True)
        params = R.init_model(jax.random.key(0), cfg)
        toks = jax.random.randint(jax.random.key(1), (2, 9), 0, cfg.vocab_size)
        outs = []
        for c in (cfg, cfg_a):
            caches = T.init_caches(c, 2, 16)
            _, caches = T.prefill(params, c, toks[:, :8], caches)
            lg, _ = T.decode_step(params, c, toks[:, 8:9], caches, 8)
            outs.append(np.asarray(lg))
        np.testing.assert_allclose(outs[0], outs[1], rtol=2e-4, atol=2e-4)


class TestRemat:
    def test_remat_same_loss(self):
        cfg = R.tiny_config("dense")
        params = R.init_model(jax.random.key(0), cfg)
        toks = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab_size)
        batch = {"tokens": toks, "labels": toks}
        l0 = R.make_train_loss(cfg, remat=False)(params, batch)
        l1 = R.make_train_loss(cfg, remat=True)(params, batch)
        np.testing.assert_allclose(float(l0), float(l1), rtol=1e-6)


class TestScanVsUnrolled:
    @pytest.mark.parametrize("family", ["dense", "moe", "ssm", "hybrid"])
    def test_unrolled_matches_scan(self, family):
        import dataclasses as dc
        cfg = R.tiny_config(family)
        cfg_u = dc.replace(cfg, scan_layers=False)
        params = R.init_model(jax.random.key(0), cfg)
        toks = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab_size)
        batch = {"tokens": toks, "labels": toks}
        l0 = R.make_train_loss(cfg)(params, batch)
        l1 = R.make_train_loss(cfg_u)(params, batch)
        np.testing.assert_allclose(float(l0), float(l1), rtol=1e-6)


class TestDecodeConsistency:
    @pytest.mark.parametrize("family", ["dense", "moe", "ssm", "hybrid"])
    def test_decode_matches_forward(self, family):
        # MoE: capacity-based dispatch drops depend on the token population,
        # so decode==forward holds only without drops -> generous capacity.
        cfg = R.tiny_config(family, capacity_factor=16.0) \
            if family in ("moe", "hybrid") else R.tiny_config(family)
        params = R.init_model(jax.random.key(0), cfg)
        toks = jax.random.randint(jax.random.key(1), (2, 9), 0, cfg.vocab_size)
        full_logits, _, _ = T.forward(params, cfg, toks)
        caches = T.init_caches(cfg, 2, 16)
        _, caches = T.prefill(params, cfg, toks[:, :8], caches)
        lg, _ = T.decode_step(params, cfg, toks[:, 8:9], caches, 8)
        np.testing.assert_allclose(np.asarray(full_logits[:, 8]),
                                   np.asarray(lg[:, 0]), rtol=5e-4, atol=5e-4)


class TestDropoutContentAddressing:
    def test_mask_invariant_to_batch_position(self):
        """The ElasWave RNG guarantee at layer level: a sample's dropout mask
        depends on its id, not its slot or rank."""
        from repro.models.layers import RngCtx, dropout
        key = jax.random.key(3)
        x = jnp.ones((4, 8, 16))
        ctx1 = RngCtx(step_key=key, sample_ids=jnp.array([7, 3, 9, 1]),
                      deterministic=False)
        ctx2 = RngCtx(step_key=key, sample_ids=jnp.array([1, 9, 3, 7]),
                      deterministic=False)
        y1 = dropout(x, 0.5, ctx1)
        y2 = dropout(x, 0.5, ctx2)
        np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2[::-1]))


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        from repro.checkpoint import CheckpointManager
        cfg = R.tiny_config("dense", num_layers=2)
        params = R.init_model(jax.random.key(0), cfg)
        cm = CheckpointManager(str(tmp_path), keep=2)
        cm.save(3, params)
        cm.save(7, params, blocking=False)
        cm.wait()
        step, flats, _ = cm.restore()
        assert step == 7
        rebuilt = cm.restore_into(params, flats["params"])
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(rebuilt)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_corruption_detected(self, tmp_path):
        from repro.checkpoint import CheckpointManager
        cfg = R.tiny_config("dense", num_layers=1)
        params = R.init_model(jax.random.key(0), cfg)
        cm = CheckpointManager(str(tmp_path))
        cm.save(1, params)
        f = next(tmp_path.glob("step_*/params.npz"))
        data = bytearray(f.read_bytes())
        data[100] ^= 0xFF
        f.write_bytes(bytes(data))
        with pytest.raises(IOError):
            cm.restore()
