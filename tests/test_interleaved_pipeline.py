"""Interleaved-1F1B virtual-stage schedule."""
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # container lacks hypothesis -> deterministic stub
    from _hypothesis_stub import given, settings, strategies as st

from repro.core.pipeline import (StageTiming, simulate_1f1b,
                                 simulate_interleaved_1f1b)


class TestInterleaved:
    def test_bubble_shrinks_with_v(self):
        st_ = [StageTiming(1.0, 2.0, 8)] * 8
        base = simulate_1f1b(st_)
        prev = base.step_time
        for v in (2, 4):
            r = simulate_interleaved_1f1b(st_, v=v)
            assert r.step_time < prev
            prev = r.step_time

    def test_matches_theory(self):
        """bubble fraction ~ (P-1)/(vM + P-1) for balanced interleaving."""
        P, M, v = 4, 8, 2
        st_ = [StageTiming(1.0, 2.0, M)] * P
        r = simulate_interleaved_1f1b(st_, v=v)
        work = M * 3.0
        theory = work * (1 + (P - 1) / (v * M))
        assert abs(r.step_time - theory) / theory < 0.05

    def test_busy_work_conserved(self):
        st_ = [StageTiming(1.0, 2.0, 8)] * 4
        base = simulate_1f1b(st_)
        inter = simulate_interleaved_1f1b(st_, v=2)
        assert abs(sum(base.stage_busy) - sum(inter.stage_busy)) < 1e-9

    @given(st.integers(2, 6), st.integers(2, 12), st.integers(2, 3))
    @settings(max_examples=30, deadline=None)
    def test_never_slower_than_plain(self, P, M, v):
        st_ = [StageTiming(1.0, 2.0, M)] * P
        base = simulate_1f1b(st_)
        inter = simulate_interleaved_1f1b(st_, v=v)
        assert inter.step_time <= base.step_time + 1e-9
