"""Data pipeline: the elastic invariant — sample content is addressed by
global id, independent of partitioning."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # container lacks hypothesis -> deterministic stub
    from _hypothesis_stub import given, settings, strategies as st

from repro.data.pipeline import (GlobalBatchSampler, make_batch,
                                 materialize_samples)


class TestDeterminism:
    def test_same_id_same_tokens(self):
        a = materialize_samples(np.array([5, 9]), 32, 1000)
        b = materialize_samples(np.array([9, 5]), 32, 1000)
        np.testing.assert_array_equal(a[0], b[1])
        np.testing.assert_array_equal(a[1], b[0])

    def test_tokens_in_vocab(self):
        t = materialize_samples(np.arange(100), 64, 517)
        assert t.min() >= 0 and t.max() < 517


class TestPartition:
    @given(st.integers(1, 6), st.integers(1, 4), st.integers(1, 8))
    @settings(max_examples=60, deadline=None)
    def test_partition_covers_global_batch(self, dp, num_micro, per_rank):
        gb = dp * per_rank * num_micro
        s = GlobalBatchSampler(gb)
        parts = s.partition(3, [per_rank] * dp, num_micro)
        got = np.sort(np.concatenate([ids for r in parts for ids in r]))
        np.testing.assert_array_equal(got, s.sample_ids(3))

    def test_elastic_reslice_same_samples(self):
        """DP=4 and DP=3 (resized) cover the SAME global sample set."""
        s = GlobalBatchSampler(24)
        p4 = s.partition(7, [6, 6, 6, 6], 1)
        p3 = s.partition(7, [8, 8, 8], 1)
        ids4 = np.sort(np.concatenate([ids for r in p4 for ids in r]))
        ids3 = np.sort(np.concatenate([ids for r in p3 for ids in r]))
        np.testing.assert_array_equal(ids4, ids3)

    def test_uneven_sizes(self):
        s = GlobalBatchSampler(10)
        p = s.partition(0, [4, 3, 3], 1)
        assert [len(p[r][0]) for r in range(3)] == [4, 3, 3]


def test_make_batch_shapes():
    b = make_batch(np.arange(4), 16, 100)
    assert b["tokens"].shape == (4, 16)
    assert b["sample_ids"].shape == (4,)
