"""Sharding rules + a reduced-mesh lowering test (the in-process twin of the
512-device dry-run, kept cheap for CI: 8 placeholder devices via subprocess).
"""
import json
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.models import registry as R
from repro.parallel import sharding as S


class FakeMesh:
    """Just enough of a Mesh for the rule functions."""
    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


class TestFit:
    def test_divisible(self):
        m = FakeMesh({"data": 16, "model": 16})
        assert S._fit(m, 64, "model") == "model"
        assert S._fit(m, 63, "model") is None

    def test_suffix_fallback(self):
        m = FakeMesh({"pod": 2, "data": 16, "model": 16})
        # 16 divides by ("data",) but not ("pod","data")=32
        assert S._fit(m, 16, ("pod", "data")) == "data"
        assert S._fit(m, 64, ("pod", "data")) == ("pod", "data")

    def test_odd_vocab_unsharded(self):
        m = FakeMesh({"data": 16, "model": 16})
        # whisper vocab 51865 is odd -> cannot shard on 16
        assert S._fit(m, 51865, "model") is None


class TestParamSpecs:
    def test_rules_cover_all_leaves(self):
        m = FakeMesh({"data": 16, "model": 16})
        for fam in ("dense", "moe", "ssm", "hybrid"):
            cfg = R.tiny_config(fam)
            shapes = R.model_param_shapes(cfg)
            specs = S.param_pspecs(cfg, m, shapes)
            # same tree structure, all PartitionSpec
            leaves = jax.tree.leaves(specs,
                                     is_leaf=lambda x: isinstance(x, P))
            assert all(isinstance(s, P) for s in leaves)
            n_shapes = len(jax.tree.leaves(shapes))
            assert len(leaves) == n_shapes

    def test_no_duplicate_axis_in_spec(self):
        m = FakeMesh({"pod": 2, "data": 16, "model": 16})
        for fam in ("dense", "moe", "hybrid"):
            cfg = R.tiny_config(fam)
            shapes = R.model_param_shapes(cfg)
            specs = S.param_pspecs(cfg, m, shapes)
            for s in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)):
                used = []
                for entry in s:
                    if entry is None:
                        continue
                    names = (entry,) if isinstance(entry, str) else entry
                    used.extend(names)
                assert len(used) == len(set(used)), s


LOWER_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
from repro.launch.mesh import make_mesh
from repro.launch.steps import build_cell
from repro.parallel.sharding import to_shardings
from repro.models import registry as R

cfg = R.tiny_config("{family}")
mesh = make_mesh((2, 4), ("data", "model"))
cell = build_cell(cfg, "{shape}", seq={seq}, batch=4, mesh=mesh, remat=False)
in_sh = tuple(to_shardings(mesh, p) for p in cell.arg_pspecs)
out_sh = to_shardings(mesh, cell.out_pspecs)
with mesh:
    lowered = jax.jit(cell.fn, in_shardings=in_sh, out_shardings=out_sh,
                      donate_argnums=cell.donate).lower(*cell.arg_shapes)
    compiled = lowered.compile()
print(json.dumps({{"ok": True, "flops": compiled.cost_analysis()["flops"]}}))
"""


@pytest.mark.skipif(not hasattr(jax.sharding, "AxisType"),
                    reason="reduced-mesh lowering needs jax.sharding.AxisType"
                           " (jax >= 0.5); installed jax is older")
@pytest.mark.parametrize("family,shape,seq", [
    ("dense", "train_4k", 64),
    ("moe", "train_4k", 64),
    ("ssm", "train_4k", 64),
    ("hybrid", "decode_32k", 64),
    ("dense", "prefill_32k", 64),
])
def test_reduced_mesh_lowering(family, shape, seq):
    """lower+compile on an 8-device (2x4) mesh in a subprocess (device count
    must be set before jax init)."""
    script = LOWER_SCRIPT.format(family=family, shape=shape, seq=seq)
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, timeout=600,
                         env={**__import__("os").environ,
                              "PYTHONPATH": "src"},
                         cwd=str(__import__("pathlib").Path(__file__).parents[1]))
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["ok"] and res["flops"] > 0
