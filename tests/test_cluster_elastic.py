"""Integration tests: the VirtualCluster elastic runtime end-to-end.

These are the paper's headline guarantees, verified numerically:
  * computation consistency (§7.5): elastic loss trajectory == fault-free
  * parameter consistency (§5): live remap preserves optimizer state exactly
  * migration completeness (§6.2): layer moves don't change the math
  * dataflow invariant (§4.1): global batch and gradient scale preserved
"""
import numpy as np
import pytest

from repro.core.cluster import VirtualCluster
from repro.models import registry as R

CFG = R.tiny_config("dense", num_layers=8, dropout_rate=0.1)


def mk(dp=4, pp=2, **kw):
    return VirtualCluster(CFG, dp=dp, pp=pp, global_batch=16, num_micro=2,
                          seq_len=16, seed=0, **kw)


@pytest.fixture(scope="module")
def baseline_losses():
    return mk().run(6)


class TestComputationConsistency:
    def test_failfree_deterministic(self, baseline_losses):
        again = mk().run(6)
        np.testing.assert_allclose(baseline_losses, again, rtol=0, atol=0)

    def test_elastic_matches_failfree(self, baseline_losses):
        """Fail (d=1,p=1) after step 3 — trajectory must match the fault-free
        run to fp-reordering tolerance (paper: RNG resharding + exact grad
        weighting)."""
        el = mk()
        losses = el.run(3)
        el.recover_fail_stop(1, 1)
        losses += el.run(3)
        dev = np.abs(np.array(baseline_losses) - np.array(losses))
        assert dev.max() < 5e-5, dev

    def test_naive_rng_diverges(self):
        """Paper §7.5 ablation: without RNG resharding the trajectory drifts
        by orders of magnitude more."""
        base = mk(rng_mode="naive").run(6)
        el = mk(rng_mode="naive")
        losses = el.run(3)
        el.recover_fail_stop(1, 1)
        losses += el.run(3)
        dev_naive = np.abs(np.array(base) - np.array(losses))[3:].max()

        base_r = mk().run(6)
        el2 = mk()
        l2 = el2.run(3)
        el2.recover_fail_stop(1, 1)
        l2 += el2.run(3)
        dev_reshard = np.abs(np.array(base_r) - np.array(l2))[3:].max()
        assert dev_naive > 50 * max(dev_reshard, 1e-9)

    def test_two_failures(self, baseline_losses):
        el = mk()
        losses = el.run(2)
        el.recover_fail_stop(3, 0)
        losses += el.run(2)
        el.recover_fail_stop(0, 1)
        losses += el.run(2)
        dev = np.abs(np.array(baseline_losses) - np.array(losses))
        assert dev.max() < 1e-4, dev


class TestParameterConsistency:
    @pytest.mark.parametrize("layout", ["interleaved", "contiguous"])
    def test_remap_verified(self, layout):
        """_live_remap_stage asserts bit-exact reconstruction internally."""
        el = mk(zero_layout=layout)
        el.run(2)
        rec = el.recover_fail_stop(2, 0)
        assert rec["total"] > 0
        el.run(1)   # training proceeds

    def test_remap_uses_snapshot_for_failed_shard(self):
        el = mk()
        el.run(2)
        el.recover_fail_stop(1, 1)
        # the failed dp rank is out of the stage's DP group; survivors'
        # reassembled state covers the (possibly migrated) stage exactly.
        # (bit-exactness vs pre-failure truth is asserted inside
        # _live_remap_stage before migration reshuffles the stage spaces.)
        st_new = el.stages[1]
        assert 1 not in st_new.dp_ranks
        full = el._stage_full_vec(st_new)
        assert full.size == st_new.total


class TestMigration:
    def test_migration_preserves_params(self):
        el = mk()
        el.run(2)
        from jax.flatten_util import ravel_pytree
        before = [np.asarray(ravel_pytree(p)[0]) for p in el.layer_params]
        moves = [(3, 0, 1)]   # move layer 3 stage0 -> stage1
        new_ranges = [(0, 2), (3, 7)]
        el._apply_migrations(moves, new_ranges)
        after_masters = el._entry_from_stage(3)["master"]
        np.testing.assert_array_equal(after_masters.astype(np.float32),
                                      before[3].astype(np.float32))
        assert el.layer_assignment == [(0, 2), (3, 7)]
        el.run(1)

    def test_blocking_vs_nonblocking_mttr(self):
        el_b = mk(non_blocking_migration=False)
        el_n = mk(non_blocking_migration=True)
        for el in (el_b, el_n):
            el.run(1)
        t_b = el_b._apply_migrations([(3, 0, 1)], [(0, 2), (3, 7)])
        t_n = el_n._apply_migrations([(3, 0, 1)], [(0, 2), (3, 7)])
        assert t_n <= t_b


class TestFailSlow:
    def test_straggler_recovery_improves_time(self):
        # enough micro-batches that the 1F1B steady state dominates (the
        # minimax objective optimizes steady-state mini-step time)
        el = VirtualCluster(CFG, dp=4, pp=2, global_batch=32, num_micro=8,
                            seq_len=16, seed=0)
        el.run(1)
        t_before = el.simulate_step_time()
        el.inject_fail_slow(0, 0, 1.6)
        t_slow = el.simulate_step_time()
        assert t_slow > t_before
        el.recover_fail_slow(0, 0, 1.6)
        t_after = el.simulate_step_time()
        assert t_after < t_slow


class TestOtherFamilies:
    @pytest.mark.parametrize("family", ["moe", "ssm"])
    def test_elastic_consistency(self, family):
        """ElasWave's guarantees hold across model families (MoE routing and
        SSD recurrences included)."""
        cfg = R.tiny_config(family, dropout_rate=0.1) if family != "moe" else \
            R.tiny_config(family, dropout_rate=0.1, capacity_factor=16.0)
        base = VirtualCluster(cfg, dp=4, pp=2, global_batch=16, num_micro=2,
                              seq_len=16, seed=0)
        bl = base.run(4)
        el = VirtualCluster(cfg, dp=4, pp=2, global_batch=16, num_micro=2,
                            seq_len=16, seed=0)
        losses = el.run(2)
        el.recover_fail_stop(1, 0)
        losses += el.run(2)
        dev = np.abs(np.array(bl) - np.array(losses))
        assert dev.max() < 1e-4, dev


class TestScaleOut:
    def test_shrink_then_regrow_trajectory(self, baseline_losses):
        el = mk()
        losses = el.run(2)
        el.recover_fail_stop(1, 1)
        losses += el.run(2)
        el.recover_scale_out(1, 1)
        losses += el.run(2)
        dev = np.abs(np.array(baseline_losses) - np.array(losses))
        assert dev.max() < 1e-4
        # DP width restored
        assert len(el.stages[1].dp_ranks) == 4
        assert el.per_rank_mbs == [2, 2, 2, 2]


class TestAgent:
    def test_detects_fail_stop(self):
        el = mk()
        el.run(1)
        el.inject_fail_stop(2, 1)
        rec = el.detect_and_recover()
        assert rec is not None and rec["total"] > 0
        assert not el.alive[2, 1]
        el.run(1)
