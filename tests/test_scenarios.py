"""Scenario engine: deterministic replay, burst ordering, degenerate traces,
and the shapes of the new scenario library (see docs/ARCHITECTURE.md)."""
import json

import numpy as np
import pytest

from repro.core.events import ElasticEvent, EventKind, burst
from repro.core.policies import ElasWavePolicy
from repro.scenarios import (AnalyticScenarioRunner, AnalyticWorkload,
                             ClusterWorkload, Scenario, get_scenario,
                             node_shrink_cells, run_scenario)
from repro.scenarios.library import SCENARIOS


# ---------------------------------------------------------------------------
# spec-level (no cluster): ordering, builders, degenerate traces
# ---------------------------------------------------------------------------
class TestSpec:
    def test_events_sorted_by_step_ties_keep_order(self):
        e_late = ElasticEvent(EventKind.FAIL_STOP, 5, (1,))
        e_a = ElasticEvent(EventKind.FAIL_SLOW, 2, (0,), slow_factor=1.2)
        e_b = ElasticEvent(EventKind.FAIL_SLOW, 2, (3,), slow_factor=1.4)
        scn = Scenario("s", (e_late, e_a, e_b), horizon=7)
        assert [e.step for e in scn.events] == [2, 2, 5]
        # insertion order preserved within the same step (burst determinism)
        assert scn.events_at(2) == [e_a, e_b]

    def test_burst_ranks_sorted(self):
        ev = burst(EventKind.FAIL_STOP, 1, (7, 2, 5))
        assert ev.ranks == (2, 5, 7)

    def test_event_outside_horizon_rejected(self):
        with pytest.raises(ValueError):
            Scenario("bad", (ElasticEvent(EventKind.FAIL_STOP, 4, (0,)),),
                     horizon=4)

    def test_unknown_scenario_name(self):
        with pytest.raises(KeyError):
            get_scenario("no_such_scenario")

    def test_capacity_trace_emits_delta_events(self):
        trace = [(100, 0), (50, 1), (50, 2), (50, 0)]
        scn = Scenario.from_capacity_trace("cap", trace, dp=4, pp=3)
        assert [e.step for e in scn.events] == [100, 150, 200]
        kinds = [e.kind for e in scn.events]
        assert kinds == [EventKind.SCALE_IN, EventKind.SCALE_IN,
                         EventKind.SCALE_OUT]
        seq = node_shrink_cells(2, 4, 3)
        # first shrink = first node's cells; second = the delta only
        assert scn.events[0].ranks == tuple(d * 3 + p for d, p in seq[:2])
        assert scn.events[1].ranks == tuple(d * 3 + p for d, p in seq[2:4])
        # final regrow rejoins everything that went down
        assert set(scn.events[2].ranks) == {d * 3 + p for d, p in seq[:4]}
        assert scn.horizon == 250

    def test_shrink_cells_monotone_prefix(self):
        full = node_shrink_cells(3, 8, 3)
        for n in (1, 2):
            assert node_shrink_cells(n, 8, 3) == full[:2 * n]


# ---------------------------------------------------------------------------
# cluster mode: determinism, bursts, empty traces
# ---------------------------------------------------------------------------
W = ClusterWorkload(dp=4, pp=2, global_batch=16, num_micro=2)


def small_failstop():
    return Scenario.single("det", EventKind.FAIL_STOP, step=2,
                           ranks=(W.rank(1, 1),), horizon=4)


class TestClusterRunner:
    def test_deterministic_replay(self):
        """Same trace -> identical step records; recovery records identical
        except the measured planner wall time ('plan', folded into 'total'),
        which is the one intentionally non-replayable MTTR component."""
        r1 = run_scenario(small_failstop(), W)
        r2 = run_scenario(small_failstop(), W)
        assert r1.steps == r2.steps
        assert r1.summary["losses"] == r2.summary["losses"]
        assert len(r1.recoveries) == len(r2.recoveries)
        for a, b in zip(r1.recoveries, r2.recoveries):
            ka = {k: v for k, v in a["mttr"].items()
                  if k not in ("plan", "total")}
            kb = {k: v for k, v in b["mttr"].items()
                  if k not in ("plan", "total")}
            assert ka == kb
            assert {k: v for k, v in a.items() if k != "mttr"} == \
                {k: v for k, v in b.items() if k != "mttr"}

    def test_empty_trace_matches_fault_free(self):
        scn = Scenario("empty", (), horizon=3)
        res = run_scenario(scn, W)
        assert res.recoveries == [] and len(res.steps) == 3
        base = W.make_cluster().run(3)
        np.testing.assert_allclose(res.summary["losses"], base, rtol=0, atol=0)

    def test_zero_horizon(self):
        res = run_scenario(Scenario("null", (), horizon=0), W)
        assert res.steps == [] and res.summary["final_loss"] is None

    def test_burst_is_single_record_with_one_detect(self):
        scn, w = get_scenario("concurrent_burst")
        res = run_scenario(scn, w)
        assert len(res.recoveries) == 1
        rec = res.recoveries[0]
        assert rec["ranks"] == sorted(rec["ranks"])
        # detection paid once for the concurrent pair
        assert rec["mttr"]["detect"] == pytest.approx(0.5)
        assert rec["mttr"]["total"] > rec["mttr"]["detect"]
        # both stages lost one replica
        assert res.steps[-1]["dp_width"] == w.dp - 1

    def test_artifact_roundtrip(self, tmp_path):
        res = run_scenario(small_failstop(), W)
        path = res.write(tmp_path)
        data = json.loads(path.read_text())
        assert data["mode"] == "cluster"
        assert len(data["steps"]) == 4 and len(data["recoveries"]) == 1
        assert data["recoveries"][0]["mttr"]["total"] > 0


class TestLibraryShapes:
    def test_shrink_regrow_restores_width(self):
        scn, w = get_scenario("shrink_regrow")
        res = run_scenario(scn, w)
        widths = [s["dp_width"] for s in res.steps]
        assert widths[0] == w.dp and min(widths) == w.dp - 1 \
            and widths[-1] == w.dp
        # rejoin recovery has no detect/plan/migration, only comm + remap
        rejoin = res.recoveries[-1]
        assert rejoin["kind"] == "scale_out"
        assert rejoin["mttr"]["detect"] == 0.0
        assert rejoin["mttr"]["migration"] == 0.0
        assert rejoin["mttr"]["communicator"] > 0.0

    def test_cascading_failslow_dvfs_absorbs(self):
        scn, w = get_scenario("cascading_failslow")
        res = run_scenario(scn, w)
        t = [s["step_time"] for s in res.steps]
        # final (post-DVFS) step time is below the degraded peak
        assert t[-1] < max(t)
        kinds = [r["kind"] for r in res.recoveries]
        assert kinds == ["fail_slow", "fail_slow", "dvfs_set"]

    def test_every_library_entry_is_well_formed(self):
        for name in SCENARIOS:
            scn, w = get_scenario(name)
            assert scn.name == name and scn.horizon > 0
            assert all(e.step < scn.horizon for e in scn.events)
            assert isinstance(w, ClusterWorkload)


# ---------------------------------------------------------------------------
# analytic mode
# ---------------------------------------------------------------------------
def tiny_analytic():
    from repro.core.cost_model import HardwareSpec
    from repro.models.config import ModelConfig
    cfg = ModelConfig(name="tiny-analytic", family="dense", num_layers=12,
                      d_model=512, num_heads=8, num_kv_heads=8,
                      d_ff=2048, vocab_size=4096)
    hw = HardwareSpec()
    return AnalyticWorkload(cfg=cfg, dp=4, pp=3, mbs=2, global_batch=64,
                            seq=128, hw=hw)


class TestAnalyticRunner:
    def test_shrink_reduces_throughput_and_prices_comm(self):
        wl = tiny_analytic()
        scn = Scenario.single("a", EventKind.SCALE_IN, step=0,
                              ranks=(wl.rank(0, 0),), horizon=1)
        res = AnalyticScenarioRunner(scn, wl, ElasWavePolicy(wl.hw)).run()
        assert res.mode == "analytic"
        rec = res.steps[-1]
        assert rec["feasible"] and 0 < rec["rel_throughput"] < 1
        acct = res.recoveries[0]["communicator"]
        assert acct["edit_seconds"] < acct["partial_rebuild_seconds"] \
            < acct["full_rebuild_seconds"]

    def test_deterministic_modulo_wall_time(self):
        wl = tiny_analytic()

        def go():
            scn = Scenario.single("a", EventKind.SCALE_IN, step=0,
                                  ranks=(wl.rank(0, 0),), horizon=1)
            res = AnalyticScenarioRunner(scn, wl, ElasWavePolicy(wl.hw)).run()
            for s in res.steps:
                s.pop("decide_wall_seconds")
            return res

        assert go().to_json() == go().to_json()

    def test_mttr_model_charged_per_capacity_change(self):
        wl = tiny_analytic()
        trace = [(100, 0), (100, 1), (100, 0)]
        scn = Scenario.from_capacity_trace("cap", trace, wl.dp, wl.pp)
        pol = ElasWavePolicy(wl.hw)
        free = AnalyticScenarioRunner(scn, wl, pol).run()
        paid = AnalyticScenarioRunner(scn, wl, pol,
                                      mttr_model={"elaswave": 10.0}).run()
        assert paid.summary["time_avg_rel_throughput"] < \
            free.summary["time_avg_rel_throughput"]
        assert sum(s["mttr_charged"] for s in paid.steps) == \
            pytest.approx(20.0)
