"""Rank-vectorized ClusterView + stage-vector cost model (ISSUE 7 tentpole).

Covers: zero-copy 2-D/flat buffer aliasing, vectorized reductions vs their
per-rank loop definitions, burst application vs per-cell dict surgery,
correlated failure domains, and the ``*_vec`` cost-model entry points
matching the scalar seed path element-for-element.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # container lacks hypothesis -> deterministic stub
    from _hypothesis_stub import given, settings, strategies as st

from repro.core.clusterview import ClusterView, FailureDomainMap, rank_coords
from repro.core.cost_model import (HardwareSpec, SegmentCosts, mini_step_time,
                                   mini_step_time_vec)
from repro.core.events import ElasticEvent, EventKind, burst
from repro.models import registry as R


def _view(dp=4, pp=3, **kw):
    L = 2 * pp
    ranges = [(2 * p, 2 * p + 1) for p in range(pp)]
    return ClusterView(dp, pp, global_batch=2 * dp, num_micro=2, seq=32,
                       layer_assignment=ranges, **kw)


class TestBuffers:
    def test_flat_and_2d_alias(self):
        v = _view()
        v.alive[1, 2] = False
        assert not v.rank_alive[1 * v.pp + 2]
        v.rank_slow[5] = 3.0
        assert v.slow[5 // v.pp, 5 % v.pp] == 3.0

    def test_caller_buffer_aliased(self):
        alive = np.ones((4, 3), dtype=bool)
        v = _view(alive=alive)
        v.rank_alive[0] = False
        assert not alive[0, 0]

    def test_rank_coords(self):
        rd, rs = rank_coords(4, 3)
        for r in range(12):
            assert rd[r] == r // 3 and rs[r] == r % 3
        with pytest.raises(ValueError):
            rd[0] = 5          # memoized tables are read-only

    def test_copy_independent(self):
        v = _view()
        c = v.copy()
        c.rank_alive[0] = False
        assert v.rank_alive[0]


class TestReductions:
    @settings(max_examples=10)
    @given(st.integers(2, 6), st.integers(2, 5), st.integers(0, 10**6))
    def test_reductions_match_loops(self, dp, pp, seed):
        rng = np.random.default_rng(seed)
        v = _view(dp, pp,
                  alive=rng.random((dp, pp)) > 0.3,
                  slow=1.0 + 2.0 * rng.random((dp, pp)),
                  freq=0.8 + 0.4 * rng.random((dp, pp)))
        assert list(v.stage_width()) == \
            [sum(bool(v.alive[d, p]) for d in range(dp)) for p in range(pp)]
        assert list(v.replica_width()) == \
            [sum(bool(v.alive[d, p]) for p in range(pp)) for d in range(dp)]
        assert list(v.stage_slow()) == pytest.approx(
            [max((v.slow[d, p] for d in range(dp) if v.alive[d, p]),
                 default=1.0) for p in range(pp)], abs=0)
        assert list(v.stage_freq()) == pytest.approx(
            [max((v.freq[d, p] for d in range(dp) if v.alive[d, p]),
                 default=1.0) for p in range(pp)], abs=0)
        assert v.alive_count() == int(v.alive.sum())
        assert set(v.dead_ranks().tolist()) == \
            {r for r in range(dp * pp) if not v.rank_alive[r]}

    def test_apply_elastic_matches_cell_surgery(self):
        v1, v2 = _view(), _view()
        events = [
            burst(EventKind.FAIL_SLOW, 0, (1, 4, 7), slow_factor=2.5),
            burst(EventKind.DVFS_SET, 1, (4, 5), freq=1.1),
            burst(EventKind.FAIL_STOP, 2, (0, 3, 6)),
            burst(EventKind.SCALE_OUT, 3, (3,)),
        ]
        for ev in events:
            v1.apply_elastic(ev)
            for r in ev.ranks:       # the seed runner's per-cell surgery
                d, p = r // v2.pp, r % v2.pp
                if ev.kind == EventKind.FAIL_SLOW:
                    v2.slow[d, p] = max(v2.slow[d, p], ev.slow_factor)
                elif ev.kind == EventKind.DVFS_SET:
                    v2.freq[d, p] = ev.freq
                elif ev.is_grow:
                    v2.alive[d, p] = True
                else:
                    v2.alive[d, p] = False
        assert np.array_equal(v1.rank_alive, v2.rank_alive)
        assert np.array_equal(v1.rank_slow, v2.rank_slow)
        assert np.array_equal(v1.rank_freq, v2.rank_freq)


class TestFailureDomains:
    def test_domain_roundtrip(self):
        m = FailureDomainMap(n_ranks=100, domain_size=8)
        assert m.n_domains == 13
        assert list(m.domain_of([0, 7, 8, 99])) == [0, 0, 1, 12]
        assert list(m.ranks_of([12])) == [96, 97, 98, 99]   # clipped tail
        assert list(m.ranks_of([1, 0, 1])) == list(range(16))  # dedup+sort

    def test_sample_deterministic_distinct(self):
        m = FailureDomainMap(n_ranks=10_000, domain_size=16)
        a, b = m.sample(5, seed=3), m.sample(5, seed=3)
        assert np.array_equal(a, b)
        assert len(set(a.tolist())) == 5
        assert len(m.sample(10**9, seed=0)) == m.n_domains  # capped

    def test_workload_carries_domains(self):
        from repro.core.cost_model import HardwareSpec
        from repro.scenarios import AnalyticWorkload
        w = AnalyticWorkload(cfg=R.tiny_config("dense", num_layers=4),
                             dp=8, pp=2, mbs=1, global_batch=16,
                             seq=32, hw=HardwareSpec(), domain_size=4)
        seg = w.build_seg()
        v = w.build_view(seg)
        assert v.domains.n_domains == 4
        assert list(v.rank_domain[:5]) == [0, 0, 0, 0, 1]


class TestVecCostModel:
    def setup_method(self):
        self.hw = HardwareSpec()
        self.seg = SegmentCosts.build(R.tiny_config("dense", num_layers=12),
                                      64, self.hw)

    def test_seg_fwd_flops_vec_bitwise(self):
        segs = [(0, 3), (4, 7), (8, 11), (2, 9)]
        a = np.array([s[0] for s in segs])
        b = np.array([s[1] for s in segs])
        for mbs in (1, 3):
            vec = self.seg.seg_fwd_flops_vec(a, b, mbs)
            for i, (x, y) in enumerate(segs):
                assert vec[i] == self.seg.seg_fwd_flops(x, y, mbs)

    def test_mini_step_time_vec_bitwise(self):
        segs = [(0, 3), (4, 7), (8, 11)]
        a = np.array([s[0] for s in segs])
        b = np.array([s[1] for s in segs])
        mbs = np.array([1, 2, 4])
        freq = np.array([1.0, 1.1, 0.9])
        vec = mini_step_time_vec(self.seg, a, b, mbs, freq=freq, hw=self.hw)
        for i, (x, y) in enumerate(segs):
            assert vec[i] == mini_step_time(self.seg, x, y, int(mbs[i]),
                                            freq=float(freq[i]), hw=self.hw)

    def test_seg_mem_vec_close(self):
        # activation term is count*footprint vs repeated addition -> ULP-level
        a = np.array([0, 4])
        b = np.array([3, 11])
        vec = self.seg.seg_mem_vec(a, b, 2, inflight=3, dp_size=4)
        for i in range(2):
            ref = self.seg.seg_mem(int(a[i]), int(b[i]), 2, 3, 4)
            assert vec[i] == pytest.approx(ref, rel=1e-12)

    def test_pre_memoized(self):
        c1 = self.seg._pre(self.seg.fwd_flops)
        c2 = self.seg._pre(self.seg.fwd_flops)
        assert c1 is c2


class TestPolicyParity:
    """The vectorized policies must reproduce the per-rank-loop decisions."""

    @settings(max_examples=6)
    @given(st.integers(2, 5), st.integers(2, 4), st.integers(0, 10**6))
    def test_decisions_deterministic_under_views(self, dp, pp, seed):
        from repro.core.policies import (ElasWavePolicy, OobleckPolicy,
                                         TorchFTPolicy)
        from repro.scenarios import AnalyticWorkload
        rng = np.random.default_rng(seed)
        hw = HardwareSpec()
        w = AnalyticWorkload(cfg=R.tiny_config("dense", num_layers=2 * pp),
                             dp=dp, pp=pp, mbs=1, global_batch=2 * dp,
                             seq=64, hw=hw)
        seg = w.build_seg()
        alive = rng.random((dp, pp)) > 0.25
        slow = np.where(rng.random((dp, pp)) > 0.7, 2.0, 1.0)
        for pol in (ElasWavePolicy(hw=hw), TorchFTPolicy(),
                    OobleckPolicy(hw=hw)):
            d1 = pol.decide(seg, w.build_view(seg, alive.copy(), slow.copy()))
            d2 = pol.decide(seg, w.build_view(seg, alive.copy(), slow.copy()))
            assert d1.step_time == d2.step_time
            assert d1.feasible == d2.feasible

    def test_oobleck_keeps_partial_replicas(self):
        """A replica that lost one stage is kept via template fallback
        (TorchFT would drop it)."""
        from repro.core.policies import OobleckPolicy, TorchFTPolicy
        from repro.scenarios import AnalyticWorkload
        hw = HardwareSpec()
        w = AnalyticWorkload(cfg=R.tiny_config("dense", num_layers=8),
                             dp=4, pp=4, mbs=1, global_batch=8, seq=64, hw=hw)
        seg = w.build_seg()
        alive = np.ones((4, 4), dtype=bool)
        alive[0, 1] = False                     # replica 0 loses one stage
        ob = OobleckPolicy(hw=hw).decide(seg, w.build_view(seg, alive.copy()))
        tf = TorchFTPolicy().decide(seg, w.build_view(seg, alive.copy()))
        assert ob.feasible and tf.feasible
        assert ob.detail["alive_reps"] == 4     # template keeps replica 0
        assert tf.detail["alive_reps"] == 3
        assert ob.detail["wasted_ranks"] == 0
        assert tf.detail["wasted_ranks"] == 3
        # the damaged replica runs a 3-stage template over all 8 layers
        assert tuple(ob.detail["templates"][3]) and \
            sum(ob.detail["templates"][3]) == 8
