"""1F1B pipeline simulator + cost model sanity."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # container lacks hypothesis -> deterministic stub
    from _hypothesis_stub import given, settings, strategies as st

from repro.core.cost_model import HardwareSpec, SegmentCosts, mini_step_time
from repro.core.pipeline import StageTiming, simulate_1f1b, simulate_dp_pp
from repro.models import registry as R


class TestSimulator:
    def test_balanced_matches_closed_form(self):
        # (M + P - 1) * (f + b) for a balanced 1F1B pipeline
        for P in (2, 4, 8):
            for M in (4, 8, 16):
                r = simulate_1f1b([StageTiming(1.0, 2.0, M)] * P)
                assert abs(r.step_time - (M + P - 1) * 3.0) < 1e-9

    def test_straggler_gates(self):
        base = simulate_1f1b([StageTiming(1.0, 2.0, 8)] * 4).step_time
        slow = simulate_1f1b([StageTiming(1.0, 2.0, 8)] * 3 +
                             [StageTiming(1.5, 3.0, 8)]).step_time
        assert slow > base

    def test_peak_inflight_1f1b(self):
        r = simulate_1f1b([StageTiming(1.0, 2.0, 8)] * 4)
        # stage i holds at most P - i in-flight activations
        assert r.peak_inflight == [4, 3, 2, 1]

    @given(st.lists(st.floats(0.1, 3.0), min_size=2, max_size=6),
           st.integers(2, 12))
    @settings(max_examples=40, deadline=None)
    def test_step_time_lower_bound(self, fwds, M):
        stages = [StageTiming(f, 2 * f, M) for f in fwds]
        r = simulate_1f1b(stages)
        # never faster than the busiest stage's serial work
        assert r.step_time >= max(3 * f * M for f in fwds) - 1e-9
        # bubble nonnegative
        assert all(b >= -1e-9 for b in r.stage_bubble)

    def test_reroute_slows_stage(self):
        base, _ = simulate_dp_pp([[1.0] * 4] * 2, [[2.0] * 4] * 2, 8)
        rerouted, _ = simulate_dp_pp([[1.0] * 4] * 2, [[2.0] * 4] * 2, 8,
                                     extra_micro={(0, 1): 4})
        assert rerouted > base


class TestCostModel:
    def test_eq1_overlap_caps_p2p(self):
        cfg = R.tiny_config("dense")
        hw = HardwareSpec()
        seg = SegmentCosts.build(cfg, 128, hw)
        # full overlap (sigma=1): P2P hidden if smaller than compute
        t_overlap = mini_step_time(seg, 0, 3, 4, sigma_f=1.0, sigma_b=1.0)
        t_noover = mini_step_time(seg, 0, 3, 4, sigma_f=0.0, sigma_b=0.0)
        assert t_overlap <= t_noover

    def test_monotone_in_layers_and_mbs(self):
        cfg = R.tiny_config("dense")
        seg = SegmentCosts.build(cfg, 128, HardwareSpec())
        assert seg.seg_fwd_flops(0, 3, 4) > seg.seg_fwd_flops(0, 1, 4)
        assert seg.seg_fwd_flops(0, 3, 8) > seg.seg_fwd_flops(0, 3, 4)
        assert seg.seg_mem(0, 3, 4, 2) > seg.seg_mem(0, 1, 4, 2)

    def test_moe_active_flops_lower_than_dense_total(self):
        moe = R.tiny_config("moe", num_experts=8, top_k=1)
        from repro.core.cost_model import layer_flops
        # top-1 of 8 experts: active flops far below all-expert compute
        fl = layer_flops(moe, 1, 128)
        dense_equiv = layer_flops(R.tiny_config("dense"), 1, 128)
        assert fl < 8 * dense_equiv
