"""Elastic-trace fuzzing: the four paper guarantees over random legal traces.

Every failure prints a one-line repro command carrying the fuzz seed
(``FuzzCase.repro()``), so a red CI log reproduces the exact workload + trace
with ``PYTHONPATH=src python -m benchmarks.fuzz_soak --mode ... --seed ...``.

Budgets: ``ELASWAVE_FUZZ_ANALYTIC`` (default 200 seeds x 3 policies, runs in
seconds) and ``ELASWAVE_FUZZ_NUMERIC`` (default 25 seeds, slow-marked: every
VirtualCluster jit-compiles afresh).  The injected-violation tests prove the
harness actually *fails* — each guarantee is broken on purpose (shard
corruption, rank-addressed RNG, tampered communicator, batch mutation) and
must be caught with the seed line attached.
"""
import dataclasses
import os
import random

import numpy as np
import pytest

import _hypothesis_stub as hs

from repro.core.communicator import DynamicCommunicator
from repro.core.events import ElasticEvent, EventKind
from repro.core.invariants import (DataflowConsistencyChecker,
                                   InvariantChecker, InvariantViolation,
                                   ParameterConsistencyChecker,
                                   default_analytic_checkers)
from repro.scenarios import (ClusterWorkload, POLICY_NAMES, Scenario,
                             make_analytic_case, make_cluster_case, run_case,
                             shrink_case, trace_is_legal)
from repro.scenarios.fuzz import FuzzCase

N_ANALYTIC = int(os.environ.get("ELASWAVE_FUZZ_ANALYTIC", "200"))
N_NUMERIC = int(os.environ.get("ELASWAVE_FUZZ_NUMERIC", "25"))


def _run_reporting(case, policy=None, **kw):
    """Run one case; on violation the repro line is already attached by
    ``run_case`` — just let it propagate (pytest shows the full message)."""
    return run_case(case, policy=policy, **kw)


# ---------------------------------------------------------------------------
# the headline properties
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", POLICY_NAMES)
def test_analytic_traces_uphold_invariants(policy):
    """>= N_ANALYTIC random legal analytic traces per policy, all four
    checkable analytic guarantees asserted after every event/decision."""
    for seed in range(N_ANALYTIC):
        _run_reporting(make_analytic_case(seed), policy=policy)


def test_numeric_smoke_traces_uphold_invariants():
    """Two numeric traces stay in the fast shard so the full checker stack
    (twin-oracle lockstep included) is exercised on every CI run."""
    for seed in (90, 91):
        _run_reporting(make_cluster_case(seed))


@pytest.mark.slow
def test_numeric_traces_uphold_invariants():
    """>= N_NUMERIC random legal numeric traces through the VirtualCluster
    with the full four-invariant checker stack."""
    for seed in range(N_NUMERIC):
        _run_reporting(make_cluster_case(seed))


# ---------------------------------------------------------------------------
# injected violations: the harness must catch every broken guarantee
# ---------------------------------------------------------------------------
def _cluster_case_with_shrink():
    for seed in range(60):
        c = make_cluster_case(seed)
        if any(e.is_shrink for e in c.scenario.events):
            return c
    raise RuntimeError("no shrink-bearing cluster seed in range")


def _analytic_case_with_shrink():
    for seed in range(60):
        c = make_analytic_case(seed)
        if any(e.is_shrink for e in c.scenario.events):
            return c
    raise RuntimeError("no shrink-bearing analytic seed in range")


class _ShardCorruptor(InvariantChecker):
    """Flips one master-weight element after each step (a silent bit error)."""
    name = "shard-corruptor"

    def after_cluster_step(self, step, cluster, loss):
        cluster.stages[0].flat["master"][0] += 1.0


def test_injected_shard_corruption_is_caught():
    case = _cluster_case_with_shrink()
    with pytest.raises(InvariantViolation) as ei:
        run_case(case, checkers=[_ShardCorruptor(),
                                 ParameterConsistencyChecker()])
    msg = str(ei.value)
    assert "parameter-consistency" in msg
    assert f"fuzz seed {case.seed}" in msg          # one-line repro attached
    assert f"--seed {case.seed}" in msg


def test_naive_rng_mode_is_caught():
    """The paper's rank-addressed ablation moves surviving samples' streams
    on the first dataflow resize — the RNG checker must flag it (§4.4)."""
    case = _cluster_case_with_shrink()
    naive = FuzzCase(case.seed, case.mode, case.scenario,
                     dataclasses.replace(case.workload, rng_mode="naive"))
    with pytest.raises(InvariantViolation, match="rng-consistency"):
        run_case(naive)


class _TamperedComm(DynamicCommunicator):
    """A communicator whose committed edits cost twice the truth."""

    def apply(self, delta, policy="edit"):
        stats = super().apply(delta, policy)
        stats.seconds *= 2.0
        return stats


def test_tampered_communicator_is_caught():
    case = _analytic_case_with_shrink()
    with pytest.raises(InvariantViolation, match="mttr-throughput"):
        run_case(case, policy="elaswave", comm_factory=_TamperedComm)


class _BatchMutator(InvariantChecker):
    """Silently shrinks the global batch after the first event (§4.1)."""
    name = "batch-mutator"

    def after_analytic_event(self, step, event, view, comm, extra):
        view.global_batch -= 1


def test_mutated_global_batch_is_caught():
    case = _analytic_case_with_shrink()
    with pytest.raises(InvariantViolation, match="dataflow-consistency"):
        run_case(case, policy="elaswave",
                 checkers=[_BatchMutator(), DataflowConsistencyChecker()])


# ---------------------------------------------------------------------------
# generator self-tests
# ---------------------------------------------------------------------------
def test_generated_analytic_traces_are_legal():
    for seed in range(100):
        c = make_analytic_case(seed)
        assert trace_is_legal(c.scenario.events, c.workload.dp,
                              c.workload.pp), f"seed {seed}"


def test_generation_is_deterministic():
    for seed in (0, 7, 123):
        a = make_analytic_case(seed)
        b = make_analytic_case(seed)
        assert [e.describe() for e in a.scenario.events] == \
               [e.describe() for e in b.scenario.events]
        assert a.workload == b.workload
        assert a.scenario.horizon == b.scenario.horizon


def test_cluster_traces_never_inject_migrate():
    """MIGRATE is analytic-only; the numeric executor rejects it."""
    for seed in range(60):
        c = make_cluster_case(seed)
        assert all(e.kind != EventKind.MIGRATE for e in c.scenario.events)


def test_cluster_traces_respect_event_budget():
    for seed in range(60):
        c = make_cluster_case(seed)
        # max_events=3 plus at most one trailing scheduled rejoin pair
        assert len(c.scenario.events) <= 4


def test_shrinker_minimizes_to_single_event():
    """Greedy event deletion on a synthetic predicate (trace contains a
    fail-slow with factor >= 2) must reach the 1-minimal trace."""
    wl = ClusterWorkload(dp=3, pp=1, global_batch=6, num_micro=1, seq_len=8,
                         num_layers=2)
    events = (
        ElasticEvent(EventKind.FAIL_STOP, 0, (1,)),
        ElasticEvent(EventKind.DVFS_SET, 1, (0,), freq=1.1),
        ElasticEvent(EventKind.FAIL_SLOW, 2, (0,), slow_factor=3.0),
        ElasticEvent(EventKind.SCALE_OUT, 3, (1,)),
        ElasticEvent(EventKind.FAIL_SLOW, 4, (2,), slow_factor=1.5),
    )
    case = FuzzCase(0, "cluster", Scenario("shrink-me", events, 6), wl)

    def fails(c):
        return any(e.kind == EventKind.FAIL_SLOW and e.slow_factor >= 2
                   for e in c.scenario.events)

    small = shrink_case(case, fails)
    assert len(small.scenario.events) == 1
    ev = small.scenario.events[0]
    assert ev.kind == EventKind.FAIL_SLOW and ev.slow_factor == 3.0


def test_shrinker_never_emits_illegal_traces():
    """Deleting a kill must drag its dependent rejoin out of consideration —
    every intermediate candidate offered to the predicate is legal."""
    wl = ClusterWorkload(dp=2, pp=1, global_batch=4, num_micro=1, seq_len=8,
                         num_layers=2)
    events = (
        ElasticEvent(EventKind.SCALE_IN, 0, (1,)),
        ElasticEvent(EventKind.SCALE_OUT, 1, (1,)),
        ElasticEvent(EventKind.FAIL_SLOW, 2, (0,), slow_factor=2.0),
    )
    case = FuzzCase(0, "cluster", Scenario("dep", events, 4), wl)
    seen = []

    def fails(c):
        assert trace_is_legal(c.scenario.events, wl.dp, wl.pp)
        seen.append(tuple(e.describe() for e in c.scenario.events))
        return any(e.kind == EventKind.FAIL_SLOW for e in c.scenario.events)

    small = shrink_case(case, fails)
    assert len(small.scenario.events) == 1
    assert seen                                   # predicate actually probed


# ---------------------------------------------------------------------------
# construction-time legality (satellite: crisp ValueErrors)
# ---------------------------------------------------------------------------
class TestEventLegality:
    def test_duplicate_ranks_in_burst(self):
        with pytest.raises(ValueError, match="duplicate ranks"):
            Scenario("bad", (ElasticEvent(EventKind.FAIL_STOP, 0, (1, 1)),), 4)

    def test_rejoin_of_live_rank(self):
        with pytest.raises(ValueError, match="rejoin of live rank"):
            Scenario("bad", (ElasticEvent(EventKind.SCALE_OUT, 0, (2,)),), 4)

    def test_refail_of_dead_rank(self):
        with pytest.raises(ValueError, match="already-dead"):
            Scenario("bad", (ElasticEvent(EventKind.FAIL_STOP, 0, (1,)),
                             ElasticEvent(EventKind.SCALE_IN, 1, (1,))), 4)

    def test_negative_step(self):
        with pytest.raises(ValueError, match="negative step"):
            Scenario("bad", (ElasticEvent(EventKind.FAIL_STOP, -1, (1,)),), 4)

    def test_negative_rank(self):
        with pytest.raises(ValueError, match="negative rank"):
            Scenario("bad", (ElasticEvent(EventKind.FAIL_STOP, 0, (-3,)),), 4)

    def test_rejoin_before_fail_is_rejected(self):
        # events sort by step, so rejoin@0 precedes fail@1 -> rejoin-of-live
        with pytest.raises(ValueError, match="rejoin of live rank"):
            Scenario.shrink_regrow("bad", rank=1, fail_step=2, rejoin_step=1,
                                   horizon=4)

    def test_legal_shrink_regrow_still_constructs(self):
        s = Scenario.shrink_regrow("ok", rank=1, fail_step=1, rejoin_step=2,
                                   horizon=4)
        assert len(s.events) == 2

    def test_fail_slow_repeats_are_legal(self):
        s = Scenario.cascade("ok", [(0, 1.5), (0, 2.0)], start=0, spacing=1,
                             horizon=4)
        assert len(s.events) == 2

    def test_trace_is_legal_rejects_last_replica_kill(self):
        evs = [ElasticEvent(EventKind.FAIL_STOP, 0, (0, 2)),
               ElasticEvent(EventKind.FAIL_STOP, 1, (4,))]
        assert not trace_is_legal(evs, dp=3, pp=2)   # stage 0 emptied
        assert trace_is_legal(evs[:1], dp=3, pp=2)

    def test_trace_is_legal_rejects_out_of_grid_rank(self):
        evs = [ElasticEvent(EventKind.FAIL_STOP, 0, (99,))]
        assert not trace_is_legal(evs, dp=2, pp=2)


# ---------------------------------------------------------------------------
# hypothesis-stub upgrades (satellite)
# ---------------------------------------------------------------------------
class TestHypothesisStub:
    def test_tuples_booleans_one_of_deterministic(self):
        st = hs.strategies
        strat = st.tuples(st.integers(0, 9), st.booleans(),
                          st.one_of(st.just("a"), st.just("b")))
        a = [strat.draw(random.Random(42)) for _ in range(5)]
        b = [strat.draw(random.Random(42)) for _ in range(5)]
        assert a == b
        x, flag, tag = a[0]
        assert 0 <= x <= 9 and isinstance(flag, bool) and tag in ("a", "b")

    def test_one_of_accepts_iterable(self):
        st = hs.strategies
        strat = st.one_of([st.just(1), st.just(2)])
        assert strat.draw(random.Random(0)) in (1, 2)

    def test_data_records_draws(self):
        st = hs.strategies
        d = st.data().draw(random.Random(0))
        v = d.draw(st.integers(5, 5), label="x")
        assert v == 5
        assert "x=5" in repr(d)

    def test_failure_report_prints_seed_and_values(self, capsys):
        @hs.given(hs.strategies.integers(0, 3))
        def prop(value):
            raise AssertionError("boom")

        with pytest.raises(AssertionError, match="boom"):
            prop()
        out = capsys.readouterr().out
        assert "falsifying example" in out
        assert "value=" in out                      # drawn values reported
        assert f"{prop.__module__}" in out          # derived seed string


if __name__ == "__main__":
    raise SystemExit(pytest.main([__file__, "-v"]))
