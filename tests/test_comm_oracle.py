"""Vectorized-vs-dict communicator oracle (ISSUE 7 equivalence contract).

``core.communicator.DynamicCommunicator`` (int64 link codes, memoized CSR
group tables) must be observationally identical to the preserved seed
implementation ``core.legacy_comm.LegacyDynamicCommunicator`` at small scale:
same ``OpStats`` (counts AND seconds), same group tables, same link sets,
same ``affected_groups``, and same end-to-end MTTR accounting through
``AnalyticScenarioRunner`` — across random hybrid layouts, random burst
sizes, and all three recovery policies, at dp x pp x tp <= 64 ranks.
"""
import random

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # container lacks hypothesis -> deterministic stub
    from _hypothesis_stub import given, settings, strategies as st

from repro.core.clusterview import GroupDelta
from repro.core.events import EventKind, burst as make_burst
from repro.core.communicator import (DynamicCommunicator, OpStats,
                                     build_hybrid_groups)
from repro.core.legacy_comm import LegacyDynamicCommunicator

LAYOUTS = [(2, 2, 1), (4, 2, 1), (2, 4, 2), (4, 4, 2), (8, 4, 2), (4, 4, 4),
           (3, 3, 1), (2, 8, 4)]
POLICIES = ("edit", "partial_rebuild", "full_rebuild")


def _stats_tuple(s: OpStats):
    return (s.mode, s.links_created, s.links_reused, s.links_destroyed,
            s.ranks_touched, s.seconds)


def _random_trace(dp, pp, tp, seed, steps=4):
    """A deterministic random burst trace over the layout's rank space."""
    rng = random.Random(seed)
    n = dp * pp * tp
    trace = []
    for _ in range(steps):
        k = rng.randint(1, max(1, n // 4))
        rem = tuple(sorted(rng.sample(range(n), k)))
        n_add = rng.randint(0, len(rem))
        adds = tuple((f"dp_stage{(r // tp) % pp}_tp{r % tp}", r)
                     for r in rem[:n_add])
        trace.append((GroupDelta(remove=rem, add=adds),
                      rng.choice(POLICIES)))
    return trace


class TestOpStatsOracle:
    @settings(max_examples=20)
    @given(st.sampled_from(LAYOUTS), st.integers(0, 10**6))
    def test_apply_matches_legacy(self, layout, seed):
        dp, pp, tp = layout
        g = build_hybrid_groups(dp, pp, tp)
        vec, leg = DynamicCommunicator(g), LegacyDynamicCommunicator(g)
        assert vec.links == leg.links
        assert vec.all_ranks() == leg.all_ranks()
        for delta, policy in _random_trace(dp, pp, tp, seed):
            pv = vec.price(delta, policy)
            pl = leg.price(delta, policy)
            assert _stats_tuple(pv) == _stats_tuple(pl)
            sv = vec.apply(delta, policy)
            sl = leg.apply(delta, policy)
            assert _stats_tuple(sv) == _stats_tuple(sl)
            assert _stats_tuple(pv) == _stats_tuple(sv)  # price == commit
            assert vec.groups == leg.groups
            assert vec.links == leg.links

    @settings(max_examples=10)
    @given(st.sampled_from(LAYOUTS), st.integers(0, 10**6))
    def test_affected_groups_identical(self, layout, seed):
        dp, pp, tp = layout
        g = build_hybrid_groups(dp, pp, tp)
        vec, leg = DynamicCommunicator(g), LegacyDynamicCommunicator(g)
        rng = random.Random(seed)
        n = dp * pp * tp
        for _ in range(5):
            ranks = rng.sample(range(n), rng.randint(1, max(1, n // 3)))
            assert vec.affected_groups(ranks) == leg.affected_groups(ranks)
        assert vec.affected_groups([]) == leg.affected_groups([]) == []

    def test_price_does_not_mutate(self):
        g = build_hybrid_groups(4, 4, 2)
        vec = DynamicCommunicator(g)
        before_groups = {k: list(v) for k, v in vec.groups.items()}
        before_links = vec.links
        for policy in POLICIES:
            vec.price(GroupDelta.shrink([0, 5, 9]), policy)
        assert vec.groups == before_groups
        assert vec.links == before_links
        assert vec.history == []

    def test_deprecated_shims_delegate(self):
        g = build_hybrid_groups(4, 2)
        vec, ref = DynamicCommunicator(g), DynamicCommunicator(g)
        with pytest.warns(DeprecationWarning):
            st_old = vec.edit(remove=[3])
        st_new = ref.apply(GroupDelta.shrink([3]), "edit")
        assert _stats_tuple(st_old) == _stats_tuple(st_new)
        assert len(vec.history) == 1
        with pytest.warns(DeprecationWarning):
            vec.partial_rebuild(remove=[4])
        with pytest.warns(DeprecationWarning):
            vec.full_rebuild({k: list(v) for k, v in vec.groups.items()})
        assert [h.mode for h in vec.history] == \
            ["edit", "partial_rebuild", "full_rebuild"]

    def test_ring_cache_invalidation(self):
        """Satellite: memoized per-group ring codes must be dropped for
        edited groups and reused (same object) for untouched ones."""
        g = build_hybrid_groups(4, 4)
        vec = DynamicCommunicator(g)
        vec.affected_groups([0])                      # warm CSR
        c_before = vec._codes("dp_stage0_tp0")
        untouched = vec._codes("dp_stage3_tp0")
        vec.apply(GroupDelta.shrink([0]), "edit")     # rank 0 is stage 0
        assert vec._codes("dp_stage3_tp0") is untouched
        c_after = vec._codes("dp_stage0_tp0")
        assert not np.array_equal(c_before, c_after)


class TestMttrOracle:
    @settings(max_examples=6)
    @given(st.sampled_from([(2, 2), (4, 2), (4, 4), (8, 8)]),
           st.integers(0, 10**6))
    def test_runner_accounting_identical(self, shape, seed):
        """End-to-end MTTR accounting: the analytic runner with the
        vectorized communicator produces byte-identical recovery records and
        summaries to the legacy dict/set communicator."""
        from repro.core.cost_model import HardwareSpec
        from repro.core.policies import ElasWavePolicy
        from repro.models import registry as R
        from repro.scenarios import (AnalyticScenarioRunner, AnalyticWorkload,
                                     Scenario)
        dp, pp = shape
        rng = random.Random(seed)
        hw = HardwareSpec()
        w = AnalyticWorkload(cfg=R.tiny_config("dense", num_layers=2 * pp),
                             dp=dp, pp=pp, mbs=1, global_batch=2 * dp,
                             seq=64, hw=hw)
        # burst killing one random replica-worth of ranks, then regrow
        dead = tuple(sorted(rng.sample(range(dp * pp),
                                       rng.randint(1, max(1, pp // 2)))))
        scn = Scenario("oracle", (
            make_burst(EventKind.FAIL_STOP, 2, dead),
            make_burst(EventKind.SCALE_OUT, 6, dead)), 10)
        vec = AnalyticScenarioRunner(scn, w, ElasWavePolicy(hw=hw)).run()
        leg = AnalyticScenarioRunner(
            scn, w, ElasWavePolicy(hw=hw),
            comm_factory=LegacyDynamicCommunicator).run()
        assert vec.recoveries == leg.recoveries
        assert vec.summary == leg.summary
        assert [{k: v for k, v in s.items() if k != "decide_wall_seconds"}
                for s in vec.steps] == \
               [{k: v for k, v in s.items() if k != "decide_wall_seconds"}
                for s in leg.steps]
