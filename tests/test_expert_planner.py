"""EP elasticity planner: LPT placement quality + reshard plan invariants."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # container lacks hypothesis -> deterministic stub
    from _hypothesis_stub import given, settings, strategies as st

from repro.core.planners.expert import (ExpertPlan, brute_force_placement,
                                        lpt_placement, plan_expert_reshard)


class TestLpt:
    @given(st.lists(st.floats(0.1, 10.0), min_size=2, max_size=7),
           st.integers(2, 3))
    @settings(max_examples=60, deadline=None)
    def test_within_lpt_bound_of_optimal(self, loads, W):
        """LPT is a (4/3 - 1/3m)-approximation of minimax makespan."""
        workers = list(range(W))
        placement = lpt_placement(loads, workers)
        got = {w: 0.0 for w in workers}
        for e, w in placement.items():
            got[w] += loads[e]
        opt = brute_force_placement(loads, workers)
        assert max(got.values()) <= opt * (4 / 3 - 1 / (3 * W)) + 1e-9

    def test_every_expert_placed_once(self):
        placement = lpt_placement([1.0] * 8, [0, 1, 2])
        assert sorted(placement) == list(range(8))
        assert set(placement.values()) <= {0, 1, 2}


class TestReshard:
    def test_orphans_recovered_survivors_pinned(self):
        E, W = 8, 4
        old = {e: e % W for e in range(E)}         # round robin
        plan = plan_expert_reshard([1.0] * E, old, surviving=[0, 1, 3],
                                   expert_bytes=1000,
                                   snapshot_holder={e: (e % W + 1) % W
                                                    for e in range(E)})
        # every expert placed on a survivor
        assert set(plan.placement.values()) <= {0, 1, 3}
        # survivors' experts did not move
        for e, w in old.items():
            if w in (0, 1, 3):
                assert plan.placement[e] == w
        # orphaned experts (worker 2: experts 2, 6) moved, from snapshots
        moved = {m.expert for m in plan.moves}
        assert moved == {2, 6}
        assert all(m.from_snapshot for m in plan.moves)
        assert plan.est_seconds > 0

    def test_hot_expert_balance(self):
        """A hot expert's orphaned siblings land on the coldest workers."""
        load = [10.0, 1.0, 1.0, 1.0]
        old = {0: 0, 1: 0, 2: 1, 3: 1}
        plan = plan_expert_reshard(load, old, surviving=[0, 1],
                                   expert_bytes=10)
        # pinned stay; nothing orphaned -> no moves
        assert plan.moves == []
        plan2 = plan_expert_reshard(load, {0: 2, 1: 0, 2: 0, 3: 1},
                                    surviving=[0, 1], expert_bytes=10)
        # hot orphan 0 goes to the lighter worker (1)
        assert plan2.placement[0] == 1

    @given(st.integers(3, 8), st.integers(2, 4))
    @settings(max_examples=40, deadline=None)
    def test_shrink_plan_complete(self, E, W):
        old = {e: e % W for e in range(E)}
        surviving = list(range(1, W))              # worker 0 dies
        plan = plan_expert_reshard([1.0] * E, old, surviving, 64)
        assert sorted(plan.placement) == list(range(E))
        assert set(plan.placement.values()) <= set(surviving)
        # exactly the orphans move
        orphans = {e for e, w in old.items() if w == 0}
        assert {m.expert for m in plan.moves} == orphans
