"""Elastic serving plane: continuous batching, SLO admission, KV-cache
migration bit-exactness, sampled-stream reproducibility across migration,
recovery-policy dispositions, scenario runner schema, and the Agent's
dynamic rank registration (see docs/ARCHITECTURE.md)."""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.agent import Agent, Probe
from repro.core.events import ElasticEvent, EventKind
from repro.models import registry as R
from repro.scenarios import Scenario, ServeWorkload, run_serve_scenario
from repro.serving import (SERVE_POLICIES, KVPool, DropPolicy, Request,
                           RequestState, SLO, SamplerConfig, ServingEngine,
                           migrate_slot, offline_generate, sample_tokens)


def tiny_cfg(**kw):
    base = dict(num_layers=2, d_model=32, num_heads=2, num_kv_heads=1,
                d_ff=64, vocab_size=128, dropout_rate=0.0)
    base.update(kw)
    return R.tiny_config("dense", **base)


def submit_n(engine, n, prompt_len=6, max_new=4, seed=0):
    rng = np.random.default_rng(seed)
    for rid in range(n):
        prompt = rng.integers(0, engine.cfg.vocab_size,
                              size=prompt_len).astype(np.int32)
        engine.submit(Request(rid=rid, arrival=0.0, prompt=prompt,
                              max_new_tokens=max_new))


def sequences(engine, n):
    return [list(engine.requests[rid].generated) for rid in range(n)]


# ---------------------------------------------------------------------------
# numeric: migration bit-exactness (greedy and sampled streams)
# ---------------------------------------------------------------------------
@pytest.mark.slow
class TestMigrationBitExact:
    @pytest.mark.parametrize("sampler", [
        SamplerConfig(),                                        # greedy
        SamplerConfig(method="topk", temperature=0.7, top_k=8, seed=3),
    ], ids=["greedy", "topk"])
    def test_scale_in_migration_is_invisible_to_tokens(self, sampler):
        """A mid-stream single-replica SCALE_IN migrates every in-flight
        request (zero drops) and the decoded streams are bit-identical to an
        undisturbed run — for greedy AND seeded top-k sampling (the sampling
        key is (rid, absolute position), not (replica, slot))."""
        cfg = tiny_cfg()

        def make():
            eng = ServingEngine(cfg, n_replicas=2, slots_per_replica=3,
                                max_len=16, mode="numeric", seed=0,
                                sampler=sampler)
            submit_n(eng, 3)
            return eng

        base = make()
        base.drain()
        want = sequences(base, 3)
        assert all(len(s) == 4 for s in want)

        eng = make()
        eng.tick()                      # admit + prefill everywhere
        eng.tick()                      # one batched decode step
        assert eng.replicas[0].pool.n_active > 0
        stats = eng.apply_event(
            ElasticEvent(EventKind.SCALE_IN, 0, (0,)))
        assert stats["migrated"] == 2 and stats["dropped"] == 0
        assert stats["kv_bytes_moved"] > 0
        assert sorted(eng.replicas) == [1]
        eng.drain()

        assert sequences(eng, 3) == want
        s = eng.summary()
        assert s["completed"] == 3 and s["dropped"] == 0
        assert s["migrations"] == 2


# ---------------------------------------------------------------------------
# numeric: offline generation (enc-dec fixed to work, not rejected)
# ---------------------------------------------------------------------------
@pytest.mark.slow
class TestOfflineGenerate:
    def test_encdec_serves_through_engine(self):
        cfg = R.tiny_config("audio", dropout_rate=0.0)
        out = offline_generate(cfg, batch=2, prompt_len=3, max_new_tokens=3,
                               seed=0, frames_len=8)
        assert out["sequences"].shape == (2, 3)
        assert out["summary"]["completed"] == 2
        assert out["summary"]["dropped"] == 0


# ---------------------------------------------------------------------------
# synthetic: policy dispositions, rebuild invariance, SLO admission
# ---------------------------------------------------------------------------
def synthetic_engine(policy=None, n_replicas=2, slots=2, slo=None,
                     max_len=32):
    return ServingEngine(tiny_cfg(), n_replicas=n_replicas,
                         slots_per_replica=slots, max_len=max_len,
                         mode="synthetic", policy=policy, slo=slo)


class TestRecoveryPolicies:
    def test_fail_stop_rebuilds_and_streams_unchanged(self):
        base = synthetic_engine()
        submit_n(base, 4)
        base.drain()
        want = sequences(base, 4)

        eng = synthetic_engine()
        submit_n(eng, 4)
        eng.tick()
        eng.tick()
        stats = eng.apply_event(ElasticEvent(EventKind.FAIL_STOP, 0, (0,)))
        assert stats["rebuilt"] == 2 and stats["dropped"] == 0
        assert stats["stall_seconds"] >= eng.cost.detect_seconds
        eng.drain()
        assert sequences(eng, 4) == want        # (rid, pos)-content tokens
        assert eng.summary()["re_prefills"] == 2
        assert eng.summary()["completed"] == 4

    def test_drop_policy_loses_in_flight(self):
        eng = synthetic_engine(policy=DropPolicy())
        submit_n(eng, 4)
        eng.tick()
        eng.tick()
        stats = eng.apply_event(ElasticEvent(EventKind.SCALE_IN, 0, (0,)))
        assert stats["dropped"] == 2
        eng.drain()
        s = eng.summary()
        assert s["dropped"] == 2 and s["completed"] == 2
        dropped = [r for r in eng.requests.values()
                   if r.state == RequestState.DROPPED]
        assert len(dropped) == 2

    def test_scale_out_adds_replica_and_agent_rank(self):
        eng = synthetic_engine(n_replicas=1)
        eng.apply_event(ElasticEvent(EventKind.SCALE_OUT, 0, (3,)))
        assert sorted(eng.replicas) == [0, 3]
        assert eng.agent.ranks == [0, 3]


class TestRecoveryEdges:
    """Recovery edge cases: a burst SCALE_IN that removes a replica which
    just *received* migrated slots (concurrent scale-in during an in-flight
    migration), full survivors under both migrate and drop dispositions."""

    def test_scale_in_burst_chains_migrations_through_doomed_replica(self):
        """One burst removes replicas 0 AND 1: replica 0's slots migrate
        into a survivor that is itself being removed later in the same
        event, so they must hop again — zero drops, streams unchanged."""
        def make():
            eng = synthetic_engine(n_replicas=3, slots=4)
            submit_n(eng, 6)
            return eng

        base = make()
        base.drain()
        want = sequences(base, 6)

        eng = make()
        eng.tick()
        eng.tick()
        assert all(eng.replicas[r].pool.n_active == 2 for r in range(3))
        stats = eng.apply_event(
            ElasticEvent(EventKind.SCALE_IN, 0, (0, 1)))
        assert stats["dropped"] == 0
        # replica 0's two slots land on a survivor, replica 1's (its own two
        # plus any just-received) hop onward; everything ends on replica 2
        assert stats["migrated"] + stats["rebuilt"] >= 4
        assert sorted(eng.replicas) == [2]
        assert eng.replicas[2].pool.n_active + len(eng.queue) == 6 - \
            eng.summary()["completed"]
        eng.drain()
        assert sequences(eng, 6) == want
        s = eng.summary()
        assert s["completed"] == 6 and s["dropped"] == 0

    def test_migrate_falls_back_to_rebuild_when_survivors_full(self):
        """ElasWave policy on SCALE_IN prefers migration, but with zero free
        survivor slots it must degrade to requeue-with-prefix (rebuild), not
        drop — and the requeued work still completes."""
        eng = synthetic_engine(n_replicas=2, slots=2)
        submit_n(eng, 4)                      # fills both replicas exactly
        eng.tick()
        eng.tick()
        assert all(r.pool.n_free == 0 for r in eng.alive_replicas())
        stats = eng.apply_event(ElasticEvent(EventKind.SCALE_IN, 0, (0,)))
        assert stats["migrated"] == 0         # nowhere to put the KV
        assert stats["rebuilt"] == 2 and stats["dropped"] == 0
        assert stats["kv_bytes_moved"] == 0
        eng.drain()
        s = eng.summary()
        assert s["completed"] == 4 and s["dropped"] == 0
        assert s["re_prefills"] == 2

    def test_drop_accounting_with_full_survivors(self):
        """DROP disposition with survivors at capacity: the departing
        replica's in-flight work is charged as dropped (not rebuilt, no KV
        movement, no stall), survivors' work is untouched, and queued work
        still drains through the remaining capacity."""
        eng = synthetic_engine(policy=DropPolicy(), n_replicas=2, slots=2)
        submit_n(eng, 6)                      # 4 in flight + 2 queued
        eng.tick()
        eng.tick()
        assert eng.n_active == 4 and len(eng.queue) == 2
        stats = eng.apply_event(ElasticEvent(EventKind.SCALE_IN, 0, (0,)))
        assert stats["dropped"] == 2
        assert stats["migrated"] == 0 and stats["rebuilt"] == 0
        assert stats["kv_bytes_moved"] == 0
        assert stats["stall_seconds"] == 0.0  # graceful + no KV to move
        eng.drain()
        s = eng.summary()
        assert s["dropped"] == 2 and s["completed"] == 4
        dropped = {r.rid for r in eng.requests.values()
                   if r.state == RequestState.DROPPED}
        assert len(dropped) == 2              # exactly the doomed slots


class TestSLOAdmission:
    def test_blown_ttft_is_rejected_at_first_admission(self):
        eng = synthetic_engine(slo=SLO(ttft=0.01, per_token=1.0), max_len=80)
        prompt = np.zeros(64, dtype=np.int32)   # prefill alone blows 10 ms
        eng.submit(Request(rid=0, arrival=0.0, prompt=prompt,
                           max_new_tokens=4))
        eng.tick()
        assert eng.requests[0].state == RequestState.REJECTED
        assert eng.summary()["rejected"] == 1

    def test_full_pools_defer_but_eventually_serve(self):
        eng = synthetic_engine(n_replicas=1, slots=2)
        submit_n(eng, 5)
        eng.tick()
        assert eng.n_active == 2 and eng.deferrals >= 1
        eng.drain()
        s = eng.summary()
        assert s["completed"] == 5 and s["rejected"] == 0


# ---------------------------------------------------------------------------
# kv pool mechanics
# ---------------------------------------------------------------------------
class TestKVPool:
    def test_migrate_slot_moves_exact_arrays(self):
        caches = {"k": jnp.arange(2 * 3 * 8 * 4, dtype=jnp.float32)
                  .reshape(2, 3, 8, 4)}
        src = KVPool(3, caches)
        dst = KVPool(3, {"k": jnp.zeros((2, 3, 8, 4), jnp.float32)})
        src.assign(1, rid=7, length=5)
        moved = migrate_slot(src, 1, dst, 2, rid=7)
        assert moved > 0
        assert src.slot_req[1] == -1 and dst.slot_req[2] == 7
        assert int(dst.lengths[2]) == 5
        np.testing.assert_array_equal(np.asarray(dst.caches["k"][:, 2]),
                                      np.asarray(caches["k"][:, 1]))

    def test_sample_tokens_deterministic_in_rid_and_position(self):
        sc = SamplerConfig(method="topk", temperature=0.8, top_k=4, seed=1)
        logits = np.random.default_rng(0).standard_normal((2, 32))
        a = sample_tokens(logits, [5, 9], [3, 3], sc)
        b = sample_tokens(logits, [5, 9], [3, 3], sc)
        np.testing.assert_array_equal(a, b)    # replayable stream
        # the key is content-addressed in (rid, position): the draw for
        # (rid=5, pos=3) is the same regardless of its row in the batch
        c = sample_tokens(logits[::-1], [9, 5], [3, 3], sc)
        assert int(c[1]) == int(a[0])
        assert all(0 <= int(t) < 32 for t in c)


# ---------------------------------------------------------------------------
# scenario runner + artifact schema
# ---------------------------------------------------------------------------
class TestServeScenarioRunner:
    TRACE = [(60, 0), (60, 1), (60, 2), (60, 0)]

    def run(self, policy):
        scn = Scenario.from_capacity_trace("serve_t", self.TRACE, dp=4, pp=2)
        w = ServeWorkload(mode="synthetic", request_rate=0.15,
                          max_new_tokens=48, max_len=80)
        # compress hard so the open-loop stream keeps slots busy and the
        # capacity changes land on in-flight requests (same as serve_bench)
        return run_serve_scenario(scn, w, policy=SERVE_POLICIES[policy],
                                  time_scale=0.02)

    def test_migrate_policy_drops_nothing_drop_policy_does(self):
        mig = self.run("elaswave_migrate")
        drp = self.run("drop")
        assert mig.summary["dropped"] == 0
        assert mig.summary["migrations"] + mig.summary["re_prefills"] > 0
        assert drp.summary["dropped"] > 0
        assert mig.summary["completed"] > drp.summary["completed"]

    def test_result_schema_round_trips(self):
        res = self.run("rebuild")
        blob = json.loads(res.to_json())
        assert blob["mode"] == "serving"
        assert blob["workload"]["n_replicas"] == 4
        assert blob["steps"] and blob["recoveries"]
        rec = blob["recoveries"][0]
        assert {"migrated", "rebuilt", "dropped",
                "kv_bytes_moved"} <= set(rec["serving"])
        for k in ("ttft_p50", "ttft_p99", "per_token_p50", "per_token_p99",
                  "goodput_tokens_per_s", "slo_attainment",
                  "drops_per_capacity_change"):
            assert k in blob["summary"]


# ---------------------------------------------------------------------------
# agent: dynamic rank membership
# ---------------------------------------------------------------------------
class TestAgentDynamicRanks:
    def probes(self, agent, dead=()):
        return [Probe(step=0, rank=r, heartbeat=r not in dead,
                      step_seconds=0.1) for r in agent.ranks]

    def test_membership_and_unregistered_probes_ignored(self):
        a = Agent(num_ranks=2, miss_limit=2)
        assert a.ranks == [0, 1]
        a.remove_rank(1)
        assert a.ranks == [0] and a.num_ranks == 1
        # probes for retired ranks are ignored, not KeyErrors
        evs = a.observe([Probe(step=0, rank=1, heartbeat=False,
                               step_seconds=0.1),
                         Probe(step=0, rank=0, heartbeat=True,
                               step_seconds=0.1)])
        assert evs == []
        a.add_rank(3)
        assert a.ranks == [0, 3]

    def test_rejoined_rank_is_redetected_after_second_failure(self):
        a = Agent(num_ranks=2, miss_limit=2)
        for _ in range(2):
            evs = a.observe(self.probes(a, dead={1}))
        assert [e.kind for e in evs] == [EventKind.FAIL_STOP]
        a.remove_rank(1)              # recovery retires it
        a.add_rank(1)                 # ...then it rejoins
        evs = []
        for _ in range(2):
            evs = a.observe(self.probes(a, dead={1}))
        assert [e.kind for e in evs] == [EventKind.FAIL_STOP]

    def test_cluster_rejoin_then_fail_is_redetected(self):
        """End-to-end through the VirtualCluster wiring: fail -> recover
        (remove_rank) -> scale-out rejoin (add_rank) -> fail again must be
        re-detected, which the static-membership agent could not do."""
        from repro.core.cluster import VirtualCluster
        cl = VirtualCluster(R.tiny_config("dense", num_layers=2), dp=2, pp=2,
                            global_batch=4, num_micro=2, seq_len=8, seed=0)
        cl.inject_fail_stop(1, 1)
        assert cl.detect_and_recover() is not None
        assert 3 not in cl.agent.ranks
        cl.recover_scale_out(1, 1)
        assert 3 in cl.agent.ranks
        cl.inject_fail_stop(1, 1)
        assert cl.detect_and_recover() is not None
